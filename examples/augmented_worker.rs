//! Figure 5: the augmented-worker application — multi-device AND
//! multi-modal.
//!
//! Mobile device, left pipeline:  camera -> DETECT model -> tensor_if
//!   gate; when an assembly action is detected, an "activation" message
//!   is published to the wearable.
//! Wearable device: publishes IMU windows only while activated (sensor
//!   power gating).
//! Mobile device, right pipeline: subscribes the wearable stream, runs
//!   the action classifier (correct/incorrect), reports to the app.
//!
//! Run: `make artifacts && cargo run --release --example augmented_worker`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use edgepipe::buffer::Buffer;
use edgepipe::caps::Caps;
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::appsink_channel;
use edgepipe::metrics;
use edgepipe::mqtt::{Broker, ClientOptions, MqttClient};
use edgepipe::pipeline::parser;
use edgepipe::serial::wire;
use edgepipe::tensor::{f32_to_bytes, DType, TensorInfo, TensorsInfo};
use edgepipe::util::rng::XorShift64;

fn start(desc: &str, registry: &Registry, env: &PipelineEnv) -> edgepipe::pipeline::Running {
    parser::parse(desc, registry, env).expect("parse").start().expect("start")
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    for m in ["detect", "imucls"] {
        if !std::path::Path::new(&env.artifacts_dir).join(format!("{m}.manifest.txt")).exists() {
            eprintln!("artifacts missing — run `make artifacts` first");
            std::process::exit(1);
        }
    }
    let broker = Broker::start("127.0.0.1:0")?;
    let b = broker.addr().to_string();
    println!("broker on {b}");

    // Mobile, left pipeline: DETECT gate publishes activation on/off.
    let left = start(
        &format!(
            "videotestsrc width=96 height=96 framerate=15 pattern=ball num-buffers=60 ! \
             tensor_converter ! tensor_transform mode=arithmetic option=typecast:float32,div:255.0 ! \
             tensor_filter framework=pjrt model=detect ! \
             tensor_if compared-value=0 operator=gt threshold=0.4 name=gate \
             gate.src_0 ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=worker/activate broker={b} \
             gate.src_1 ! fakesink"
        ),
        &registry,
        &env,
    );

    // Wearable device: IMU sensor publishing ONLY while activated.
    // (Modeled with the edge library — a wearable runs EdgePipe-Edge, not
    // the full framework.)
    let active = Arc::new(AtomicBool::new(false));
    let act2 = active.clone();
    let watcher = MqttClient::connect(
        &b,
        ClientOptions { client_id: "wearable-ctl".into(), ..Default::default() },
    )?;
    watcher.subscribe_cb("worker/activate", move |_msg| {
        act2.store(true, Ordering::Relaxed);
    })?;

    let imu_info = TensorsInfo::one(TensorInfo::new(DType::F32, &[9, 128]).unwrap());
    let wearable_b = b.clone();
    let active_w = active.clone();
    let wearable = std::thread::spawn(move || {
        let mut sensor =
            edgepipe::edge::EdgeSensor::connect(&wearable_b, "worker/imu", &imu_info).unwrap();
        let mut rng = XorShift64::new(7);
        let mut published = 0u64;
        for _ in 0..40 {
            std::thread::sleep(Duration::from_millis(100));
            if !active_w.load(Ordering::Relaxed) {
                continue; // sensors off: power saving (Fig 5)
            }
            let window: Vec<f32> = (0..128 * 9).map(|_| rng.normal() * 0.5).collect();
            sensor.publish(&f32_to_bytes(&window)).unwrap();
            published += 1;
        }
        sensor.close();
        published
    });

    // Mobile, right pipeline: classify wearable windows.
    let right = start(
        &format!(
            "mqttsrc sub-topic=worker/imu broker={b} ! tensor_converter ! queue leaky=2 ! \
             tensor_filter framework=pjrt model=imucls ! appsink channel=verdicts"
        ),
        &registry,
        &env,
    );
    let verdicts = appsink_channel("verdicts").expect("verdict channel");

    let mut correct = 0u64;
    let mut incorrect = 0u64;
    let reporter = std::thread::spawn(move || {
        while let Ok(buf) = verdicts.recv_timeout(Duration::from_secs(15)) {
            let p_ok = f32::from_le_bytes([buf.data[0], buf.data[1], buf.data[2], buf.data[3]]);
            if p_ok >= 0.5 {
                correct += 1;
            } else {
                incorrect += 1;
                println!("  ALARM: incorrect assembly detected (p={:.2})", 1.0 - p_ok);
            }
        }
        (correct, incorrect)
    });

    let _ = left.wait_eos(Duration::from_secs(120));
    let published = wearable.join().unwrap();
    std::thread::sleep(Duration::from_millis(800));
    let _ = right.stop(Duration::from_secs(5));
    let (correct, incorrect) = reporter.join().unwrap();

    let activations = metrics::global().counter("tensor_if.gate.then").count();
    let idles = metrics::global().counter("tensor_if.gate.else").count();
    println!("DETECT gate: {activations} activations, {idles} idle frames");
    println!("wearable: {published} IMU windows published (gated)");
    println!("classifier verdicts: {correct} correct, {incorrect} incorrect");
    assert!(activations + idles > 0);

    // Demonstrate the full frame wire format is what crossed the broker:
    let _ = wire::encode(&Buffer::new(vec![0u8; 4]), Some(&Caps::tensors_flexible()), Default::default());
    println!("augmented_worker OK");
    Ok(())
}
