//! Figure 2 / Listing 1: inference workload offloading with query
//! elements, including capability discovery and automatic failover (R3/R4).
//!
//! Topology (all in one process; every byte crosses real sockets):
//!   - an MQTT broker
//!   - TWO server pipelines ("Device B" twice) advertising
//!     `objdetect/ssdlite` with the detect gate model
//!   - ONE client pipeline ("Device A") using
//!     `tensor_query_client protocol=mqtt-hybrid` — no server address in
//!     its description
//!
//! Mid-run the primary server is killed; the client fails over and the
//! stream continues.
//!
//! Run: `make artifacts && cargo run --release --example offload_query`

use std::time::Duration;

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::appsink_channel;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;

fn start(desc: &str, registry: &Registry, env: &PipelineEnv) -> edgepipe::pipeline::Running {
    parser::parse(desc, registry, env).expect("parse").start().expect("start")
}

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    if !std::path::Path::new(&env.artifacts_dir).join("detect.manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let broker = Broker::start("127.0.0.1:0")?;
    let b = broker.addr().to_string();
    println!("broker on {b}");

    // Device B (x2): one-line server pipelines (paper §5.1: "declaring the
    // service name is all developers need to do").
    let (p1, p2) = (free_port(), free_port());
    let server_desc = |pair: &str, port: u16| {
        format!(
            "tensor_query_serversrc operation=objdetect/ssdlite port={port} pair-id={pair} \
               protocol=mqtt-hybrid broker={b} server-id={pair} model-label=detect-v1 ! \
             tensor_filter framework=pjrt model=detect ! \
             tensor_query_serversink operation=objdetect/ssdlite pair-id={pair}"
        )
    };
    let server1 = start(&server_desc("server-a", p1), &registry, &env);
    let server2 = start(&server_desc("server-b", p2), &registry, &env);
    std::thread::sleep(Duration::from_millis(500));
    println!("servers advertised: server-a:{p1}, server-b:{p2}");

    // Device A: client discovers by capability `objdetect/#` (R3).
    let client = start(
        &format!(
            "videotestsrc width=96 height=96 framerate=20 pattern=ball num-buffers=60 ! \
             videoconvert ! tensor_converter ! \
             tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
             tensor_query_client operation=objdetect/# protocol=mqtt-hybrid broker={b} timeout-ms=2000 ! \
             appsink channel=results"
        ),
        &registry,
        &env,
    );
    let rx = appsink_channel("results").expect("results channel");

    let mut n = 0u64;
    let mut killed = false;
    loop {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(buf) => {
                n += 1;
                let act = f32::from_le_bytes([buf.data[0], buf.data[1], buf.data[2], buf.data[3]]);
                if n % 10 == 0 {
                    println!("  response {n}: activation {act:.3}");
                }
                if n == 20 && !killed {
                    println!(">>> killing primary server mid-stream (R4 failover test)");
                    // Stop server-a entirely; the client's next request
                    // fails and it reconnects to server-b.
                    let _ = &server1;
                    killed = true;
                    // Drop is deferred: move it out via Option dance below.
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let _ = server1.stop(Duration::from_secs(5));
    let mut after_failover = 0u64;
    while let Ok(_buf) = rx.recv_timeout(Duration::from_secs(60)) {
        n += 1;
        after_failover += 1;
    }
    let outcome = client.wait_eos(Duration::from_secs(60));
    println!("client outcome: {outcome:?}");
    println!("total responses: {n} (of 60 sent), {after_failover} served after failover");
    if let Some(s) = edgepipe::metrics::global().summary("query.tensor_query_client4.rtt_us") {
        println!("query RTT: mean {:.2} ms, p95 {:.2} ms", s.mean / 1000.0, s.p95 / 1000.0);
    }
    let _ = server2.stop(Duration::from_secs(5));
    assert!(after_failover > 0, "failover did not happen");
    println!("offload_query OK");
    Ok(())
}
