//! Figure 3 / Listing 2: the distributed IoT AI application.
//!
//! Four "devices" (pipelines) over one MQTT broker:
//!   C1, C2 — camera devices publishing flexbuf-serialized frames
//!   P      — processing device: subscribes C1, runs the detector
//!            (PJRT), publishes inference results
//!   D      — output device: subscribes C1 + C2 + P's results, muxes and
//!            composites them (timestamp-synchronized merge)
//!
//! Reports the E3 metric: the inter-stream timestamp delta at the mux.
//!
//! Run: `make artifacts && cargo run --release --example pubsub_iot`

use std::time::Duration;

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::metrics;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;

fn start(desc: &str, registry: &Registry, env: &PipelineEnv) -> edgepipe::pipeline::Running {
    parser::parse(desc, registry, env).expect("parse").start().expect("start")
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let have_model =
        std::path::Path::new(&env.artifacts_dir).join("detect.manifest.txt").exists();
    let broker = Broker::start("127.0.0.1:0")?;
    let b = broker.addr().to_string();
    println!("broker on {b}");

    // Device D (output): mux two camera streams + composite side-by-side.
    let output = start(
        &format!(
            "mqttsrc sub-topic=camleft broker={b} ! tensor_converter ! queue ! mux.sink_0 \
             mqttsrc sub-topic=camright broker={b} ! tensor_converter ! queue ! mux.sink_1 \
             tensor_mux name=mux ! tensor_demux name=dmux srcs=2 \
             dmux.src_0 ! tensor_decoder mode=direct_video ! queue ! mix.sink_0 \
             dmux.src_1 ! tensor_decoder mode=direct_video ! queue ! mix.sink_1 \
             compositor name=mix sink_0::xpos=0 sink_1::xpos=160 ! videoconvert ! appsink name=display"
        ),
        &registry,
        &env,
    );

    // Device P (processing): camera feed -> detector -> publish results.
    let processing = if have_model {
        Some(start(
            &format!(
                "mqttsrc sub-topic=camleft broker={b} ! tensor_converter ! queue leaky=2 max-size-buffers=2 ! \
                 tensor_transform mode=arithmetic option=typecast:float32,div:255.0 ! \
                 tensor_filter framework=pjrt model=detect ! \
                 tensor_decoder mode=flexbuf ! mqttsink pub-topic=edge/inference broker={b}"
            ),
            &registry,
            &env,
        ))
    } else {
        eprintln!("(artifacts missing: skipping the inference device)");
        None
    };

    // A monitor for P's published inferences.
    let monitor = start(
        &format!("mqttsrc sub-topic=edge/inference broker={b} ! tensor_converter ! appsink name=infs"),
        &registry,
        &env,
    );
    std::thread::sleep(Duration::from_millis(400));

    // Camera devices C1 and C2 (left camera must match the detect model's
    // 96x96 input so P can run it directly).
    let secs = 5u64;
    let nbuf = secs * 20;
    let cam1 = start(
        &format!(
            "videotestsrc width=96 height=96 framerate=20 pattern=ball num-buffers={nbuf} ! \
             tensor_converter ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=camleft broker={b}"
        ),
        &registry,
        &env,
    );
    // C2 with injected latency (the §4.2.3 experiment): a large queue in
    // front of the sink delays frames without dropping them.
    let cam2 = start(
        &format!(
            "videotestsrc width=96 height=96 framerate=20 pattern=smpte num-buffers={nbuf} ! \
             queue2 max-size-buffers=128 ! tensor_converter ! tensor_decoder mode=flexbuf ! \
             mqttsink pub-topic=camright broker={b}"
        ),
        &registry,
        &env,
    );
    println!("running {secs}s of 20 fps dual-camera pub/sub...");
    let _ = cam1.wait_eos(Duration::from_secs(secs + 30));
    let _ = cam2.wait_eos(Duration::from_secs(secs + 30));
    std::thread::sleep(Duration::from_millis(800));

    let displayed = metrics::global().counter("appsink.display").count();
    let inferences = metrics::global().counter("appsink.infs").count();
    println!("composited frames at device D: {displayed}");
    println!("inference results published by device P: {inferences}");
    if let Some(s) = metrics::global().summary("mux.mux.delta_ms") {
        println!(
            "mux timestamp delta (E3): mean {:.2} ms, p95 {:.2} ms, max {:.2} ms over {} merges",
            s.mean, s.p95, s.max, s.count
        );
    }
    let st = broker.stats();
    println!(
        "broker: {} msgs in, {} delivered, {} dropped (slow subscribers)",
        st.published, st.delivered, st.dropped_slow
    );
    let _ = output.stop(Duration::from_secs(5));
    let _ = monitor.stop(Duration::from_secs(5));
    if let Some(p) = processing {
        let _ = p.stop(Duration::from_secs(5));
    }
    assert!(displayed > 0, "no frames composited");
    println!("pubsub_iot OK");
    Ok(())
}
