//! Quickstart: an on-device AI pipeline in one description string.
//!
//! Synthetic camera → preprocess → SSD-lite detector (AOT HLO via PJRT)
//! → bounding-box renderer → sink, while a second tee branch passes the
//! raw video through — the Listing 1 topology minus the network.
//!
//! Run:  `make artifacts && cargo run --release --example quickstart`

use std::time::{Duration, Instant};

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::appsink_channel;
use edgepipe::pipeline::parser;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let env = PipelineEnv::default();
    if !std::path::Path::new(&env.artifacts_dir).join("detector.manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // The whole application is this description (cf. paper §5.1).
    let desc = "\
        videotestsrc width=640 height=480 framerate=30 pattern=ball num-buffers=60 ! tee name=ts \
        ts. ! queue leaky=2 ! videoconvert ! videoscale width=300 height=300 ! \
             video/x-raw,width=300,height=300,format=RGB ! \
             tensor_converter ! \
             tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
             tensor_filter framework=pjrt model=detector ! \
             tensor_decoder mode=bounding_boxes option4=640:480 ! \
             appsink channel=boxes \
        ts. ! queue leaky=2 ! videoconvert ! fakesink";

    let registry = Registry::with_builtins();
    let pipeline = parser::parse(desc, &registry, &env)?;
    let rx = appsink_channel("boxes").expect("appsink channel");
    println!("quickstart: running detector pipeline (300x300 SSD-lite on PJRT CPU)...");
    let t0 = Instant::now();
    let running = pipeline.start()?;

    let mut frames = 0u64;
    let mut first_latency = None;
    while let Ok(buf) = rx.recv_timeout(Duration::from_secs(120)) {
        frames += 1;
        if first_latency.is_none() {
            first_latency = Some(t0.elapsed());
        }
        if frames % 10 == 0 {
            println!("  rendered frame {frames}: {} bytes, pts {:?}", buf.len(), buf.pts);
        }
    }
    let elapsed = t0.elapsed();
    let outcome = running.wait_eos(Duration::from_secs(30));
    println!("outcome: {outcome:?}");
    println!(
        "frames: {frames} in {:.1}s -> {:.2} fps (first frame after {:?})",
        elapsed.as_secs_f64(),
        frames as f64 / elapsed.as_secs_f64(),
        first_latency.unwrap_or_default()
    );
    if let Some(s) = edgepipe::metrics::global().summary("filter.tensor_filter6.latency_us") {
        println!(
            "inference latency: mean {:.1} ms, p95 {:.1} ms",
            s.mean / 1000.0,
            s.p95 / 1000.0
        );
    }
    Ok(())
}
