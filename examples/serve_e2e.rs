//! END-TO-END SERVING DRIVER (the EXPERIMENTS.md headline run).
//!
//! Full among-device serving stack in one process, every hop over real
//! sockets: MQTT broker → hybrid-advertised query servers running AOT
//! HLO models on PJRT → N client pipelines streaming camera frames and
//! collecting responses. Reports per-request latency percentiles,
//! aggregate throughput, CPU usage and peak RSS.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e -- \
//!        [--model detect|detector] [--clients 4] [--servers 2] [--secs 10] [--fps 30]`

use std::time::Duration;

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::metrics::{self, CpuSampler};
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;
use edgepipe::util::args::Args;

fn start(desc: &str, registry: &Registry, env: &PipelineEnv) -> edgepipe::pipeline::Running {
    parser::parse(desc, registry, env).expect("parse").start().expect("start")
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "detect");
    let n_clients = args.get_u64("clients", 4) as usize;
    let n_servers = args.get_u64("servers", 2) as usize;
    let secs = args.get_u64("secs", 10);
    let fps = args.get_u64("fps", 30);
    let (side, div) = match model {
        "detect" => (96, "255.0"),
        "detector" => (300, "127.5"),
        other => {
            eprintln!("unknown model `{other}` (use detect|detector)");
            std::process::exit(2);
        }
    };

    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    if !std::path::Path::new(&env.artifacts_dir).join(format!("{model}.manifest.txt")).exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let broker = Broker::start("127.0.0.1:0")?;
    let b = broker.addr().to_string();
    println!("serve_e2e: model={model} servers={n_servers} clients={n_clients} {fps} fps x {secs}s");
    println!("broker on {b}");

    // Servers: advertise `serving/<model>` via MQTT-hybrid.
    let mut servers = Vec::new();
    for i in 0..n_servers {
        let desc = format!(
            "tensor_query_serversrc operation=serving/{model} port=0 pair-id=e2e-srv{i} \
               protocol=mqtt-hybrid broker={b} server-id=e2e-srv{i} model-label={model} ! \
             tensor_filter framework=pjrt model={model} ! \
             tensor_query_serversink operation=serving/{model} pair-id=e2e-srv{i}"
        );
        servers.push(start(&desc, &registry, &env));
    }
    std::thread::sleep(Duration::from_millis(600));

    // Clients: live camera at the requested rate, leaky preprocessing
    // (drop frames rather than queue them — live serving semantics).
    let nbuf = secs * fps;
    metrics::global().reset();
    let mut cpu = CpuSampler::start();
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for i in 0..n_clients {
        let desc = format!(
            "videotestsrc width={side} height={side} framerate={fps} pattern=ball num-buffers={nbuf} ! \
             videoconvert ! tensor_converter ! queue leaky=2 max-size-buffers=2 ! \
             tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:{div} ! \
             tensor_query_client name=qc{i} operation=serving/# protocol=mqtt-hybrid broker={b} timeout-ms=10000 ! \
             appsink name=client{i}"
        );
        clients.push(start(&desc, &registry, &env));
    }
    for c in clients {
        let out = c.wait_eos(Duration::from_secs(secs + 300));
        if !matches!(out, edgepipe::pipeline::WaitOutcome::Eos) {
            eprintln!("client outcome: {out:?}");
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let cpu_pct = cpu.sample();

    // Aggregate results.
    let mut total = 0u64;
    for i in 0..n_clients {
        total += metrics::global().counter(&format!("appsink.client{i}")).count();
    }
    println!("\n=== serve_e2e results ===");
    println!("requests served:   {total} / {} offered", nbuf * n_clients as u64);
    println!("throughput:        {:.1} req/s aggregate ({:.1} per client)", total as f64 / elapsed, total as f64 / elapsed / n_clients as f64);
    let mut rtts: Vec<edgepipe::metrics::Summary> = Vec::new();
    for i in 0..n_clients {
        if let Some(s) = metrics::global().summary(&format!("query.qc{i}.rtt_us")) {
            rtts.push(s);
        }
    }
    if !rtts.is_empty() {
        let mean = rtts.iter().map(|s| s.mean).sum::<f64>() / rtts.len() as f64;
        let p95 = rtts.iter().map(|s| s.p95).fold(0.0, f64::max);
        let max = rtts.iter().map(|s| s.max).fold(0.0, f64::max);
        println!("query RTT:         mean {:.2} ms, worst-client p95 {:.2} ms, max {:.2} ms", mean / 1000.0, p95 / 1000.0, max / 1000.0);
    }
    println!("process CPU:       {cpu_pct:.0}% of one core");
    if let Some(rss) = metrics::peak_rss_kb() {
        println!("peak RSS:          {:.1} MiB", rss as f64 / 1024.0);
    }
    let st = broker.stats();
    println!("broker control:    {} msgs (data path bypasses broker: MQTT-hybrid)", st.published);
    for s in servers {
        let _ = s.stop(Duration::from_secs(5));
    }
    assert!(total > 0, "no requests served");
    println!("serve_e2e OK");
    Ok(())
}
