"""AOT export: lower L2 models to HLO *text* + weight blobs + manifests.

Interchange format (per /opt/xla-example gotchas): HLO text, NOT a
serialized HloModuleProto — jax>=0.5 emits 64-bit instruction ids which
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly.

Per model ``<name>`` we emit into ``artifacts/``:

- ``<name>.hlo.txt``       HLO text of ``fn(x, *flat_params)`` lowered with
                           return_tuple=True (Rust unwraps the tuple).
- ``<name>.weights.bin``   all flat params, little-endian f32, concatenated
                           in manifest order.
- ``<name>.manifest.txt``  line-oriented manifest the Rust runtime parses:
                               model <name>
                               input <name> f32 d0,d1,...
                               output <name> f32 d0,d1,...
                               param <name> f32 d0,d1,... <byte_off> <nbytes>

Usage: ``python -m compile.aot --out ../artifacts [--models a,b,...]``
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(name: str, outdir: str) -> dict:
    closed, bank = M.build(name)
    spec = M.MODELS[name]
    in_shape = spec["input_shape"]

    arg_specs = [jax.ShapeDtypeStruct(in_shape, jnp.float32)]
    arg_specs += [jax.ShapeDtypeStruct(v.shape, jnp.float32)
                  for v in bank.values]
    lowered = jax.jit(closed).lower(*arg_specs)
    hlo = to_hlo_text(lowered)

    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    weights_path = os.path.join(outdir, f"{name}.weights.bin")
    offsets = []
    off = 0
    with open(weights_path, "wb") as f:
        for v in bank.values:
            raw = np.ascontiguousarray(v, np.float32).tobytes()
            f.write(raw)
            offsets.append((off, len(raw)))
            off += len(raw)

    manifest_path = os.path.join(outdir, f"{name}.manifest.txt")
    with open(manifest_path, "w") as f:
        f.write(f"model {name}\n")
        dims = ",".join(str(d) for d in in_shape)
        f.write(f"input x f32 {dims}\n")
        for oname, oshape in spec["outputs"]:
            dims = ",".join(str(d) for d in oshape)
            f.write(f"output {oname} f32 {dims}\n")
        for pname, v, (boff, blen) in zip(bank.names, bank.values, offsets):
            dims = ",".join(str(d) for d in v.shape)
            f.write(f"param {pname} f32 {dims} {boff} {blen}\n")
    return dict(hlo_chars=len(hlo), weight_bytes=off,
                n_params=len(bank.values))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(M.MODELS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        info = export_model(name, args.out)
        print(f"[aot] {name}: hlo={info['hlo_chars']} chars, "
              f"weights={info['weight_bytes']} B "
              f"({info['n_params']} tensors)", file=sys.stderr)


if __name__ == "__main__":
    main()
