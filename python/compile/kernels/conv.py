"""L1 convolution kernels built on the Pallas matmul tile.

Two kernels cover the models' conv menu:

- ``conv2d`` — dense KxK conv as im2col + Pallas matmul (MXU path).  The
  im2col gather is expressed with ``lax.conv_general_dilated_patches`` so
  XLA fuses the patch extraction; the FLOPs all land in the Pallas tile.
- ``depthwise_conv3x3`` — a dedicated Pallas kernel on the VPU mental
  model: grid over channel blocks, each step holds an (H+2, W+2, bc) input
  slab in VMEM and computes the output as nine shifted multiply-adds.

Both are NHWC with batch folded into rows, f32, SAME or VALID padding,
stride 1 or 2 — exactly what the SSD-lite / pose / detect models need.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .matmul import matmul


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1,
           padding: str = "SAME", act: str = "relu6") -> jax.Array:
    """Dense conv: x (N,H,W,Cin), w (KH,KW,Cin,Cout), b (Cout,) -> NHWC.

    im2col + Pallas matmul; the matmul is the only FLOP-carrying op.
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, f"conv cin mismatch {x.shape} {w.shape}"
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # patches: (N, Ho, Wo, Cin*KH*KW) with feature order (cin, kh, kw)
    _, ho, wo, patch_dim = patches.shape
    cols = patches.reshape(n * ho * wo, patch_dim)
    # conv_general_dilated_patches emits features as (Cin, KH, KW); reorder
    # the weight to match instead of transposing the (large) patch matrix.
    wmat = w.transpose(2, 0, 1, 3).reshape(patch_dim, cout)
    out = matmul(cols, wmat) + b
    if act == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return out.reshape(n, ho, wo, cout)


def pointwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                   act: str = "relu6") -> jax.Array:
    """1x1 conv = row-major reshape + Pallas matmul (no im2col needed)."""
    n, h, wdt, cin = x.shape
    cout = w.shape[-1]
    out = matmul(x.reshape(n * h * wdt, cin), w.reshape(cin, cout)) + b
    if act == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    return out.reshape(n, h, wdt, cout)


def _dw_kernel(x_ref, w_ref, o_ref, *, stride: int, ho: int, wo: int):
    """Depthwise 3x3 tile: nine shifted MACs over a VMEM channel slab."""
    x = x_ref[...]            # (hp, wp, bc) padded input slab
    w = w_ref[...]            # (3, 3, bc)
    acc = jnp.zeros((ho, wo, x.shape[-1]), jnp.float32)
    for di in range(3):
        for dj in range(3):
            sl = lax.slice(
                x,
                (di, dj, 0),
                (di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1,
                 x.shape[-1]),
                (stride, stride, 1),
            )
            acc += sl * w[di, dj, :]
    o_ref[...] = acc


def depthwise_conv3x3(x: jax.Array, w: jax.Array, b: jax.Array, *,
                      stride: int = 1, act: str = "relu6",
                      bc: int = 32) -> jax.Array:
    """Depthwise 3x3 conv, SAME padding: x (1,H,W,C), w (3,3,C), b (C,).

    Pallas grid over channel blocks; H and W stay whole inside a block
    (the models' largest slab, 152x152x32 f32, is ~3 MiB — VMEM-sized).
    """
    n, h, wdt, c = x.shape
    assert n == 1, "depthwise kernel is written for batch-major loops"
    assert w.shape == (3, 3, c), f"depthwise weight {w.shape} vs C={c}"
    ho = (h + stride - 1) // stride
    wo = (wdt + stride - 1) // stride
    # SAME padding for kernel 3: pad_total = (ho-1)*stride + 3 - h
    pad_h = max((ho - 1) * stride + 3 - h, 0)
    pad_w = max((wo - 1) * stride + 3 - wdt, 0)
    xp = jnp.pad(x[0], ((pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    bc = min(bc, c)
    cp = (c + bc - 1) // bc * bc
    xp = jnp.pad(xp, ((0, 0), (0, 0), (0, cp - c)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c)))
    hp, wp_dim, _ = xp.shape

    out = pl.pallas_call(
        functools.partial(_dw_kernel, stride=stride, ho=ho, wo=wo),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((hp, wp_dim, bc), lambda i: (0, 0, i)),
            pl.BlockSpec((3, 3, bc), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((ho, wo, bc), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, cp), jnp.float32),
        interpret=True,
    )(xp, wp)
    out = out[:, :, :c] + b
    if act == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    return out[None, ...]
