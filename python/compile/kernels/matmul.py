"""L1 Pallas matmul kernel — the MXU-shaped compute hot-spot.

Every convolution in the L2 models lowers to this kernel (1x1 convs are
reshapes; 3x3 convs go through im2col).  The kernel is written for the TPU
mental model the paper's accelerators (EdgeTPU/NPU) imply:

- grid = (M/bm, N/bn, K/bk); the K axis is the innermost ("arbitrary")
  loop so the output block held in VMEM is revision-accumulated across K
  steps — the classic MXU systolic schedule.
- block shapes default to 128x128, the MXU tile; edge tiles are handled by
  padding in the wrapper (Pallas BlockSpecs require divisible grids).
- ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls, and interpret mode lowers the kernel to plain HLO so the
  AOT artifact runs on the Rust PJRT CPU client (see DESIGN.md
  §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nsteps: int):
    """Accumulating matmul tile: o[i,j] += x[i,k] @ y[k,j] over grid axis 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jax.Array:
    """``x @ y`` via the Pallas tile kernel.

    ``x``: (M, K) f32, ``y``: (K, N) f32 -> (M, N) f32.  Inputs are padded
    to block multiples (the pad is free at trace time and XLA folds the
    slices); block sizes are clamped to the padded problem so small
    problems use a single tile.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {y.shape}"

    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))

    nsteps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps=nsteps),
        grid=(mp // bm, np_ // bn, nsteps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def matmul_bias_act(x: jax.Array, y: jax.Array, b: jax.Array,
                    act: str = "relu6") -> jax.Array:
    """Fused matmul + bias + activation used by every conv in the models."""
    out = matmul(x, y) + b
    if act == "relu6":
        return jnp.clip(out, 0.0, 6.0)
    if act == "relu":
        return jnp.maximum(out, 0.0)
    if act == "none":
        return out
    raise ValueError(f"unknown activation {act!r}")
