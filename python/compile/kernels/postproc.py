"""L1 SSD detection post-processing: Pallas box decode + jnp top-k.

The box decode (anchor + delta -> corner boxes, score sigmoid) is a pure
elementwise kernel — the VPU path — expressed as a single-block Pallas
call.  The top-k selection stays in jnp (``lax.top_k`` lowers to an HLO
sort, which the CPU PJRT client runs natively).

Output layout mirrors the paper's Listing 2 decoder caps:
  boxes  f32 (K, 4)   -- x0, y0, x1, y1 in [0, 1]
  cls    f32 (K,)     -- class index (float for tensor-stream uniformity)
  score  f32 (K,)     -- sigmoid class confidence
  count  f32 (1,)     -- number of detections above threshold
i.e. ``other/tensors,num_tensors=4,dimensions=4:K:1:1,K:1:1:1,...``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# SSD box-coder variances (standard TF object-detection values).
VAR_CENTER = 0.1
VAR_SIZE = 0.2


def _decode_kernel(loc_ref, anchor_ref, box_ref):
    loc = loc_ref[...]          # (A, 4): ty, tx, th, tw
    anc = anchor_ref[...]       # (A, 4): cy, cx, h, w
    cy = loc[:, 0] * VAR_CENTER * anc[:, 2] + anc[:, 0]
    cx = loc[:, 1] * VAR_CENTER * anc[:, 3] + anc[:, 1]
    h = jnp.exp(loc[:, 2] * VAR_SIZE) * anc[:, 2]
    w = jnp.exp(loc[:, 3] * VAR_SIZE) * anc[:, 3]
    box_ref[...] = jnp.stack(
        [cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2], axis=-1)


def decode_boxes(loc: jax.Array, anchors: jax.Array) -> jax.Array:
    """Decode (A,4) location deltas against (A,4) center-size anchors."""
    a = loc.shape[0]
    return pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct((a, 4), jnp.float32),
        interpret=True,
    )(loc, anchors)


def select_topk(boxes: jax.Array, logits: jax.Array, *, k: int = 20,
                threshold: float = 0.5):
    """Top-k detections by best non-background class score.

    boxes (A,4), logits (A,C) with class 0 = background.
    Returns (boxes (k,4), cls (k,), score (k,), count (1,)).
    """
    probs = jax.nn.sigmoid(logits[:, 1:])           # (A, C-1)
    best = jnp.max(probs, axis=-1)                  # (A,)
    cls = jnp.argmax(probs, axis=-1).astype(jnp.float32) + 1.0
    # argsort-based top-k: lowers to a plain HLO `sort`, which the
    # xla_extension 0.5.1 text parser accepts (`topk` from lax.top_k is a
    # newer op its parser rejects — see DESIGN.md).
    idx = jnp.argsort(-best)[:k]
    score = best[idx]
    out_boxes = jnp.clip(boxes[idx], 0.0, 1.0)
    out_cls = cls[idx]
    count = jnp.sum((score > threshold).astype(jnp.float32),
                    keepdims=True)
    return out_boxes, out_cls, score, count
