"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here computes the same mathematical result as its Pallas
counterpart using only stock jax/lax ops; pytest asserts allclose between
the two across shape/dtype sweeps (python/tests/test_kernels.py).
"""

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def conv2d_ref(x, w, b, *, stride=1, padding="SAME", act="relu6"):
    out = lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b
    return _act(out, act)


def depthwise_conv3x3_ref(x, w, b, *, stride=1, act="relu6"):
    c = x.shape[-1]
    # HWIO with feature_group_count=C: (3, 3, 1, C)
    wf = w.reshape(3, 3, 1, c)
    out = lax.conv_general_dilated(
        x, wf,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    ) + b
    return _act(out, act)


def decode_boxes_ref(loc, anchors, *, var_center=0.1, var_size=0.2):
    cy = loc[:, 0] * var_center * anchors[:, 2] + anchors[:, 0]
    cx = loc[:, 1] * var_center * anchors[:, 3] + anchors[:, 1]
    h = jnp.exp(loc[:, 2] * var_size) * anchors[:, 2]
    w = jnp.exp(loc[:, 3] * var_size) * anchors[:, 3]
    return jnp.stack([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2],
                     axis=-1)


def _act(out, act):
    if act == "relu6":
        return jnp.clip(out, 0.0, 6.0)
    if act == "relu":
        return jnp.maximum(out, 0.0)
    if act == "none":
        return out
    raise ValueError(act)
