"""L2 models — the neural networks the among-device pipelines serve.

Three models cover the paper's example applications (Listings 1/2, Fig 5):

- ``detector``  — SSD-lite object detector, 300x300x3 RGB in [-1,1]
                  (the ``ssd_mobilenet_v2`` analog from Listing 1), output
                  = the Listing 2 decoder caps: boxes(K,4), cls(K),
                  score(K), count(1) with K=20.
- ``posenet``   — single-person pose estimation, 192x192x3 -> 17 keypoints
                  (x, y, score) — the "AI exercise trainer" workload.
- ``detect``    — tiny binary activation model, 96x96x3 -> 1 score — the
                  Fig 5 "DETECT" gate on the mobile device.
- ``imucls``    — multi-modal worker-action classifier, (128,9) IMU window
                  -> 2 classes — the Fig 5 wearable-stream consumer.

All convs run through the L1 Pallas kernels (kernels/matmul.py,
kernels/conv.py); weights are seeded-random (no pretrained checkpoints
offline — see DESIGN.md substitutions), passed as runtime *arguments* so
the HLO text stays small and Rust feeds them from ``<model>.weights.bin``.

Every model is ``fn(x, *flat_params) -> tuple(outputs)``; ``aot.py``
flattens the param pytree in a deterministic order recorded in the
manifest.
"""

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.conv import conv2d, depthwise_conv3x3, pointwise_conv
from .kernels.matmul import matmul
from .kernels import postproc

# ---------------------------------------------------------------------------
# Parameter construction (seeded, deterministic order)
# ---------------------------------------------------------------------------


class ParamBank:
    """Ordered, named parameter store with He-normal seeded init."""

    def __init__(self, seed: int):
        self.key = jax.random.PRNGKey(seed)
        self.names: List[str] = []
        self.values: List[np.ndarray] = []

    def add(self, name: str, shape, fan_in: int | None = None,
            zeros: bool = False) -> None:
        if zeros:
            v = np.zeros(shape, np.float32)
        else:
            self.key, sub = jax.random.split(self.key)
            fan = fan_in if fan_in is not None else int(np.prod(shape[:-1]))
            std = math.sqrt(2.0 / max(fan, 1))
            v = np.asarray(jax.random.normal(sub, shape, jnp.float32)) * std
        self.names.append(name)
        self.values.append(v.astype(np.float32))

    def add_const(self, name: str, value: np.ndarray) -> None:
        self.names.append(name)
        self.values.append(np.asarray(value, np.float32))


def _conv_params(bank: ParamBank, name: str, kh, kw, cin, cout):
    bank.add(f"{name}.w", (kh, kw, cin, cout))
    bank.add(f"{name}.b", (cout,), zeros=True)


def _dw_params(bank: ParamBank, name: str, c):
    bank.add(f"{name}.w", (3, 3, c))
    bank.add(f"{name}.b", (c,), zeros=True)


# ---------------------------------------------------------------------------
# SSD-lite detector
# ---------------------------------------------------------------------------

DET_INPUT = (1, 300, 300, 3)
DET_K = 20            # top-k detections (paper's decoder shows 20)
DET_CLASSES = 21      # background + 20 (COCO-lite label set)
DET_ANCHORS_PER_CELL = 6
# backbone: stem s2 -> ds(32,s2) -> ds(64,s2) -> ds(128,s2) -> ds(128,s1)
# 300 -> 150 -> 75 -> 38 -> 19 -> 19 feature grid
DET_GRID = 19


def make_anchors(grid: int = DET_GRID,
                 n_per_cell: int = DET_ANCHORS_PER_CELL) -> np.ndarray:
    """Center-size anchors (cy, cx, h, w) over a grid, SSD-style scales."""
    scales = [0.2, 0.35, 0.5]
    ratios = [1.0, 2.0]
    boxes = []
    for gy in range(grid):
        for gx in range(grid):
            cy = (gy + 0.5) / grid
            cx = (gx + 0.5) / grid
            for s in scales:
                for r in ratios:
                    boxes.append([cy, cx, s / math.sqrt(r),
                                  s * math.sqrt(r)])
    anchors = np.asarray(boxes, np.float32)
    assert anchors.shape == (grid * grid * n_per_cell, 4)
    return anchors


def detector_params(seed: int = 42) -> ParamBank:
    bank = ParamBank(seed)
    _conv_params(bank, "stem", 3, 3, 3, 16)
    for i, (cin, cout) in enumerate([(16, 32), (32, 64), (64, 128),
                                     (128, 128)]):
        _dw_params(bank, f"ds{i}.dw", cin)
        _conv_params(bank, f"ds{i}.pw", 1, 1, cin, cout)
    n_out = DET_ANCHORS_PER_CELL * (4 + DET_CLASSES)
    _conv_params(bank, "head", 3, 3, 128, n_out)
    bank.add_const("anchors", make_anchors())
    return bank


def detector_fn(x: jax.Array, params: Dict[str, jax.Array]):
    """x: (1,300,300,3) f32 in [-1,1] -> (boxes, cls, score, count)."""
    h = conv2d(x, params["stem.w"], params["stem.b"], stride=2)
    strides = [2, 2, 2, 1]
    for i, s in enumerate(strides):
        h = depthwise_conv3x3(h, params[f"ds{i}.dw.w"],
                              params[f"ds{i}.dw.b"], stride=s)
        h = pointwise_conv(h, params[f"ds{i}.pw.w"], params[f"ds{i}.pw.b"])
    raw = conv2d(h, params["head.w"], params["head.b"], stride=1,
                 act="none")                      # (1, 19, 19, A*(4+C))
    a = DET_ANCHORS_PER_CELL
    raw = raw.reshape(DET_GRID * DET_GRID * a, 4 + DET_CLASSES)
    loc, logits = raw[:, :4], raw[:, 4:]
    boxes = postproc.decode_boxes(loc, params["anchors"])
    return postproc.select_topk(boxes, logits, k=DET_K)


# ---------------------------------------------------------------------------
# Pose estimation (heatmap argmax)
# ---------------------------------------------------------------------------

POSE_INPUT = (1, 192, 192, 3)
POSE_KP = 17
POSE_HM = 24        # 192 -> 96 -> 48 -> 24 heatmap grid


def posenet_params(seed: int = 43) -> ParamBank:
    bank = ParamBank(seed)
    _conv_params(bank, "stem", 3, 3, 3, 16)
    for i, (cin, cout) in enumerate([(16, 32), (32, 64)]):
        _dw_params(bank, f"ds{i}.dw", cin)
        _conv_params(bank, f"ds{i}.pw", 1, 1, cin, cout)
    _conv_params(bank, "hm", 3, 3, 64, POSE_KP)
    return bank


def posenet_fn(x: jax.Array, params: Dict[str, jax.Array]):
    """x: (1,192,192,3) -> keypoints (17,3) as (x, y, score) in [0,1]."""
    h = conv2d(x, params["stem.w"], params["stem.b"], stride=2)
    for i in range(2):
        h = depthwise_conv3x3(h, params[f"ds{i}.dw.w"],
                              params[f"ds{i}.dw.b"], stride=2)
        h = pointwise_conv(h, params[f"ds{i}.pw.w"], params[f"ds{i}.pw.b"])
    hm = conv2d(h, params["hm.w"], params["hm.b"], stride=1, act="none")
    hm = hm.reshape(POSE_HM * POSE_HM, POSE_KP)      # (HW, KP)
    score = jax.nn.sigmoid(jnp.max(hm, axis=0))      # (KP,)
    idx = jnp.argmax(hm, axis=0)                     # (KP,)
    y = (idx // POSE_HM).astype(jnp.float32) / (POSE_HM - 1)
    xx = (idx % POSE_HM).astype(jnp.float32) / (POSE_HM - 1)
    return (jnp.stack([xx, y, score], axis=-1),)


# ---------------------------------------------------------------------------
# DETECT activation gate (Fig 5)
# ---------------------------------------------------------------------------

DETECT_INPUT = (1, 96, 96, 3)


def detect_params(seed: int = 44) -> ParamBank:
    bank = ParamBank(seed)
    _conv_params(bank, "c0", 3, 3, 3, 8)
    _conv_params(bank, "c1", 3, 3, 8, 16)
    bank.add("fc.w", (16, 1))
    bank.add("fc.b", (1,), zeros=True)
    return bank


def detect_fn(x: jax.Array, params: Dict[str, jax.Array]):
    """x: (1,96,96,3) -> activation score (1,) in (0,1)."""
    h = conv2d(x, params["c0.w"], params["c0.b"], stride=2)
    h = conv2d(h, params["c1.w"], params["c1.b"], stride=2)
    h = jnp.mean(h, axis=(1, 2))                     # (1, 16)
    out = matmul(h, params["fc.w"]) + params["fc.b"]
    return (jax.nn.sigmoid(out[0]),)


# ---------------------------------------------------------------------------
# IMU action classifier (Fig 5 wearable stream)
# ---------------------------------------------------------------------------

IMU_INPUT = (1, 128, 9)   # 128 samples x 9 IMU channels
IMU_CLASSES = 2           # correct / incorrect assembly


def imucls_params(seed: int = 45) -> ParamBank:
    bank = ParamBank(seed)
    bank.add("fc0.w", (128 * 9, 64))
    bank.add("fc0.b", (64,), zeros=True)
    bank.add("fc1.w", (64, IMU_CLASSES))
    bank.add("fc1.b", (IMU_CLASSES,), zeros=True)
    return bank


def imucls_fn(x: jax.Array, params: Dict[str, jax.Array]):
    """x: (1,128,9) -> class probabilities (2,)."""
    h = x.reshape(1, 128 * 9)
    h = jnp.maximum(matmul(h, params["fc0.w"]) + params["fc0.b"], 0.0)
    logits = matmul(h, params["fc1.w"]) + params["fc1.b"]
    return (jax.nn.softmax(logits[0]),)


# ---------------------------------------------------------------------------
# Registry consumed by aot.py
# ---------------------------------------------------------------------------

MODELS: Dict[str, dict] = {
    "detector": dict(fn=detector_fn, params=detector_params,
                     input_shape=DET_INPUT,
                     outputs=[("boxes", (DET_K, 4)), ("cls", (DET_K,)),
                              ("score", (DET_K,)), ("count", (1,))]),
    "posenet": dict(fn=posenet_fn, params=posenet_params,
                    input_shape=POSE_INPUT,
                    outputs=[("keypoints", (POSE_KP, 3))]),
    "detect": dict(fn=detect_fn, params=detect_params,
                   input_shape=DETECT_INPUT,
                   outputs=[("activation", (1,))]),
    "imucls": dict(fn=imucls_fn, params=imucls_params,
                   input_shape=IMU_INPUT,
                   outputs=[("probs", (IMU_CLASSES,))]),
}


def build(name: str) -> Tuple[callable, ParamBank]:
    """Return (closed_fn(x, *flat), bank) for a registry model."""
    spec = MODELS[name]
    bank: ParamBank = spec["params"]()
    names = list(bank.names)
    fn = spec["fn"]

    def closed(x, *flat):
        params = dict(zip(names, flat))
        return tuple(fn(x, params))

    return closed, bank
