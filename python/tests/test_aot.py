"""AOT artifact contract: HLO text + manifest + weights stay in sync.

Exports the smallest model (detect) into a tmpdir and checks everything
the Rust runtime relies on.  The full `make artifacts` run covers all
models; this test keeps the contract under pytest.
"""

import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    info = aot.export_model("detect", str(out))
    return out, info


class TestArtifacts:
    def test_files_exist(self, exported):
        out, _ = exported
        for suffix in ("hlo.txt", "weights.bin", "manifest.txt"):
            assert (out / f"detect.{suffix}").exists()

    def test_hlo_text_is_parseable_module(self, exported):
        out, _ = exported
        text = (out / "detect.hlo.txt").read_text()
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text
        # 64-bit-id proto issue is avoided by text interchange; the text
        # itself must not be empty or truncated.
        assert text.rstrip().endswith("}")

    def test_manifest_matches_weights_size(self, exported):
        out, _ = exported
        lines = (out / "detect.manifest.txt").read_text().splitlines()
        assert lines[0] == "model detect"
        params = [l.split() for l in lines if l.startswith("param ")]
        total = sum(int(p[-1]) for p in params)
        assert total == (out / "detect.weights.bin").stat().st_size

    def test_manifest_offsets_contiguous(self, exported):
        out, _ = exported
        lines = (out / "detect.manifest.txt").read_text().splitlines()
        off = 0
        for l in lines:
            if not l.startswith("param "):
                continue
            _, _, _, dims, boff, blen = l.split()
            assert int(boff) == off
            n = int(np.prod([int(d) for d in dims.split(",")]))
            assert int(blen) == n * 4
            off += int(blen)

    def test_manifest_declares_io(self, exported):
        out, _ = exported
        text = (out / "detect.manifest.txt").read_text()
        assert "input x f32 1,96,96,3" in text
        assert "output activation f32 1" in text

    def test_param_order_matches_bank(self, exported):
        out, _ = exported
        _, bank = M.build("detect")
        lines = [l.split()[1] for l in
                 (out / "detect.manifest.txt").read_text().splitlines()
                 if l.startswith("param ")]
        assert lines == bank.names

    def test_weights_roundtrip(self, exported):
        out, _ = exported
        _, bank = M.build("detect")
        blob = (out / "detect.weights.bin").read_bytes()
        off = 0
        for v in bank.values:
            raw = np.frombuffer(blob, np.float32, count=v.size,
                                offset=off).reshape(v.shape)
            np.testing.assert_array_equal(raw, v)
            off += v.size * 4
