"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes/strides; every property asserts allclose against
kernels/ref.py.  interpret=True Pallas on CPU is deterministic, so tight
tolerances hold.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv import conv2d, depthwise_conv3x3, pointwise_conv
from compile.kernels.matmul import matmul, matmul_bias_act
from compile.kernels import postproc

RTOL, ATOL = 1e-4, 1e-4


def _rand(shape, seed, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(
        np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (1, 1, 1), (8, 8, 8), (128, 128, 128), (129, 127, 130),
        (37, 65, 19), (1, 256, 10), (300, 3, 7), (256, 150, 64),
    ])
    def test_matches_ref(self, m, k, n):
        x, y = _rand((m, k), m * 3 + k), _rand((k, n), n * 7 + k)
        np.testing.assert_allclose(matmul(jnp.array(x), jnp.array(y)),
                                   ref.matmul_ref(x, y),
                                   rtol=RTOL, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 80), k=st.integers(1, 80), n=st.integers(1, 80),
           seed=st.integers(0, 2**16))
    def test_matches_ref_hypothesis(self, m, k, n, seed):
        x, y = _rand((m, k), seed), _rand((k, n), seed + 1)
        np.testing.assert_allclose(matmul(jnp.array(x), jnp.array(y)),
                                   ref.matmul_ref(x, y),
                                   rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8),
                                          (128, 128, 128), (64, 8, 32)])
    def test_block_shapes_do_not_change_result(self, bm, bn, bk):
        x, y = _rand((50, 70), 1), _rand((70, 30), 2)
        out = matmul(jnp.array(x), jnp.array(y), bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, ref.matmul_ref(x, y),
                                    rtol=RTOL, atol=ATOL)

    def test_zero_inputs(self):
        x = np.zeros((12, 9), np.float32)
        y = np.zeros((9, 5), np.float32)
        assert np.all(np.asarray(matmul(jnp.array(x), jnp.array(y))) == 0)

    def test_identity(self):
        x = _rand((16, 16), 3)
        eye = np.eye(16, dtype=np.float32)
        np.testing.assert_allclose(matmul(jnp.array(x), jnp.array(eye)), x,
                                   rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("act", ["relu", "relu6", "none"])
    def test_bias_act(self, act):
        x, y = _rand((9, 11), 4), _rand((11, 6), 5)
        b = _rand((6,), 6)
        out = matmul_bias_act(jnp.array(x), jnp.array(y), jnp.array(b), act)
        want = ref._act(ref.matmul_ref(x, y) + b, act)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_bad_activation_raises(self):
        x, y, b = _rand((2, 2), 0), _rand((2, 2), 1), _rand((2,), 2)
        with pytest.raises(ValueError):
            matmul_bias_act(jnp.array(x), jnp.array(y), jnp.array(b), "gelu")

    def test_inner_dim_mismatch_raises(self):
        with pytest.raises(AssertionError):
            matmul(jnp.zeros((3, 4)), jnp.zeros((5, 6)))


# ---------------------------------------------------------------------------
# conv2d (im2col + Pallas matmul)
# ---------------------------------------------------------------------------


class TestConv2d:
    @pytest.mark.parametrize("h,w,cin,cout,stride,padding", [
        (8, 8, 3, 4, 1, "SAME"), (8, 8, 3, 4, 2, "SAME"),
        (19, 19, 16, 8, 1, "SAME"), (20, 20, 8, 12, 2, "SAME"),
        (9, 9, 4, 4, 1, "VALID"), (15, 11, 2, 6, 2, "VALID"),
        (5, 5, 1, 1, 1, "SAME"),
    ])
    def test_matches_ref(self, h, w, cin, cout, stride, padding):
        x = _rand((1, h, w, cin), h + w, 0.5)
        wt = _rand((3, 3, cin, cout), cin * cout, 0.2)
        b = _rand((cout,), cout, 0.1)
        out = conv2d(jnp.array(x), jnp.array(wt), jnp.array(b),
                     stride=stride, padding=padding)
        want = ref.conv2d_ref(x, wt, b, stride=stride, padding=padding)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    @settings(max_examples=15, deadline=None)
    @given(h=st.integers(4, 24), w=st.integers(4, 24),
           cin=st.integers(1, 8), cout=st.integers(1, 8),
           stride=st.sampled_from([1, 2]), seed=st.integers(0, 999))
    def test_matches_ref_hypothesis(self, h, w, cin, cout, stride, seed):
        x = _rand((1, h, w, cin), seed, 0.5)
        wt = _rand((3, 3, cin, cout), seed + 1, 0.2)
        b = _rand((cout,), seed + 2, 0.1)
        out = conv2d(jnp.array(x), jnp.array(wt), jnp.array(b),
                     stride=stride)
        want = ref.conv2d_ref(x, wt, b, stride=stride)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_kernel_5x5(self):
        x = _rand((1, 12, 12, 3), 10, 0.5)
        wt = _rand((5, 5, 3, 4), 11, 0.1)
        b = np.zeros((4,), np.float32)
        out = conv2d(jnp.array(x), jnp.array(wt), jnp.array(b))
        want = ref.conv2d_ref(x, wt, b)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_batch_gt_one(self):
        x = _rand((3, 10, 10, 2), 12, 0.5)
        wt = _rand((3, 3, 2, 5), 13, 0.2)
        b = _rand((5,), 14, 0.1)
        out = conv2d(jnp.array(x), jnp.array(wt), jnp.array(b))
        want = ref.conv2d_ref(x, wt, b)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_relu6_saturates(self):
        x = np.full((1, 4, 4, 1), 10.0, np.float32)
        wt = np.full((3, 3, 1, 1), 10.0, np.float32)
        b = np.zeros((1,), np.float32)
        out = np.asarray(conv2d(jnp.array(x), jnp.array(wt), jnp.array(b)))
        assert out.max() <= 6.0


class TestPointwiseConv:
    @pytest.mark.parametrize("h,w,cin,cout", [
        (19, 19, 16, 32), (1, 1, 4, 4), (38, 38, 8, 16)])
    def test_matches_dense_conv(self, h, w, cin, cout):
        x = _rand((1, h, w, cin), h * cin, 0.5)
        wt = _rand((1, 1, cin, cout), cout, 0.2)
        b = _rand((cout,), cout + 1, 0.1)
        out = pointwise_conv(jnp.array(x), jnp.array(wt), jnp.array(b))
        want = ref.conv2d_ref(x, wt, b, stride=1)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# depthwise conv
# ---------------------------------------------------------------------------


class TestDepthwise:
    @pytest.mark.parametrize("h,w,c,stride", [
        (8, 8, 4, 1), (8, 8, 4, 2), (19, 19, 32, 1), (20, 20, 16, 2),
        (7, 9, 3, 1), (150, 150, 16, 2), (5, 5, 1, 1),
    ])
    def test_matches_ref(self, h, w, c, stride):
        x = _rand((1, h, w, c), h * c, 0.5)
        wt = _rand((3, 3, c), c, 0.3)
        b = _rand((c,), c + 1, 0.1)
        out = depthwise_conv3x3(jnp.array(x), jnp.array(wt), jnp.array(b),
                                stride=stride)
        want = ref.depthwise_conv3x3_ref(x, wt, b, stride=stride)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    @settings(max_examples=15, deadline=None)
    @given(h=st.integers(3, 30), w=st.integers(3, 30), c=st.integers(1, 40),
           stride=st.sampled_from([1, 2]), seed=st.integers(0, 999))
    def test_matches_ref_hypothesis(self, h, w, c, stride, seed):
        x = _rand((1, h, w, c), seed, 0.5)
        wt = _rand((3, 3, c), seed + 1, 0.3)
        b = _rand((c,), seed + 2, 0.1)
        out = depthwise_conv3x3(jnp.array(x), jnp.array(wt), jnp.array(b),
                                stride=stride)
        want = ref.depthwise_conv3x3_ref(x, wt, b, stride=stride)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bc", [1, 8, 32, 64])
    def test_channel_block_invariance(self, bc):
        x = _rand((1, 10, 10, 24), 20, 0.5)
        wt = _rand((3, 3, 24), 21, 0.3)
        b = _rand((24,), 22, 0.1)
        out = depthwise_conv3x3(jnp.array(x), jnp.array(wt), jnp.array(b),
                                bc=bc)
        want = ref.depthwise_conv3x3_ref(x, wt, b)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# SSD post-processing
# ---------------------------------------------------------------------------


class TestPostproc:
    def test_decode_matches_ref(self):
        loc = _rand((100, 4), 30, 0.5)
        anc = np.abs(_rand((100, 4), 31, 0.2)) + 0.1
        out = postproc.decode_boxes(jnp.array(loc), jnp.array(anc))
        want = ref.decode_boxes_ref(loc, anc)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_zero_deltas_recover_anchor_corners(self):
        anc = np.array([[0.5, 0.5, 0.2, 0.4]], np.float32)  # cy,cx,h,w
        out = np.asarray(postproc.decode_boxes(
            jnp.zeros((1, 4)), jnp.array(anc)))
        np.testing.assert_allclose(out[0], [0.3, 0.4, 0.7, 0.6], atol=1e-6)

    def test_topk_orders_scores_descending(self):
        logits = _rand((50, 5), 40)
        boxes = np.abs(_rand((50, 4), 41, 0.2))
        b, c, s, n = postproc.select_topk(jnp.array(boxes),
                                          jnp.array(logits), k=10)
        s = np.asarray(s)
        assert s.shape == (10,)
        assert np.all(np.diff(s) <= 1e-6)
        assert np.asarray(b).shape == (10, 4)
        assert np.asarray(c).shape == (10,)
        assert 0 <= float(np.asarray(n)[0]) <= 10

    def test_topk_boxes_clipped_to_unit(self):
        logits = _rand((30, 4), 42)
        boxes = _rand((30, 4), 43, 3.0)   # intentionally out of range
        b, _, _, _ = postproc.select_topk(jnp.array(boxes),
                                          jnp.array(logits), k=5)
        b = np.asarray(b)
        assert b.min() >= 0.0 and b.max() <= 1.0

    def test_count_threshold(self):
        # One anchor with a huge class-1 logit -> exactly 1 above 0.5.
        logits = np.full((10, 3), -10.0, np.float32)
        logits[4, 1] = 10.0
        boxes = np.tile(np.array([[0.1, 0.1, 0.2, 0.2]], np.float32),
                        (10, 1))
        _, c, s, n = postproc.select_topk(jnp.array(boxes),
                                          jnp.array(logits), k=5)
        assert float(np.asarray(n)[0]) == 1.0
        assert float(np.asarray(s)[0]) > 0.99
        assert float(np.asarray(c)[0]) == 1.0
