"""L2 model contracts: shapes, ranges, determinism, anchor geometry."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _run(name, seed=7, scale=0.5):
    closed, bank = M.build(name)
    x = (np.random.RandomState(seed)
         .randn(*M.MODELS[name]["input_shape"]).astype(np.float32) * scale)
    outs = closed(jnp.array(x), *[jnp.array(v) for v in bank.values])
    return [np.asarray(o) for o in outs]


class TestRegistry:
    def test_all_models_declared(self):
        assert set(M.MODELS) == {"detector", "posenet", "detect", "imucls"}

    @pytest.mark.parametrize("name", list(M.MODELS))
    def test_output_shapes_match_declaration(self, name):
        outs = _run(name)
        declared = [shape for _, shape in M.MODELS[name]["outputs"]]
        assert [o.shape for o in outs] == [tuple(s) for s in declared]

    @pytest.mark.parametrize("name", list(M.MODELS))
    def test_outputs_finite(self, name):
        for o in _run(name):
            assert np.all(np.isfinite(o))

    @pytest.mark.parametrize("name", list(M.MODELS))
    def test_param_bank_deterministic(self, name):
        b1 = M.MODELS[name]["params"]()
        b2 = M.MODELS[name]["params"]()
        assert b1.names == b2.names
        for v1, v2 in zip(b1.values, b2.values):
            np.testing.assert_array_equal(v1, v2)


class TestDetector:
    def test_boxes_in_unit_square(self):
        boxes, cls, score, count = _run("detector")
        assert boxes.min() >= 0.0 and boxes.max() <= 1.0

    def test_scores_sorted_and_probabilistic(self):
        _, _, score, _ = _run("detector")
        assert np.all(np.diff(score) <= 1e-6)
        assert score.min() >= 0.0 and score.max() <= 1.0

    def test_classes_in_label_range(self):
        _, cls, _, _ = _run("detector")
        assert cls.min() >= 1.0 and cls.max() <= M.DET_CLASSES - 1 + 1

    def test_count_bounded_by_k(self):
        _, _, _, count = _run("detector")
        assert 0.0 <= count[0] <= M.DET_K


class TestAnchors:
    def test_anchor_count(self):
        anc = M.make_anchors()
        assert anc.shape == (M.DET_GRID ** 2 * M.DET_ANCHORS_PER_CELL, 4)

    def test_anchor_centers_cover_grid(self):
        anc = M.make_anchors()
        cy, cx = anc[:, 0], anc[:, 1]
        assert cy.min() > 0 and cy.max() < 1
        assert cx.min() > 0 and cx.max() < 1
        # first cell center at (0.5/grid)
        np.testing.assert_allclose(cy[0], 0.5 / M.DET_GRID, rtol=1e-6)

    def test_anchor_sizes_positive(self):
        anc = M.make_anchors()
        assert anc[:, 2:].min() > 0


class TestPosenet:
    def test_keypoints_in_unit_square(self):
        (kp,) = _run("posenet")
        assert kp[:, 0].min() >= 0 and kp[:, 0].max() <= 1
        assert kp[:, 1].min() >= 0 and kp[:, 1].max() <= 1

    def test_scores_are_sigmoid(self):
        (kp,) = _run("posenet")
        assert kp[:, 2].min() >= 0 and kp[:, 2].max() <= 1


class TestDetectGate:
    def test_activation_is_probability(self):
        (act,) = _run("detect")
        assert act.shape == (1,)
        assert 0.0 <= act[0] <= 1.0

    def test_different_inputs_different_scores(self):
        a = _run("detect", seed=1)[0][0]
        b = _run("detect", seed=2)[0][0]
        assert a != b


class TestImuCls:
    def test_probs_sum_to_one(self):
        (p,) = _run("imucls")
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)

    def test_probs_nonnegative(self):
        (p,) = _run("imucls")
        assert p.min() >= 0.0
