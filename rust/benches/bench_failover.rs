//! E9 — resilient elastic offload, gated (ISSUE 6).
//!
//! Two scenarios, both with hard budget asserts so CI fails on
//! resilience regressions, reported into `BENCH_failover.json`
//! (path override: `EDGEPIPE_BENCH_OUT`):
//!
//! **failover** — two MQTT-hybrid servers on one operation; the primary
//! dies mid-stream. Gates: the service gap until the first post-kill
//! response is bounded (`RECOVERY_MS_MAX`), frame loss across the stall
//! is bounded (leaky deadline semantics — the pipeline never errors),
//! and the client observably re-routed or retried (metrics, not luck).
//!
//! **hedged tail** — a primary whose every 5th response is artificially
//! slow, next to a fast-but-busier peer. An unhedged client eats the
//! tail; a hedged client (`hedge-pct`) duplicates the laggard request to
//! the second-best peer and takes whichever answers first. Gate: hedging
//! cuts p99 by at least 25%, and at least one hedge actually won.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use edgepipe::bench;
use edgepipe::buffer::Buffer;
use edgepipe::caps::Caps;
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::{
    appsink_channel, AppSink, AppSrc, QueryClient, QueryServerSink, QueryServerSrc,
    ResilienceConfig, TensorFilter,
};
use edgepipe::metrics;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::{parser, Pipeline, Running};
use edgepipe::tensor::{DType, TensorInfo, TensorsInfo};

/// Recovery budget: dead-request timeout + rediscovery + reconnect.
const RECOVERY_MS_MAX: u64 = 4000;
/// Frames the 30 fps source may lose across the stall (leaky queue +
/// deadline drops). ~3 s of stall at 30 fps, rounded up.
const FRAME_LOSS_MAX: u64 = 90;
/// Dropped-by-deadline budget for the client itself.
const FRAMES_DROPPED_MAX: u64 = 30;

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn qcounter(name: &str, which: &str) -> u64 {
    metrics::global().counter(&format!("query.{name}.{which}")).count()
}

// ---------------------------------------------------------------------------
// Scenario 1: kill the primary mid-run
// ---------------------------------------------------------------------------

struct FailoverRow {
    run: u64,
    gap_ms: u64,
    offered: u64,
    delivered: u64,
    frames_dropped: u64,
    retries: u64,
    reroutes: u64,
}

fn failover_runs(registry: &Registry, env: &PipelineEnv, broker: &str) -> Vec<FailoverRow> {
    const OFFERED: u64 = 240; // 8 s at 30 fps
    let mut rows = Vec::new();
    for run in 0..2u64 {
        let (p1, p2) = (free_port(), free_port());
        // Primary advertises idle, backup advertises busier: selection is
        // deterministic (always `a` first), so the kill always hits the
        // in-use server.
        let mk = |pair: &str, port: u16, load: &str| {
            format!(
                "tensor_query_serversrc operation=fo{run} port={port} pair-id={pair}-{run} \
                   protocol=mqtt-hybrid broker={broker} server-id={pair}-{run} load={load} ! \
                 tensor_filter framework=passthrough ! \
                 tensor_query_serversink operation=fo{run} pair-id={pair}-{run}"
            )
        };
        let s1 = parser::parse(&mk("a", p1, "0.0"), registry, env).unwrap().start().unwrap();
        let s2 = parser::parse(&mk("b", p2, "0.6"), registry, env).unwrap().start().unwrap();
        std::thread::sleep(Duration::from_millis(500));

        let qc = format!("foqc{run}");
        let client = parser::parse(
            &format!(
                "videotestsrc width=160 height=120 framerate=30 num-buffers={OFFERED} ! \
                 tensor_converter ! queue leaky=2 max-size-buffers=2 ! \
                 tensor_query_client name={qc} operation=fo{run} protocol=mqtt-hybrid \
                   broker={broker} timeout-ms=1000 retry=4 backoff-ms=50 deadline-ms=900 ! \
                 appsink channel=fo{run}"
            ),
            registry,
            env,
        )
        .unwrap()
        .start()
        .unwrap();
        let rx = appsink_channel(&format!("fo{run}")).unwrap();

        // Warm up: 20 responses, then kill the in-use server.
        let mut delivered: u64 = 0;
        for _ in 0..20 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
            delivered += 1;
        }
        let kill_at = Instant::now();
        let _ = s1.stop(Duration::from_secs(2));
        // First response AFTER the kill marks recovery.
        let gap = loop {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
            delivered += 1;
            let dt = kill_at.elapsed();
            if dt > Duration::from_millis(5) {
                break dt;
            }
        };
        while rx.recv_timeout(Duration::from_secs(5)).is_ok() {
            delivered += 1;
        }
        let _ = client.stop(Duration::from_secs(5));
        let _ = s2.stop(Duration::from_secs(5));

        let row = FailoverRow {
            run,
            gap_ms: gap.as_millis() as u64,
            offered: OFFERED,
            delivered,
            frames_dropped: qcounter(&qc, "frames_dropped"),
            retries: qcounter(&qc, "retries"),
            reroutes: qcounter(&qc, "reroutes"),
        };

        // --- hard gates ---
        assert!(
            row.gap_ms <= RECOVERY_MS_MAX,
            "run {run}: recovery took {} ms (budget {RECOVERY_MS_MAX} ms)",
            row.gap_ms
        );
        assert!(
            row.delivered + FRAME_LOSS_MAX >= row.offered,
            "run {run}: lost {} frames (budget {FRAME_LOSS_MAX})",
            row.offered - row.delivered
        );
        assert!(
            row.frames_dropped <= FRAMES_DROPPED_MAX,
            "run {run}: client dropped {} frames (budget {FRAMES_DROPPED_MAX})",
            row.frames_dropped
        );
        assert!(
            row.retries + row.reroutes >= 1,
            "run {run}: no observable failover (retries=0, reroutes=0)"
        );
        rows.push(row);
    }
    rows
}

// ---------------------------------------------------------------------------
// Scenario 2: hedged tail-cutting
// ---------------------------------------------------------------------------

/// Server pipeline whose filter sleeps `tail_ms` on every 5th request.
fn tail_server(op: &str, pair: &str, sid: &str, broker: &str, load: f64, tail_ms: u64) -> Running {
    let src = QueryServerSrc::new(op)
        .with_pair_id(pair)
        .with_server_id(sid)
        .with_bind("127.0.0.1:0")
        .with_hybrid(broker)
        .with_advertised_load(load);
    let n = Arc::new(AtomicU64::new(0));
    let f = TensorFilter::custom(Box::new(move |b: &Buffer| {
        if tail_ms > 0 && n.fetch_add(1, Ordering::Relaxed) % 5 == 4 {
            std::thread::sleep(Duration::from_millis(tail_ms));
        }
        Ok(b.data.to_vec())
    }));
    let mut p = Pipeline::new();
    let s = p.add("ssrc", Box::new(src)).unwrap();
    let fi = p.add("f", Box::new(f)).unwrap();
    let k = p.add("ssink", Box::new(QueryServerSink::new(pair))).unwrap();
    p.link(s, fi).unwrap();
    p.link(fi, k).unwrap();
    p.start().unwrap()
}

/// Push `n` frames one at a time through a fresh client, returning the
/// per-frame round-trip times in milliseconds (sorted ascending).
fn measure_rtts(name: &str, client: QueryClient, n: usize) -> Vec<f64> {
    let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[16]).unwrap());
    let mut p = Pipeline::new();
    let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
    let (sink, rx) = AppSink::new(4);
    let s = p.add("src", Box::new(src)).unwrap();
    let c = p.add(name, Box::new(client)).unwrap();
    let k = p.add("sink", Box::new(sink)).unwrap();
    p.link(s, c).unwrap();
    p.link(c, k).unwrap();
    let running = p.start().unwrap();

    let mut rtts = Vec::with_capacity(n);
    for i in 0..n {
        let t0 = Instant::now();
        h.push(Buffer::new(vec![i as u8; 16])).unwrap();
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        rtts.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    drop(h);
    let _ = running.stop(Duration::from_secs(5));
    rtts.sort_by(|a, b| a.total_cmp(b));
    rtts
}

fn pctile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct HedgeStats {
    p50_plain_ms: f64,
    p99_plain_ms: f64,
    p50_hedged_ms: f64,
    p99_hedged_ms: f64,
    hedges: u64,
    hedge_wins: u64,
}

fn hedged_tail(broker: &str) -> HedgeStats {
    const N: usize = 100;
    const TAIL_MS: u64 = 80;

    // Unhedged baseline: its own operation so health/RTT state is clean.
    let sp = tail_server("hb-plain", "hbp-s", "slow", broker, 0.0, TAIL_MS);
    let fp = tail_server("hb-plain", "hbp-f", "fast", broker, 0.5, 0);
    std::thread::sleep(Duration::from_millis(500));
    let plain = measure_rtts(
        "hbqc_plain",
        QueryClient::hybrid("hb-plain", broker).unwrap().with_timeout(Duration::from_secs(5)),
        N,
    );
    let _ = sp.stop(Duration::from_secs(5));
    let _ = fp.stop(Duration::from_secs(5));

    // Hedged run: identical topology, hedge at the p50 of observed RTTs.
    let sh = tail_server("hb-hedged", "hbh-s", "slow", broker, 0.0, TAIL_MS);
    let fh = tail_server("hb-hedged", "hbh-f", "fast", broker, 0.5, 0);
    std::thread::sleep(Duration::from_millis(500));
    let hedged = measure_rtts(
        "hbqc_hedged",
        QueryClient::hybrid("hb-hedged", broker)
            .unwrap()
            .with_timeout(Duration::from_secs(5))
            .with_resilience(ResilienceConfig { hedge_pct: Some(0.5), ..Default::default() }),
        N,
    );
    let _ = sh.stop(Duration::from_secs(5));
    let _ = fh.stop(Duration::from_secs(5));

    let stats = HedgeStats {
        p50_plain_ms: pctile(&plain, 0.5),
        p99_plain_ms: pctile(&plain, 0.99),
        p50_hedged_ms: pctile(&hedged, 0.5),
        p99_hedged_ms: pctile(&hedged, 0.99),
        hedges: qcounter("hbqc_hedged", "hedges"),
        hedge_wins: qcounter("hbqc_hedged", "hedge_wins"),
    };

    // --- hard gates ---
    assert!(
        stats.p99_plain_ms >= TAIL_MS as f64 * 0.8,
        "tail did not materialize: unhedged p99 {:.1} ms",
        stats.p99_plain_ms
    );
    assert!(stats.hedge_wins >= 1, "no hedge ever won against an {TAIL_MS} ms tail");
    assert!(
        stats.p99_hedged_ms <= stats.p99_plain_ms * 0.75,
        "hedging failed to cut the tail: p99 {:.1} -> {:.1} ms",
        stats.p99_plain_ms,
        stats.p99_hedged_ms
    );
    stats
}

// ---------------------------------------------------------------------------

fn main() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    println!("# bench_failover (E9, R4 / ISSUE 6)");

    let rows = failover_runs(&registry, &env, &b);
    bench::table(
        "Failover service gap",
        &["run", "gap ms", "delivered/offered", "dropped", "retries", "reroutes"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.run),
                    format!("{}", r.gap_ms),
                    format!("{}/{}", r.delivered, r.offered),
                    format!("{}", r.frames_dropped),
                    format!("{}", r.retries),
                    format!("{}", r.reroutes),
                ]
            })
            .collect::<Vec<_>>(),
    );

    let h = hedged_tail(&b);
    bench::table(
        "Hedged tail (every 5th response +80 ms on the primary)",
        &["client", "p50 ms", "p99 ms"],
        &[
            vec!["plain".into(), format!("{:.1}", h.p50_plain_ms), format!("{:.1}", h.p99_plain_ms)],
            vec![
                "hedged".into(),
                format!("{:.1}", h.p50_hedged_ms),
                format!("{:.1}", h.p99_hedged_ms),
            ],
        ],
    );
    println!("\nhedges fired: {}  hedge wins: {}", h.hedges, h.hedge_wins);

    // ---- JSON report (hand-rolled; no serde offline) ----
    let out_path =
        std::env::var("EDGEPIPE_BENCH_OUT").unwrap_or_else(|_| "BENCH_failover.json".to_string());
    let generated = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let failover_json = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"run\": {}, \"gap_ms\": {}, \"offered\": {}, \"delivered\": {}, \
                 \"frames_dropped\": {}, \"retries\": {}, \"reroutes\": {}}}",
                r.run, r.gap_ms, r.offered, r.delivered, r.frames_dropped, r.retries, r.reroutes
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": 1,\n",
            "  \"bench\": \"failover\",\n",
            "  \"generated_unix\": {generated},\n",
            "  \"budgets\": {{\"recovery_ms_max\": {rec}, \"frame_loss_max\": {loss}, ",
            "\"frames_dropped_max\": {drop}, \"hedged_p99_ratio_max\": 0.75}},\n",
            "  \"failover\": [\n{failover}\n  ],\n",
            "  \"hedged_tail\": {{\"p50_plain_ms\": {p50p:.2}, \"p99_plain_ms\": {p99p:.2}, ",
            "\"p50_hedged_ms\": {p50h:.2}, \"p99_hedged_ms\": {p99h:.2}, ",
            "\"hedges\": {hedges}, \"hedge_wins\": {wins}}}\n",
            "}}\n"
        ),
        generated = generated,
        rec = RECOVERY_MS_MAX,
        loss = FRAME_LOSS_MAX,
        drop = FRAMES_DROPPED_MAX,
        failover = failover_json,
        p50p = h.p50_plain_ms,
        p99p = h.p99_plain_ms,
        p50h = h.p50_hedged_ms,
        p99h = h.p99_hedged_ms,
        hedges = h.hedges,
        wins = h.hedge_wins,
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
