//! E9 — R4 failover: two MQTT-hybrid servers on one operation; the
//! primary dies mid-stream; measure the service gap until the client's
//! next response arrives from the backup.

use std::time::{Duration, Instant};

use edgepipe::bench;
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::appsink_channel;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn main() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    println!("# bench_failover (E9, R4)");

    let mut rows = Vec::new();
    for run in 0..3 {
        let (p1, p2) = (free_port(), free_port());
        let mk = |pair: &str, port: u16| {
            format!(
                "tensor_query_serversrc operation=fo{run} port={port} pair-id={pair}-{run} \
                   protocol=mqtt-hybrid broker={b} server-id={pair}-{run} ! \
                 tensor_filter framework=passthrough ! \
                 tensor_query_serversink operation=fo{run} pair-id={pair}-{run}"
            )
        };
        let s1 = parser::parse(&mk("a", p1), &registry, &env).unwrap().start().unwrap();
        let s2 = parser::parse(&mk("b", p2), &registry, &env).unwrap().start().unwrap();
        std::thread::sleep(Duration::from_millis(500));

        let client = parser::parse(
            &format!(
                "videotestsrc width=160 height=120 framerate=30 num-buffers=240 ! \
                 tensor_converter ! queue leaky=2 max-size-buffers=2 ! \
                 tensor_query_client operation=fo{run} protocol=mqtt-hybrid broker={b} timeout-ms=1000 ! \
                 appsink channel=fo{run}"
            ),
            &registry,
            &env,
        )
        .unwrap()
        .start()
        .unwrap();
        let rx = appsink_channel(&format!("fo{run}")).unwrap();

        // Warm up: 20 responses, then kill the currently-used server.
        for _ in 0..20 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let kill_at = Instant::now();
        let _ = s1.stop(Duration::from_secs(2));
        // Next response that arrives AFTER the kill marks recovery.
        let gap = loop {
            let _buf = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            let dt = kill_at.elapsed();
            if dt > Duration::from_millis(5) {
                break dt;
            }
        };
        rows.push(vec![format!("run {run}"), format!("{:.0}", gap.as_secs_f64() * 1000.0)]);
        while rx.recv_timeout(Duration::from_secs(5)).is_ok() {}
        let _ = client.stop(Duration::from_secs(5));
        let _ = s2.stop(Duration::from_secs(5));
    }
    bench::table("Failover service gap", &["run", "gap ms"], &rows);
    println!("\n(Gap = dead-request timeout + rediscovery + reconnect; bounded by timeout-ms=1000.)");
}
