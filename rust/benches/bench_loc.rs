//! E7 — §5.2: "users can write such an among-device AI system within 100
//! lines of codes" (vs. "well over thousands" without a pipeline
//! framework). Counts pipeline-description tokens for each reproduced
//! application and compares with the LoC of the substrate they replace.

use edgepipe::bench;
use edgepipe::pipeline::parser::segment_count;

fn main() {
    println!("# bench_loc (E7, §5.2)");
    let apps: [(&str, Vec<&str>); 4] = [
        (
            "Listing 1 / Fig 2 offloading (client+server)",
            vec![
                "v4l2src ! tee name=ts \
                 ts. videoconvert ! videoscale width=300 height=300 ! video/x-raw,width=300,height=300,format=RGB ! \
                   queue leaky=2 ! tensor_converter ! \
                   tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
                   tensor_query_client operation=objdetect/ssdlite protocol=mqtt-hybrid ! tee name=tc \
                 ts. queue leaky=2 ! videoconvert ! mix.sink_1 \
                 tc. queue leaky=2 ! appsink name=appthread \
                 tc. tensor_decoder mode=bounding_boxes option4=640:480 ! videoconvert ! mix.sink_0 \
                 compositor name=mix sink_0::zorder=2 sink_1::zorder=1 ! videoconvert ! ximagesink",
                "tensor_query_serversrc operation=objdetect/ssdlite protocol=mqtt-hybrid ! \
                 tensor_filter framework=pjrt model=detector ! \
                 tensor_query_serversink operation=objdetect/ssdlite",
            ],
        ),
        (
            "Listing 2 / Fig 3 pub/sub IoT (4 devices)",
            vec![
                "v4l2src ! tensor_converter ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=camleft",
                "v4l2src ! tensor_converter ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=camright",
                "mqttsrc sub-topic=camleft ! tensor_converter ! queue leaky=2 ! \
                 tensor_transform mode=arithmetic option=typecast:float32,div:255.0 ! \
                 tensor_filter framework=pjrt model=detect ! tensor_decoder mode=flexbuf ! \
                 mqttsink pub-topic=edge/inference",
                "mqttsrc sub-topic=camleft ! tensor_converter ! queue ! mux.sink_0 \
                 mqttsrc sub-topic=camright ! tensor_converter ! queue ! mux.sink_1 \
                 tensor_mux name=mux ! tensor_demux name=dmux srcs=2 \
                 dmux.src_0 ! tensor_decoder mode=direct_video ! queue ! mix.sink_0 \
                 dmux.src_1 ! tensor_decoder mode=direct_video ! queue ! mix.sink_1 \
                 compositor name=mix sink_0::xpos=0 sink_1::xpos=160 ! videoconvert ! ximagesink",
            ],
        ),
        (
            "Fig 5 augmented worker (mobile both pipelines)",
            vec![
                "v4l2src ! tensor_converter ! tensor_transform mode=arithmetic option=typecast:float32,div:255.0 ! \
                 tensor_filter framework=pjrt model=detect ! \
                 tensor_if compared-value=0 operator=gt threshold=0.4 name=gate \
                 gate.src_0 ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=worker/activate \
                 gate.src_1 ! fakesink",
                "mqttsrc sub-topic=worker/imu ! tensor_converter ! queue leaky=2 ! \
                 tensor_filter framework=pjrt model=imucls ! appsink name=verdicts",
            ],
        ),
        (
            "quickstart (on-device detector)",
            vec![
                "videotestsrc ! tee name=ts \
                 ts. ! queue leaky=2 ! videoconvert ! videoscale width=300 height=300 ! \
                   tensor_converter ! tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
                   tensor_filter framework=pjrt model=detector ! \
                   tensor_decoder mode=bounding_boxes option4=640:480 ! appsink channel=boxes \
                 ts. ! queue leaky=2 ! videoconvert ! fakesink",
            ],
        ),
    ];

    // LoC of the substrate these descriptions replace (what an application
    // would otherwise hand-roll): transports + broker + sync + serialization.
    let substrate_loc = count_rust_loc(&[
        "rust/src/mqtt",
        "rust/src/zmq",
        "rust/src/ntp",
        "rust/src/serial",
        "rust/src/elements",
        "rust/src/pipeline",
        "rust/src/element",
    ]);

    let mut rows = Vec::new();
    for (name, descs) in &apps {
        let tokens: usize = descs.iter().map(|d| segment_count(d)).sum();
        let lines: usize = descs.len();
        rows.push(vec![
            name.to_string(),
            format!("{}", descs.len()),
            format!("{tokens}"),
            format!("{}", tokens < 100),
            format!("{lines} desc strings"),
        ]);
    }
    bench::table(
        "Application pipeline-description size (§5.2 '<100 lines')",
        &["application", "pipelines", "description tokens", "<100?", "note"],
        &rows,
    );
    println!(
        "\nFramework substrate these apps did NOT have to write: ~{substrate_loc} LoC \
         (transports, broker, sync, serialization, elements, engine) — the paper's \
         'well over thousands of lines of codes'."
    );
}

fn count_rust_loc(dirs: &[&str]) -> usize {
    let mut total = 0;
    for d in dirs {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(d);
        total += walk(&root);
    }
    total
}

fn walk(dir: &std::path::Path) -> usize {
    let mut n = 0;
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let p = entry.path();
            if p.is_dir() {
                n += walk(&p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                if let Ok(text) = std::fs::read_to_string(&p) {
                    n += text.lines().filter(|l| !l.trim().is_empty()).count();
                }
            }
        }
    }
    n
}
