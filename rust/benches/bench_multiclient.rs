//! E10 — multi-client scaling (§3: "having multiple clients ...
//! over-complicates pipelines" with raw TCP; trivial with query elements).
//!
//! One passthrough query server, 1..8 concurrent clients at VGA/30 Hz;
//! reports aggregate and per-client fps plus fairness (min/max client).

use std::time::Duration;

use edgepipe::bench;
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::metrics;
use edgepipe::pipeline::parser;

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn main() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let secs = bench::secs();
    println!("# bench_multiclient (E10) — VGA @30Hz per client, {secs}s");
    let mut rows = Vec::new();
    for n in [1usize, 2, 4, 8] {
        metrics::global().reset();
        let port = free_port();
        let pair = format!("mc{n}");
        let server = parser::parse(
            &format!(
                "tensor_query_serversrc operation={pair} port={port} pair-id={pair} ! \
                 tensor_filter framework=passthrough ! \
                 tensor_query_serversink operation={pair} pair-id={pair}"
            ),
            &registry,
            &env,
        )
        .unwrap()
        .start()
        .unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let nbuf = secs * 30;
        let t0 = std::time::Instant::now();
        let clients: Vec<_> = (0..n)
            .map(|i| {
                parser::parse(
                    &format!(
                        "videotestsrc width=640 height=480 framerate=30 num-buffers={nbuf} ! \
                         tensor_converter ! queue leaky=2 max-size-buffers=2 ! \
                         tensor_query_client operation={pair} server=127.0.0.1:{port} timeout-ms=20000 ! \
                         appsink name={pair}c{i}"
                    ),
                    &registry,
                    &env,
                )
                .unwrap()
                .start()
                .unwrap()
            })
            .collect();
        for c in clients {
            let _ = c.wait_eos(Duration::from_secs(secs * 8 + 60));
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let counts: Vec<u64> =
            (0..n).map(|i| metrics::global().counter(&format!("appsink.{pair}c{i}")).count()).collect();
        let total: u64 = counts.iter().sum();
        let min = *counts.iter().min().unwrap() as f64 / elapsed;
        let max = *counts.iter().max().unwrap() as f64 / elapsed;
        let _ = server.stop(Duration::from_secs(5));
        rows.push(vec![
            format!("{n}"),
            format!("{:.1}", total as f64 / elapsed),
            format!("{:.1}", total as f64 / elapsed / n as f64),
            format!("{:.1} / {:.1}", min, max),
        ]);
    }
    bench::table(
        "Multi-client query scaling",
        &["clients", "aggregate fps", "per-client fps", "min/max client fps"],
        &rows,
    );
}
