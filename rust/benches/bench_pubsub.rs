//! E4 — Figure 6 Case A / Figure 7 left: stream pub/sub, MQTT vs ZeroMQ.
//!
//! Device A publishes a live video stream at L/M/H bandwidth (60 Hz);
//! Device B subscribes. MQTT goes through the in-repo broker; the
//! ZeroMQ-analog is a direct brokerless connection. We report delivered
//! fps, data rate, CPU% and RSS growth, plus the MQTT/ZMQ ratio the paper
//! plots. Expected shape: parity at L, MQTT degradation at M/H (broker
//! copy + slow-consumer drops).
//!
//! A many-subscriber table drives the broker's sharded trie `Router`
//! in-process at `EDGEPIPE_BENCH_SUBS` subscription counts (default
//! 1k/10k/100k), reporting per-publish cost for exact-match and
//! wildcard-heavy workloads against a flat-list replica of the pre-trie
//! scan at every count. The hard gates on these numbers live in
//! `bench_wirepath` (schema 6).

use std::time::Duration;

use edgepipe::bench::{self, RunStats, CASES, FPS};
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::metrics;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;

fn run_one(transport: &str, w: u32, h: u32, secs: u64, registry: &Registry, env: &PipelineEnv) -> RunStats {
    metrics::global().reset();
    let nbuf = secs * FPS as u64;
    let sink_name = format!("bps_{transport}_{w}");
    let (pub_desc, sub_desc, _broker) = match transport {
        "mqtt" => {
            let broker = Broker::start("127.0.0.1:0").unwrap();
            let b = broker.addr().to_string();
            (
                format!(
                    "videotestsrc width={w} height={h} framerate={FPS} pattern=smpte num-buffers={nbuf} ! \
                     tensor_converter ! mqttsink pub-topic=bench/cam broker={b} sync=false"
                ),
                format!(
                    "mqttsrc sub-topic=bench/cam broker={b} sync=false ! tensor_converter ! appsink name={sink_name}"
                ),
                Some(broker),
            )
        }
        "zmq" => {
            let addr = {
                let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                l.local_addr().unwrap().to_string()
            };
            (
                format!(
                    "videotestsrc width={w} height={h} framerate={FPS} pattern=smpte num-buffers={nbuf} ! \
                     tensor_converter ! zmqsink bind={addr} topic=bench"
                ),
                format!("zmqsrc connect={addr} topic=bench ! tensor_converter ! appsink name={sink_name}"),
                None,
            )
        }
        _ => unreachable!(),
    };

    bench::measured(|| {
        let sub = parser::parse(&sub_desc, registry, env).unwrap().start().unwrap();
        std::thread::sleep(Duration::from_millis(400));
        let t0 = std::time::Instant::now();
        let publ = parser::parse(&pub_desc, registry, env).unwrap().start().unwrap();
        let _ = publ.wait_eos(Duration::from_secs(secs * 4 + 30));
        let (count, bytes) = bench::drain_counter(&format!("appsink.{sink_name}"), Duration::from_millis(300));
        let elapsed = t0.elapsed().as_secs_f64() - 0.3;
        let _ = sub.stop(Duration::from_secs(5));
        (count, bytes, elapsed)
    })
}

fn main() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let secs = bench::secs();
    let runs = bench::runs();
    println!("# bench_pubsub (E4, Fig 7 left) — {secs}s x {runs} runs, offered {FPS} Hz");

    let mut rows = Vec::new();
    let mut ratio_rows = Vec::new();
    for (label, w, h) in CASES {
        let mut per_transport = Vec::new();
        for transport in ["zmq", "mqtt"] {
            let mut best = RunStats::default();
            for _ in 0..runs {
                let s = run_one(transport, w, h, secs, &registry, &env);
                if s.fps() > best.fps() {
                    best = s;
                }
            }
            rows.push(vec![
                label.to_string(),
                transport.to_string(),
                format!("{:.1}", best.fps()),
                format!("{:.1}", best.mbps()),
                format!("{:.0}", best.cpu_pct),
                format!("{}", best.rss_growth_kb / 1024),
            ]);
            per_transport.push(best);
        }
        let (z, m) = (&per_transport[0], &per_transport[1]);
        ratio_rows.push(vec![
            label.to_string(),
            format!("{:.2}", m.fps() / z.fps().max(1e-9)),
            format!("{:.2}", m.cpu_pct / z.cpu_pct.max(1e-9)),
            format!("{:.2}", (m.rss_growth_kb.max(1)) as f64 / (z.rss_growth_kb.max(1)) as f64),
        ]);
    }
    bench::table(
        "Pub/Sub absolute",
        &["case", "transport", "fps", "MB/s", "cpu %", "rss +MiB"],
        &rows,
    );
    bench::table(
        "Pub/Sub — MQTT normalized by ZeroMQ (Fig 7 left)",
        &["case", "throughput ratio", "cpu ratio", "mem-growth ratio"],
        &ratio_rows,
    );

    // Many-subscriber routing at every count (in-process Router; the
    // flat-cost and 2x-speedup gates live in bench_wirepath).
    let counts = bench::manysubs::sub_counts();
    let shards = edgepipe::mqtt::Router::new(0).shard_count();
    let mut mrows = Vec::new();
    for &n in &counts {
        let exact_ns = bench::manysubs::run_exact_scaling(n, 10_000);
        let trie_ns = bench::manysubs::run_mixed_trie(n, 5_000);
        let flat_ns = bench::manysubs::run_mixed_flat(n, 200);
        mrows.push(vec![
            n.to_string(),
            format!("{exact_ns:.0}"),
            format!("{trie_ns:.0}"),
            format!("{flat_ns:.0}"),
            format!("{:.1}x", flat_ns / trie_ns.max(1e-9)),
        ]);
    }
    bench::table(
        &format!("Many-subscriber routing — {shards}-shard trie router vs flat-list scan (ns/publish)"),
        &["subscriptions", "exact (trie)", "wildcard mix (trie)", "wildcard mix (flat)", "trie speedup"],
        &mrows,
    );
}
