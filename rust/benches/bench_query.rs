//! E5 — Figure 6 Case B / Figure 7 right: query offloading,
//! MQTT-hybrid vs TCP-raw.
//!
//! The server runs a passthrough filter so the measurement isolates the
//! transport (the paper's point: MQTT-hybrid keeps MQTT's discovery but
//! moves data onto direct TCP, eliminating the broker from the data
//! path). Expected shape: MQTT-hybrid ≈ TCP on all metrics.

use std::time::Duration;

use edgepipe::bench::{self, RunStats, CASES, FPS};
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::metrics;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

fn run_one(proto: &str, w: u32, h: u32, secs: u64, registry: &Registry, env: &PipelineEnv) -> (RunStats, f64) {
    metrics::global().reset();
    let nbuf = secs * FPS as u64;
    let port = free_port();
    let pair = format!("bq-{proto}-{w}");
    let sink_name = format!("bq_{proto}_{w}");
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    let (server_proto, client_tail) = match proto {
        "tcp" => ("tcp", format!("server=127.0.0.1:{port}")),
        "hybrid" => ("mqtt-hybrid", format!("protocol=mqtt-hybrid broker={b}")),
        _ => unreachable!(),
    };
    let server_desc = format!(
        "tensor_query_serversrc operation=bench/{pair} port={port} pair-id={pair} \
           protocol={server_proto} broker={b} server-id={pair} ! \
         tensor_filter framework=passthrough ! \
         tensor_query_serversink operation=bench/{pair} pair-id={pair}"
    );
    let client_desc = format!(
        "videotestsrc width={w} height={h} framerate={FPS} pattern=smpte num-buffers={nbuf} ! \
         tensor_converter ! queue leaky=2 max-size-buffers=4 ! \
         tensor_query_client name=qc operation=bench/{pair} timeout-ms=20000 {client_tail} ! \
         appsink name={sink_name}"
    );
    let stats = bench::measured(|| {
        let server = parser::parse(&server_desc, registry, env).unwrap().start().unwrap();
        std::thread::sleep(Duration::from_millis(400));
        let t0 = std::time::Instant::now();
        let client = parser::parse(&client_desc, registry, env).unwrap().start().unwrap();
        let _ = client.wait_eos(Duration::from_secs(secs * 6 + 60));
        let elapsed = t0.elapsed().as_secs_f64();
        let c = metrics::global().counter(&format!("appsink.{sink_name}"));
        let out = (c.count(), c.bytes(), elapsed);
        let _ = server.stop(Duration::from_secs(5));
        out
    });
    let rtt_ms = metrics::global().summary("query.qc.rtt_us").map(|s| s.mean / 1000.0).unwrap_or(0.0);
    (stats, rtt_ms)
}

fn main() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let secs = bench::secs();
    println!("# bench_query (E5, Fig 7 right) — {secs}s, offered {FPS} Hz, passthrough server");

    let mut rows = Vec::new();
    let mut ratio_rows = Vec::new();
    for (label, w, h) in CASES {
        let mut per = Vec::new();
        for proto in ["tcp", "hybrid"] {
            let (s, rtt) = run_one(proto, w, h, secs, &registry, &env);
            rows.push(vec![
                label.to_string(),
                proto.to_string(),
                format!("{:.1}", s.fps()),
                format!("{:.2}", rtt),
                format!("{:.0}", s.cpu_pct),
                format!("{}", s.rss_growth_kb / 1024),
            ]);
            per.push((s, rtt));
        }
        let ((t, trtt), (hb, hrtt)) = (&per[0], &per[1]);
        ratio_rows.push(vec![
            label.to_string(),
            format!("{:.2}", hb.fps() / t.fps().max(1e-9)),
            format!("{:.2}", hrtt / trtt.max(1e-9)),
            format!("{:.2}", hb.cpu_pct / t.cpu_pct.max(1e-9)),
        ]);
    }
    bench::table(
        "Query absolute",
        &["case", "protocol", "fps", "rtt ms", "cpu %", "rss +MiB"],
        &rows,
    );
    bench::table(
        "Query — MQTT-hybrid normalized by TCP-raw (Fig 7 right)",
        &["case", "throughput ratio", "rtt ratio", "cpu ratio"],
        &ratio_rows,
    );
}
