//! E8 — sparse tensor streams (§3/§4.1): the compression clients asked
//! for on language/speech model tensors.
//!
//! Sweeps density for a 100k-element f32 tensor and reports COO size,
//! zlib size, and encode/decode throughput vs the dense baseline.

use std::time::Instant;

use edgepipe::bench;
use edgepipe::serial::compress::{compress, decompress, Codec};
use edgepipe::tensor::{f32_to_bytes, sparse, DType, TensorInfo};
use edgepipe::util::rng::XorShift64;

fn main() {
    let n = 100_000usize;
    let info = TensorInfo::new(DType::F32, &[n as u32]).unwrap();
    let mut rng = XorShift64::new(42);
    println!("# bench_sparse (E8) — {n} f32 elements");
    let mut rows = Vec::new();
    for density_pct in [0.5f64, 1.0, 5.0, 10.0, 25.0, 50.0, 100.0] {
        let vals: Vec<f32> = (0..n)
            .map(|_| if rng.bool((density_pct / 100.0) as f32) { rng.normal() } else { 0.0 })
            .collect();
        let dense = f32_to_bytes(&vals);

        let t0 = Instant::now();
        let coo = sparse::encode(&info, &dense).unwrap();
        let enc_t = t0.elapsed();
        let t1 = Instant::now();
        let (_, roundtrip) = sparse::decode(&coo).unwrap();
        let dec_t = t1.elapsed();
        assert_eq!(roundtrip, dense);

        let t2 = Instant::now();
        let z = compress(Codec::Zlib, &dense).unwrap();
        let z_t = t2.elapsed();
        assert_eq!(decompress(Codec::Zlib, &z).unwrap(), dense);

        rows.push(vec![
            format!("{density_pct}%"),
            format!("{}", dense.len()),
            format!("{} ({:.2}x)", coo.len(), dense.len() as f64 / coo.len() as f64),
            format!("{} ({:.2}x)", z.len(), dense.len() as f64 / z.len() as f64),
            format!("{:.1}", dense.len() as f64 / enc_t.as_secs_f64() / 1e6),
            format!("{:.1}", dense.len() as f64 / dec_t.as_secs_f64() / 1e6),
            format!("{:.1}", dense.len() as f64 / z_t.as_secs_f64() / 1e6),
        ]);
    }
    bench::table(
        "Sparse (COO) vs zlib on f32 tensors",
        &["density", "dense B", "COO B (ratio)", "zlib B (ratio)", "COO enc MB/s", "COO dec MB/s", "zlib enc MB/s"],
        &rows,
    );
    println!(
        "\nCOO break-even density for f32: {:.0}% (4-byte index + 4-byte value per nnz).",
        sparse::breakeven_density(DType::F32) * 100.0
    );
}
