//! E3 — §4.2.3 / Fig 4: timestamp synchronization accuracy.
//!
//! Two publishers feed one muxing subscriber. Publisher B starts late
//! (injected latency, the paper's queue2 experiment). We compare the
//! inter-stream timestamp delta at the mux with the sync mechanism ON
//! (publisher base-time + NTP correction) vs OFF (raw remote PTS).

use std::time::Duration;

use edgepipe::bench;
use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::metrics;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::parser;

fn run_case(sync: bool, registry: &Registry, env: &PipelineEnv) -> Option<edgepipe::metrics::Summary> {
    metrics::global().reset();
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    let s = sync;
    let mux_name = format!("smux{}", sync as u8);
    let sub = parser::parse(
        &format!(
            "mqttsrc sub-topic=sa broker={b} sync={s} ! tensor_converter ! queue ! {mux_name}.sink_0 \
             mqttsrc sub-topic=sb broker={b} sync={s} ! tensor_converter ! queue ! {mux_name}.sink_1 \
             tensor_mux name={mux_name} ! fakesink"
        ),
        registry,
        env,
    )
    .unwrap()
    .start()
    .unwrap();
    std::thread::sleep(Duration::from_millis(300));

    let secs = bench::secs().min(5);
    let nbuf = secs * 30;
    let pa = parser::parse(
        &format!(
            "videotestsrc width=32 height=32 framerate=30 num-buffers={nbuf} ! \
             tensor_converter ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=sa broker={b} sync={s}"
        ),
        registry,
        env,
    )
    .unwrap()
    .start()
    .unwrap();
    // Injected latency: publisher B starts 500 ms later, so its pipeline
    // clock (and raw PTS values) lag A's by 500 ms.
    std::thread::sleep(Duration::from_millis(500));
    let pb = parser::parse(
        &format!(
            "videotestsrc width=32 height=32 framerate=30 num-buffers={nbuf} ! \
             tensor_converter ! tensor_decoder mode=flexbuf ! mqttsink pub-topic=sb broker={b} sync={s}"
        ),
        registry,
        env,
    )
    .unwrap()
    .start()
    .unwrap();
    let _ = pa.wait_eos(Duration::from_secs(secs + 30));
    let _ = pb.wait_eos(Duration::from_secs(secs + 30));
    std::thread::sleep(Duration::from_millis(500));
    let out = metrics::global().summary(&format!("mux.{mux_name}.delta_ms"));
    let _ = sub.stop(Duration::from_secs(5));
    out
}

fn main() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    println!("# bench_sync (E3, §4.2.3) — publisher B delayed 500 ms");
    let mut rows = Vec::new();
    for sync in [false, true] {
        match run_case(sync, &registry, &env) {
            Some(s) => rows.push(vec![
                if sync { "sync ON (base-time + NTP)" } else { "sync OFF (raw PTS)" }.to_string(),
                format!("{}", s.count),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p95),
                format!("{:.2}", s.max),
            ]),
            None => rows.push(vec!["(no merges)".into(), "0".into(), "-".into(), "-".into(), "-".into()]),
        }
    }
    bench::table(
        "Inter-stream timestamp delta at the mux (ms)",
        &["mechanism", "merges", "mean", "p95", "max"],
        &rows,
    );
    println!("\nExpected: OFF ≈ the injected 500 ms skew; ON ≈ frame-period scale.");
}
