//! Wire-path throughput + bytes-copied audit (the zero-copy tentpole).
//!
//! Measures one among-device pub/sub hop per frame — EdgeFrame encode,
//! MQTT PUBLISH framing, packet read, EdgeFrame decode — for the paper's
//! L/M/H bandwidth cases, twice:
//!
//! - **zero-copy**: `wire::encode_vectored` + `publish_head` scatter-
//!   gather write, `Packet::read` (single body allocation) +
//!   `wire::decode_shared` (slice view). Counted payload copies: 0.
//! - **baseline**: a faithful replica of the pre-refactor copy path
//!   (compress round-trip, packet body assembly, `payload.to_vec()` at
//!   the client, payload copy-out on decode) with every payload copy
//!   recorded via `buffer::record_copy`.
//!
//! Compressed hops are measured the same way for compressible
//! (tensor-like) and incompressible (noise) payloads: the streaming path
//! deflates straight into the single-allocation frame and inflates
//! straight out of the received view, while the baseline replica drags
//! the compressed bytes through every pre-refactor copy stage.
//!
//! A broker section drives real sockets with N subscribers to confirm
//! fan-out shares one encoded frame (payload copies per delivered frame
//! stay ~0 regardless of N) and — for compressed publishes — that each
//! frame is deflated exactly ONCE no matter how many subscribers exist.
//!
//! A **density** section (schema 3) exercises the worker-pool scheduler:
//! M pipelines x 6 compute elements at M in {1, 8, 64} on K=4 workers
//! (`EDGEPIPE_WORKERS`), asserting the pool keeps resident pipeline
//! threads at K (>=4x fewer than thread-per-element at M=64) with no
//! M=1 throughput cliff, and records the `sched.{tasks,parks,steals,
//! polls}` counters.
//!
//! A **queue-architecture** section (schema 4, three arms as of
//! schema 8) pits the lock-free Chase-Lev scheduler (per-worker
//! lock-free deques + batched injector drains + batch stealing, the
//! default) against the schema-4 mutex-deque work-stealing pool AND the
//! shared-single-queue comparator pool on the steal-heavy M=64 density
//! workload and a fan-in workload (P sources -> one multi-pad
//! collector, the batch-wakeup shape). All three arms run on detached
//! pools so the comparison is independent of `EDGEPIPE_SCHED_QUEUE`
//! (which picks the GLOBAL pool's architecture for every other
//! scenario — the CI matrix runs the whole bench under chaselev and
//! shared). Gates: mutex-stealing M=64 throughput must not regress vs
//! the shared queue, Chase-Lev M=64 throughput must not regress vs the
//! mutex-deque pool (>= 1.0x nominal, 0.9x CI floor), ready-queue lock
//! WAITS per delivered item must drop vs shared and be ~0 (<= 0.01) on
//! the Chase-Lev arm — its hot path acquires no mutex — and fan-in
//! delivery must conserve every buffer on every arm. Emits the
//! `sched.{steals,local_hits,injector_hits,stolen_tasks}` split
//! (accumulated over the Chase-Lev runs).
//!
//! A **batching** section (schema 5) gates cross-pipeline adaptive
//! inference batching: M=64 pipelines share one model behind a
//! `BatchCollector` (simulated accelerator with a fixed per-dispatch
//! cost) vs the same M pipelines running unbatched single-frame
//! dispatches. Gates: batched throughput-per-model >= 1.5x unbatched
//! nominal (>= 1.2x CI floor), mean batch size > 1, and M=1 batched
//! within 5% of unbatched nominal (>= 0.8x CI floor — the adaptive
//! target must add no latency when there is nothing to coalesce).
//!
//! A **correlated-frame** section (schema 7) gates the stateful per-link
//! codec stack: a sequence of individually-incompressible frames that
//! are nearly identical frame-to-frame (static scene + noise floor) is
//! round-tripped through `LinkCodec`/`LinkDecoder` pairs per arm. Gates:
//! the delta chain's bytes-on-wire <= 0.6x plain per-frame zlib with
//! round-trip fps >= 1.0x zlib, `Codec::Auto` must converge onto the
//! delta arm on that stream (its last emitted frame carries the delta
//! codec byte) while the existing adaptation gate keeps it at
//! pass-through on uncorrelated noise, and the sparse COO link must
//! beat dense+zlib where index/value pairs win (0.02% scatter — below
//! deflate's zero-run floor) and never exceed the raw dense payload
//! at 10%.
//!
//! A **many-subscriber** section (schema 6) gates the sharded
//! subscription-trie router: the `Router` is driven in-process (100k
//! real sockets are infeasible) at `EDGEPIPE_BENCH_SUBS` subscription
//! counts (default 1k/10k/100k; CI runs 1k/8k). Gates: per-publish cost
//! on exact-match topics grows <= 1.3x from the smallest to the largest
//! count (flat cost in TOTAL subscriptions — the pre-trie broker was
//! linear), and a wildcard-heavy mix must route >= 2x faster than an
//! in-bench flat-list replica of the pre-trie `matches()` scan. The
//! broker fan-out section runs against a multi-shard broker so the
//! deflates-per-published-frame == 1 invariant is proven across shards.
//!
//! Emits `BENCH_wirepath.json` (path override: `EDGEPIPE_BENCH_OUT`) so
//! the perf trajectory is tracked across PRs. Knobs: `EDGEPIPE_BENCH_SECS`
//! (window per case) and `EDGEPIPE_BENCH_RUNS` (best-of-N).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use edgepipe::bench::{self, CASES};
use edgepipe::buffer::{bytes_copied, record_copy, Buffer, Bytes};
use edgepipe::caps::Caps;
use edgepipe::element::sched::{self, QueueMode, Scheduler};
use edgepipe::element::{Ctx, Element, Item, Leaky};
use edgepipe::elements::{Identity, Queue, TensorFilter};
use edgepipe::metrics;
use edgepipe::mqtt::packet::{self, Packet};
use edgepipe::mqtt::{Broker, BrokerConfig, ClientOptions, MqttClient, Router};
use edgepipe::pipeline::{ExecMode, Pipeline};
use edgepipe::runtime::{BatchCfg, BatchCollector, InferenceBackend};
use edgepipe::serial::compress::{self, AutoCodec};
use edgepipe::serial::{wire, Codec};
use edgepipe::tensor::{f32_to_bytes, DType, TensorInfo, TensorsInfo};
use edgepipe::util::rng::XorShift64;
use edgepipe::util::write_all_vectored;
use edgepipe::util::Result;

const TOPIC: &str = "bench/wire";

/// Tensor-like payload: small alphabet, long runs — deflates well.
fn compressible_payload(n: usize) -> Vec<u8> {
    (0..n).map(|i| ((i >> 3) & 0x0F) as u8).collect()
}

/// Incompressible payload (pre-compressed-video stand-in).
fn noise_payload(n: usize, seed: u64) -> Vec<u8> {
    let mut v = vec![0u8; n];
    XorShift64::new(seed).fill_bytes(&mut v);
    v
}

/// Correlated sequence: one incompressible base frame plus a small
/// drifting perturbation per frame. Each frame alone is noise to zlib,
/// but nearly identical to its neighbours — a static scene seen through
/// a sensor noise floor, the delta codec's home turf.
fn correlated_sequence(n_frames: usize, len: usize) -> Vec<Buffer> {
    let base = noise_payload(len, 0xBA5E);
    (0..n_frames)
        .map(|i| {
            let mut v = base.clone();
            let mut rng = XorShift64::new(0xD417A + i as u64);
            for _ in 0..(len / 1000).max(1) {
                let at = rng.below(len as u64) as usize;
                v[at] = rng.next_u32() as u8;
            }
            Buffer::new(v).with_pts(i as u64)
        })
        .collect()
}

/// One stateful-link codec arm over a correlated sequence.
struct CodecArm {
    fps: f64,
    bytes_per_frame: f64,
    /// Wire codec byte of the last emitted frame — what `Codec::Auto`
    /// converged to by the end of the window.
    last_wire_codec: u8,
}

/// Round-trip the sequence through one stateful link pair (encode and
/// decode both measured — the honest cost of a hop), cycling the frames
/// until the window elapses.
fn run_codec_arm(codec: Codec, frames: &[Buffer], window: Duration) -> CodecArm {
    let mut enc = wire::LinkCodec::new(codec, "");
    let mut dec = wire::LinkDecoder::new("");
    let (mut n, mut bytes, mut last) = (0u64, 0u64, 0u8);
    let t0 = Instant::now();
    while t0.elapsed() < window {
        for b in frames {
            let wf = enc.encode(b, None).unwrap();
            bytes += wf.len() as u64;
            last = wf.header[6];
            let (out, _) =
                dec.decode(&Bytes::from(wf.to_vec())).unwrap().expect("lossless link");
            // Full memcmp on the first cycle only; afterwards a length
            // check keeps the loop honest without dominating it.
            if n < frames.len() as u64 {
                assert_eq!(&out.data[..], &b.data[..]);
            } else {
                assert_eq!(out.len(), b.len());
            }
            std::hint::black_box(&out);
            n += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    CodecArm {
        fps: n as f64 / secs,
        bytes_per_frame: bytes as f64 / n.max(1) as f64,
        last_wire_codec: last,
    }
}

/// Mean bytes-on-wire at one sparse density: the COO link vs plain
/// dense+zlib frames of the same payloads. Returns (coo, dense_zlib).
fn sparse_bytes_at(n_elems: usize, density: f64) -> (f64, f64) {
    let info = TensorsInfo::one(TensorInfo::new(DType::F32, &[n_elems as u32]).unwrap());
    let caps = Caps::tensors(&info);
    let mut rng = XorShift64::new(0x5BA2 + (density * 1e6) as u64);
    let mut enc = wire::LinkCodec::new(Codec::Sparse, "");
    let mut dec = wire::LinkDecoder::new("");
    let frames = 8u64;
    let (mut coo, mut zlib) = (0u64, 0u64);
    for f in 0..frames {
        let mut vals = vec![0.0f32; n_elems];
        for _ in 0..((n_elems as f64 * density) as usize).max(1) {
            let at = rng.below(n_elems as u64) as usize;
            vals[at] = rng.normal();
        }
        let buf = Buffer::new(f32_to_bytes(&vals)).with_pts(f);
        let wf = enc.encode(&buf, Some(&caps)).unwrap();
        coo += wf.len() as u64;
        let (out, _) =
            dec.decode(&Bytes::from(wf.to_vec())).unwrap().expect("sparse frames stand alone");
        assert_eq!(&out.data[..], &buf.data[..]);
        zlib += wire::encode_vectored(&buf, Some(&caps), Codec::Zlib).unwrap().len() as u64;
    }
    (coo as f64 / frames as f64, zlib as f64 / frames as f64)
}

/// One measured hop mode.
struct HopResult {
    fps: f64,
    /// Counted payload-bytes copied per frame, normalised by payload size.
    copies_per_frame: f64,
}

/// Zero-copy hop: vectored encode/publish, shared-view read/decode.
/// For `Codec::Zlib` the encode deflates in place into one allocation and
/// the decode streams the inflater out of the received view.
fn run_zero_copy(buf: &Buffer, caps: &Caps, codec: Codec, window: Duration) -> HopResult {
    let payload_len = buf.len() as f64;
    let mut sink: Vec<u8> = Vec::with_capacity(buf.len() + 256);
    let mut frames = 0u64;
    let copied0 = bytes_copied();
    let t0 = Instant::now();
    while t0.elapsed() < window {
        sink.clear();
        let wf = wire::encode_vectored(buf, Some(caps), codec).unwrap();
        let head = packet::publish_head(TOPIC, 0, false, false, None, wf.len()).unwrap();
        write_all_vectored(
            &mut sink,
            &[head.as_slice(), wf.header.as_slice(), wf.payload.as_slice()],
        )
        .unwrap();
        // Receive side: one body allocation, then slice views (and for
        // compressed frames one streamed inflate allocation).
        let mut cur = std::io::Cursor::new(&sink[..]);
        let pkt = Packet::read(&mut cur).unwrap();
        let Packet::Publish { payload, .. } = pkt else { panic!("expected publish") };
        let (out, _caps) = wire::decode_shared(&payload).unwrap();
        assert_eq!(out.len(), buf.len());
        std::hint::black_box(&out);
        frames += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let copied = (bytes_copied() - copied0) as f64;
    HopResult { fps: frames as f64 / secs, copies_per_frame: copied / frames as f64 / payload_len }
}

/// Baseline hop: replica of the pre-refactor copy pipeline, every payload
/// copy counted. Produces byte-identical wire traffic to the zero-copy
/// mode (for `Codec::Zlib` the copies are of the compressed bytes, as the
/// seed code did).
fn run_baseline(buf: &Buffer, caps: &Caps, codec: Codec, window: Duration) -> HopResult {
    let payload_len = buf.len() as f64;
    let mut sink: Vec<u8> = Vec::with_capacity(buf.len() + 256);
    let mut frames = 0u64;
    let copied0 = bytes_copied();
    let t0 = Instant::now();
    while t0.elapsed() < window {
        sink.clear();
        // wire::encode, seed behavior: compress() into a fresh buffer
        // (copy 1 into the frame below), then extend into the frame
        // (copy 2).
        let wf = wire::encode_vectored(buf, Some(caps), codec).unwrap();
        let compressed = wf.payload.to_vec_counted();
        let mut frame = Vec::with_capacity(wf.len());
        frame.extend_from_slice(&wf.header);
        record_copy(compressed.len());
        frame.extend_from_slice(&compressed);
        // MqttClient::publish, seed behavior: payload.to_vec() (copy 3).
        record_copy(frame.len());
        let owned = frame.to_vec();
        // Packet::encode, seed behavior: body assembly (copy 4) + body
        // into the final packet (copy 5).
        let mut body = Vec::with_capacity(2 + TOPIC.len() + owned.len());
        body.extend_from_slice(&(TOPIC.len() as u16).to_be_bytes());
        body.extend_from_slice(TOPIC.as_bytes());
        record_copy(owned.len());
        body.extend_from_slice(&owned);
        sink.push(0x30);
        packet::put_remaining(&mut sink, body.len());
        record_copy(body.len());
        sink.extend_from_slice(&body);
        // Receive side, seed behavior: read body, copy the payload out of
        // it (copy 6), then wire::decode copies/inflates the payload
        // again (7).
        let mut cur = std::io::Cursor::new(&sink[..]);
        let mut first = [0u8; 1];
        std::io::Read::read_exact(&mut cur, &mut first).unwrap();
        let mut rem = 0usize;
        let mut shift = 0u32;
        loop {
            let mut b = [0u8; 1];
            std::io::Read::read_exact(&mut cur, &mut b).unwrap();
            rem |= ((b[0] & 0x7f) as usize) << shift;
            if b[0] & 0x80 == 0 {
                break;
            }
            shift += 7;
        }
        let mut body_in = vec![0u8; rem];
        std::io::Read::read_exact(&mut cur, &mut body_in).unwrap();
        let tlen = u16::from_be_bytes([body_in[0], body_in[1]]) as usize;
        let frame_region = &body_in[2 + tlen..];
        record_copy(frame_region.len());
        let frame_in = frame_region.to_vec();
        // wire::decode (compat) itself counts its payload copy-out.
        let (out, _caps) = wire::decode(&frame_in).unwrap();
        assert_eq!(out.len(), buf.len());
        std::hint::black_box(&out);
        frames += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let copied = (bytes_copied() - copied0) as f64;
    HopResult { fps: frames as f64 / secs, copies_per_frame: copied / frames as f64 / payload_len }
}

/// Best-of-N pair of (zero-copy, baseline) for one scenario.
fn run_pair(
    buf: &Buffer,
    caps: &Caps,
    codec: Codec,
    window: Duration,
    runs: u64,
) -> (HopResult, HopResult) {
    let mut zc = HopResult { fps: 0.0, copies_per_frame: f64::NAN };
    let mut base = HopResult { fps: 0.0, copies_per_frame: f64::NAN };
    for _ in 0..runs {
        let z = run_zero_copy(buf, caps, codec, window);
        if z.fps > zc.fps {
            zc = z;
        }
        let b = run_baseline(buf, caps, codec, window);
        if b.fps > base.fps {
            base = b;
        }
    }
    (zc, base)
}

struct FanoutResult {
    subscribers: usize,
    delivered_fps: f64,
    copies_per_delivered_frame: f64,
    /// Deflate operations per *published* frame (NaN for Codec::None).
    deflates_per_published_frame: f64,
}

/// Routing shards for the broker fan-out section: multi-shard even on
/// small CI runners, so the compress-once audit crosses shard locks.
const FANOUT_SHARDS: usize = 4;

/// Real broker fan-out: 1 publisher, N subscribers, shared encoded frame,
/// multi-shard routing core.
fn run_broker_fanout(
    w: u32,
    h: u32,
    n_subs: usize,
    codec: Codec,
    window: Duration,
) -> FanoutResult {
    let broker = Broker::start_with(
        "127.0.0.1:0",
        BrokerConfig { shards: FANOUT_SHARDS, ..Default::default() },
    )
    .unwrap();
    assert_eq!(broker.shard_count(), FANOUT_SHARDS);
    let addr = broker.addr().to_string();
    let received = Arc::new(AtomicU64::new(0));
    let mut subs = Vec::new();
    let mut drainers = Vec::new();
    for i in 0..n_subs {
        let c = MqttClient::connect(
            &addr,
            ClientOptions { client_id: format!("wiresub-{i}"), ..Default::default() },
        )
        .unwrap();
        let rx = c.subscribe(TOPIC).unwrap();
        let counter = received.clone();
        drainers.push(std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                std::hint::black_box(msg.payload.len());
                counter.fetch_add(1, Ordering::Relaxed);
            }
        }));
        subs.push(c);
    }
    let publ = MqttClient::connect(
        &addr,
        ClientOptions { client_id: "wirepub".into(), ..Default::default() },
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(200)); // subscriptions land

    let payload_len = (w * h * 3) as usize;
    let data = match codec {
        Codec::None => vec![0xC3u8; payload_len],
        _ => compressible_payload(payload_len),
    };
    let buf = Buffer::new(data).with_pts(0);
    let caps = Caps::video(w, h, 60);
    let copied0 = bytes_copied();
    let deflates0 = compress::deflate_ops();
    let mut published = 0u64;
    let t0 = Instant::now();
    while t0.elapsed() < window {
        let wf = wire::encode_vectored(&buf, Some(&caps), codec).unwrap();
        if publ.publish_frame(TOPIC, &wf, false).is_err() {
            break;
        }
        published += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let deflates = compress::deflate_ops() - deflates0;
    // fps uses only deliveries that landed inside the publish window;
    // the drain below exists so the copy audit sees every frame.
    let delivered_in_window = received.load(Ordering::Relaxed);
    std::thread::sleep(Duration::from_millis(300)); // let deliveries drain
    let copied = (bytes_copied() - copied0) as f64;
    publ.disconnect();
    for c in &subs {
        c.disconnect();
    }
    for d in drainers {
        let _ = d.join();
    }
    let delivered_total = received.load(Ordering::Relaxed);
    FanoutResult {
        subscribers: n_subs,
        delivered_fps: delivered_in_window as f64 / secs,
        copies_per_delivered_frame: if delivered_total == 0 {
            f64::NAN
        } else {
            copied / delivered_total as f64 / payload_len as f64
        },
        deflates_per_published_frame: if codec == Codec::None || published == 0 {
            f64::NAN
        } else {
            deflates as f64 / published as f64
        },
    }
}

/// Drive the adaptive codec: noise must switch a link to pass-through,
/// and a later compressible phase must switch it back via the probe.
fn run_auto_adaptation(w: u32, h: u32) -> (bool, bool) {
    let payload_len = (w * h * 3) as usize;
    let caps = Caps::video(w, h, 60);
    let mut auto = AutoCodec::new("bench.auto");
    let noise = Buffer::new(noise_payload(payload_len, 0xBEEF));
    for _ in 0..16 {
        let wf = wire::encode_vectored_auto(&noise, Some(&caps), &mut auto).unwrap();
        std::hint::black_box(wf.len());
    }
    let disabled_on_noise = !auto.is_compressing();
    let tensorish = Buffer::new(compressible_payload(payload_len));
    for _ in 0..(auto.probe_interval + 4) {
        let wf = wire::encode_vectored_auto(&tensorish, Some(&caps), &mut auto).unwrap();
        std::hint::black_box(wf.len());
    }
    let reenabled_on_tensor = auto.is_compressing();
    (disabled_on_noise, reenabled_on_tensor)
}

// ---------------------------------------------------------------------------
// Density scenario (schema 3): M pipelines x 6 elements on K pool workers.
// The worker-pool scheduler must keep resident thread count at K while the
// thread-per-element runner burns M x 6, with no M=1 throughput cliff.
// ---------------------------------------------------------------------------

/// Unthrottled compute source: one small buffer per `produce` call.
struct DensitySrc;

impl Element for DensitySrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        ctx.push_buffer(Buffer::new(vec![0u8; 64]))?;
        Ok(true)
    }
}

/// Counting compute sink.
struct DensitySink {
    count: Arc<AtomicU64>,
}

impl Element for DensitySink {
    fn n_src_pads(&self) -> usize {
        0
    }

    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<()> {
        if item.is_buffer() {
            self.count.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

/// src ! identity ! queue ! identity ! identity ! sink — six all-compute
/// elements, the paper's "several filters between capture and sink" shape.
fn density_pipeline(count: Arc<AtomicU64>) -> Pipeline {
    let mut p = Pipeline::new();
    let s = p.add("src", Box::new(DensitySrc)).unwrap();
    let f1 = p.add("f1", Box::new(Identity)).unwrap();
    let q = p.add("q", Box::new(Queue::new(16, Leaky::No))).unwrap();
    let f2 = p.add("f2", Box::new(Identity)).unwrap();
    let f3 = p.add("f3", Box::new(Identity)).unwrap();
    let k = p.add("sink", Box::new(DensitySink { count })).unwrap();
    for (a, b) in [(s, f1), (f1, q), (q, f2), (f2, f3), (f3, k)] {
        p.link(a, b).unwrap();
    }
    p
}

/// Run M copies for `window`; returns (resident-thread delta over the
/// pre-start baseline while running, delivered buffers/sec).
fn run_density(m: usize, mode: ExecMode, window: Duration) -> (u64, f64) {
    let before = metrics::thread_count().expect("/proc/self/status Threads:");
    let counts: Vec<Arc<AtomicU64>> = (0..m).map(|_| Arc::new(AtomicU64::new(0))).collect();
    let runnings: Vec<_> = counts
        .iter()
        .map(|c| density_pipeline(c.clone()).start_mode(mode).unwrap())
        .collect();
    std::thread::sleep(window);
    let during = metrics::thread_count().expect("/proc/self/status Threads:");
    let delivered: u64 = counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    for r in runnings {
        let _ = r.stop(Duration::from_secs(10));
    }
    (during.saturating_sub(before), delivered as f64 / window.as_secs_f64())
}

/// Like [`run_density`] but pinned to a specific pool (queue-architecture
/// comparison). Returns (delivered buffers/sec, delivered buffers).
fn run_density_on(m: usize, pool: &Arc<Scheduler>, window: Duration) -> (f64, u64) {
    let counts: Vec<Arc<AtomicU64>> = (0..m).map(|_| Arc::new(AtomicU64::new(0))).collect();
    let runnings: Vec<_> = counts
        .iter()
        .map(|c| density_pipeline(c.clone()).start_pooled_on(pool).unwrap())
        .collect();
    std::thread::sleep(window);
    let delivered: u64 = counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    for r in runnings {
        let _ = r.stop(Duration::from_secs(10));
    }
    (delivered as f64 / window.as_secs_f64(), delivered)
}

// ---------------------------------------------------------------------------
// Queue-architecture scenario (schema 4): steal-heavy + fan-in workloads,
// work-stealing deques vs the shared-single-queue comparator.
// ---------------------------------------------------------------------------

/// Bounded compute source for the fan-in workload.
struct BoundedSrc {
    n: u64,
    sent: u64,
}

impl Element for BoundedSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }
    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> edgepipe::util::Result<()> {
        unreachable!()
    }
    fn produce(&mut self, ctx: &mut Ctx) -> edgepipe::util::Result<bool> {
        if self.sent >= self.n {
            return Ok(false);
        }
        ctx.push_buffer(Buffer::new(vec![0u8; 64]))?;
        self.sent += 1;
        Ok(true)
    }
}

/// Multi-pad counting collector (the fan-in consumer).
struct FanInCollector {
    pads: usize,
    count: Arc<AtomicU64>,
}

impl Element for FanInCollector {
    fn n_sink_pads(&self) -> usize {
        self.pads
    }
    fn n_src_pads(&self) -> usize {
        0
    }
    fn sink_queue_cfg(&self, _: usize) -> edgepipe::element::QueueCfg {
        edgepipe::element::QueueCfg { capacity: 4, leaky: Leaky::No }
    }
    fn handle(&mut self, _pad: usize, item: Item, _: &mut Ctx) -> edgepipe::util::Result<()> {
        if item.is_buffer() {
            self.count.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

const FANIN_PIPELINES: usize = 16;
const FANIN_SOURCES: usize = 8;
const FANIN_BUFS: u64 = 400;

/// M fan-in pipelines (P bounded sources -> one P-pad collector) run to
/// EOS on `pool`; panics if any buffer is lost (batch-wakeup
/// conservation). Returns delivered items/sec.
fn run_fanin_on(pool: &Arc<Scheduler>) -> f64 {
    let t0 = Instant::now();
    let mut counts = Vec::new();
    let mut runnings = Vec::new();
    for _ in 0..FANIN_PIPELINES {
        let count = Arc::new(AtomicU64::new(0));
        let mut p = Pipeline::new();
        let c = p
            .add("collect", Box::new(FanInCollector { pads: FANIN_SOURCES, count: count.clone() }))
            .unwrap();
        for i in 0..FANIN_SOURCES {
            let s = p.add(&format!("src{i}"), Box::new(BoundedSrc { n: FANIN_BUFS, sent: 0 })).unwrap();
            p.link_pads(s, 0, c, i).unwrap();
        }
        runnings.push(p.start_pooled_on(pool).unwrap());
        counts.push(count);
    }
    for r in runnings {
        assert_eq!(
            r.wait_eos(Duration::from_secs(120)),
            edgepipe::pipeline::WaitOutcome::Eos,
            "fan-in pipeline wedged (lost wakeup)"
        );
    }
    let secs = t0.elapsed().as_secs_f64();
    let expect = FANIN_SOURCES as u64 * FANIN_BUFS;
    for c in &counts {
        assert_eq!(
            c.load(Ordering::Relaxed),
            expect,
            "fan-in lost buffers under batched wakeups"
        );
    }
    (FANIN_PIPELINES as u64 * expect) as f64 / secs
}

/// Snapshot of the ready-queue lock counters.
fn lock_snapshot() -> (u64, u64) {
    let g = metrics::global();
    (g.counter("sched.queue_locks").count(), g.counter("sched.lock_waits").count())
}

/// Let the previously measured pool finish its post-teardown bookkeeping
/// (each worker runs one last counted empty scan before sleeping) so the
/// process-global counter deltas attribute to the right architecture.
fn quiesce() {
    std::thread::sleep(Duration::from_millis(50));
}

/// Snapshot of the dequeue-source counters
/// (local/injector/steals/stolen_tasks).
fn dequeue_snapshot() -> (u64, u64, u64, u64) {
    let g = metrics::global();
    (
        g.counter("sched.local_hits").count(),
        g.counter("sched.injector_hits").count(),
        g.counter("sched.steals").count(),
        g.counter("sched.stolen_tasks").count(),
    )
}

// ---------------------------------------------------------------------------
// Cross-pipeline batching scenario (schema 5): M pipelines share one model
// behind a BatchCollector vs per-frame unbatched dispatch of the same work.
// ---------------------------------------------------------------------------

const BATCH_LABEL: &str = "bench_sim";
/// Per-`infer_batch`-call overhead, the cost batching amortises (a PJRT
/// dispatch / accelerator launch stand-in).
const DISPATCH_SPIN: u64 = 20_000;
/// Per-frame compute inside a dispatch.
const FRAME_SPIN: u64 = 2_000;

fn spin(iters: u64) {
    let mut acc = 0u64;
    for i in 0..iters {
        acc = acc.wrapping_add(std::hint::black_box(i));
    }
    std::hint::black_box(acc);
}

/// Simulated accelerator: a fixed dispatch cost per `infer_batch` call
/// plus a small per-frame cost, echoing payloads. Counts calls and frames
/// so the bench can report the realised mean batch size.
struct SimAccel {
    dispatches: Arc<AtomicU64>,
    frames: Arc<AtomicU64>,
}

impl InferenceBackend for SimAccel {
    fn label(&self) -> &str {
        "sim-accel"
    }

    fn negotiate(&mut self, incoming: &Caps) -> Result<Caps> {
        Ok(incoming.clone())
    }

    fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
        spin(DISPATCH_SPIN);
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.frames.fetch_add(inputs.len() as u64, Ordering::Relaxed);
        let mut out = Vec::with_capacity(inputs.len());
        for b in inputs {
            spin(FRAME_SPIN);
            out.push(b.to_vec());
        }
        Ok(out)
    }
}

/// Unthrottled source that emits sticky caps before flooding frames
/// (`tensor_filter` rejects buffers before caps).
struct InferSrc {
    caps_sent: bool,
}

impl Element for InferSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }
    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }
    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        if !self.caps_sent {
            self.caps_sent = true;
            ctx.push_caps(Caps::any())?;
            return Ok(true);
        }
        ctx.push_buffer(Buffer::new(vec![0u8; 64]))?;
        Ok(true)
    }
}

/// M src ! tensor_filter ! sink pipelines on the worker pool for `window`.
/// The batched arm shares ONE collector (max_batch=64, 2ms budget) across
/// all M filters; the unbatched arm gives each filter its own direct
/// SimAccel, paying the dispatch cost per frame. Returns (delivered
/// frames/sec, mean frames per `infer_batch` call).
fn run_batching(m: usize, batched: bool, window: Duration) -> (f64, f64) {
    let dispatches = Arc::new(AtomicU64::new(0));
    let frames = Arc::new(AtomicU64::new(0));
    let mk = || SimAccel { dispatches: dispatches.clone(), frames: frames.clone() };
    let collector = if batched {
        Some(BatchCollector::new(
            BATCH_LABEL,
            Box::new(mk()),
            BatchCfg { max_batch: 64, timeout: Duration::from_millis(2) },
        ))
    } else {
        None
    };
    let counts: Vec<Arc<AtomicU64>> = (0..m).map(|_| Arc::new(AtomicU64::new(0))).collect();
    let runnings: Vec<_> = counts
        .iter()
        .map(|c| {
            let mut p = Pipeline::new();
            let s = p.add("src", Box::new(InferSrc { caps_sent: false })).unwrap();
            let filter = match &collector {
                Some(col) => TensorFilter::batched(col.clone()),
                None => TensorFilter::new(Box::new(mk())),
            };
            let f = p.add("filter", Box::new(filter)).unwrap();
            let k = p.add("sink", Box::new(DensitySink { count: c.clone() })).unwrap();
            p.link(s, f).unwrap();
            p.link(f, k).unwrap();
            p.start_mode(ExecMode::Pool).unwrap()
        })
        .collect();
    std::thread::sleep(window);
    let delivered: u64 = counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    for r in runnings {
        let _ = r.stop(Duration::from_secs(10));
    }
    let d = dispatches.load(Ordering::Relaxed);
    let fr = frames.load(Ordering::Relaxed);
    let mean_batch = if d == 0 { f64::NAN } else { fr as f64 / d as f64 };
    (delivered as f64 / window.as_secs_f64(), mean_batch)
}

/// Publish counts for the many-subscriber section (fixed-iteration, not
/// windowed: per-publish cost is the measurand). The flat-list arm is
/// O(subscriptions) per publish, so it gets far fewer iterations.
const EXACT_PUBLISHES: u64 = 20_000;
const MIXED_TRIE_PUBLISHES: u64 = 10_000;
const MIXED_FLAT_PUBLISHES: u64 = 400;

fn json_case(
    label: &str,
    kind: &str,
    w: u32,
    h: u32,
    payload: usize,
    zc: &HopResult,
    base: &HopResult,
) -> String {
    format!(
        concat!(
            "    {{\"case\": \"{}\", \"payload\": \"{}\", \"width\": {}, \"height\": {}, ",
            "\"payload_bytes\": {}, \"zero_copy_fps\": {:.1}, ",
            "\"baseline_fps\": {:.1}, \"speedup\": {:.3}, ",
            "\"zero_copy_payload_copies_per_frame\": {:.3}, ",
            "\"baseline_payload_copies_per_frame\": {:.3}}}"
        ),
        label.chars().next().unwrap(),
        kind,
        w,
        h,
        payload,
        zc.fps,
        base.fps,
        zc.fps / base.fps.max(1e-9),
        zc.copies_per_frame,
        base.copies_per_frame,
    )
}

fn main() {
    // Pin the pool size before the scheduler first spins up so the
    // density scenario is deterministic across machines.
    if std::env::var("EDGEPIPE_WORKERS").is_err() {
        std::env::set_var("EDGEPIPE_WORKERS", "4");
    }
    let secs = bench::secs();
    let runs = bench::runs();
    let window = Duration::from_secs(secs);
    println!("# bench_wirepath — per-hop encode/publish/read/decode, {secs}s x {runs} runs");

    // ---- Codec::None: the PR 1 zero-copy path --------------------------
    let mut rows = Vec::new();
    let mut json_cases = Vec::new();
    let mut h_speedup = 0.0f64;
    let mut h_zero_copies = f64::NAN;
    for (label, w, h) in CASES {
        let payload = (w * h * 3) as usize;
        let buf = Buffer::new(vec![0x5Au8; payload]).with_pts(0).with_duration(16_666_667);
        let caps = Caps::video(w, h, 60);
        let (zc, base) = run_pair(&buf, &caps, Codec::None, window, runs);
        let speedup = zc.fps / base.fps.max(1e-9);
        if label.starts_with('H') {
            h_speedup = speedup;
            h_zero_copies = zc.copies_per_frame;
        }
        rows.push(vec![
            label.to_string(),
            format!("{:.0}", zc.fps),
            format!("{:.0}", base.fps),
            format!("{speedup:.2}x"),
            format!("{:.2}", zc.copies_per_frame),
            format!("{:.2}", base.copies_per_frame),
        ]);
        json_cases.push(json_case(label, "solid", w, h, payload, &zc, &base));
    }
    bench::table(
        "Per-hop wire path — zero-copy vs pre-refactor baseline (Codec::None)",
        &["case", "zero-copy fps", "baseline fps", "speedup", "copies/frame (zc)", "copies/frame (base)"],
        &rows,
    );

    // Acceptance gates: the H case must beat the copy path >=1.5x, and the
    // zero-copy hop must stay at <=2 payload copies per frame.
    assert!(
        h_zero_copies <= 2.0,
        "zero-copy hop copied {h_zero_copies:.2} payloads/frame (budget: 2)"
    );
    assert!(
        h_speedup >= 1.5,
        "H-case speedup {h_speedup:.2}x below the 1.5x acceptance bar"
    );

    // ---- Codec::Zlib: the streaming one-allocation compressed hop ------
    let mut zrows = Vec::new();
    let mut zlib_json = Vec::new();
    let mut h_noise_speedup = 0.0f64;
    for (label, w, h) in CASES {
        let payload = (w * h * 3) as usize;
        let caps = Caps::video(w, h, 60);
        for (kind, data) in [
            ("tensor", compressible_payload(payload)),
            ("noise", noise_payload(payload, 0xA11CE)),
        ] {
            let buf = Buffer::new(data).with_pts(0).with_duration(16_666_667);
            let (zc, base) = run_pair(&buf, &caps, Codec::Zlib, window, runs);
            let speedup = zc.fps / base.fps.max(1e-9);
            if label.starts_with('H') && kind == "noise" {
                h_noise_speedup = speedup;
            }
            // Copy budget: the streaming compressed hop never pays a
            // counted payload copy — one in-place deflate allocation on
            // encode, one streamed inflate allocation on decode.
            assert!(
                zc.copies_per_frame <= 2.0,
                "zlib {label}/{kind}: {:.2} payload copies/frame (budget: 2)",
                zc.copies_per_frame
            );
            assert!(
                base.copies_per_frame > 2.0 || kind == "tensor",
                "zlib baseline replica lost its copies ({label}/{kind}: {:.2})",
                base.copies_per_frame
            );
            zrows.push(vec![
                format!("{label} / {kind}"),
                format!("{:.0}", zc.fps),
                format!("{:.0}", base.fps),
                format!("{speedup:.2}x"),
                format!("{:.2}", zc.copies_per_frame),
                format!("{:.2}", base.copies_per_frame),
            ]);
            zlib_json.push(json_case(label, kind, w, h, payload, &zc, &base));
        }
    }
    bench::table(
        "Compressed hops — streaming one-allocation zlib vs pre-refactor copy path",
        &["case / payload", "zc fps", "baseline fps", "speedup", "copies (zc)", "copies (base)"],
        &zrows,
    );
    // Throughput win: on incompressible H frames the compressed bytes are
    // full-size, so the eliminated copy stages are where the difference
    // shows. Deflate dominates both modes though, so the true ratio sits
    // only modestly above 1.0 — the hard, deterministic gates are the
    // counter-based copy budgets above; this wall-clock ratio only gets a
    // regression tripwire with jitter headroom (short CI windows on
    // shared runners swing several percent).
    assert!(
        h_noise_speedup >= 0.9,
        "zlib H/noise speedup {h_noise_speedup:.2}x — streaming path regressed vs the copy path"
    );

    // ---- Codec::Auto adaptation ----------------------------------------
    let (auto_noise_off, auto_tensor_on) = run_auto_adaptation(CASES[1].1, CASES[1].2);
    assert!(auto_noise_off, "Codec::Auto kept deflating an incompressible link");
    assert!(auto_tensor_on, "Codec::Auto probe failed to re-enable zlib on compressible data");
    println!("\nCodec::Auto: noise link fell back to pass-through, probe re-enabled zlib ✔");

    // ---- Broker fan-out -------------------------------------------------
    let (_, w, h) = CASES[2];
    let fanout = run_broker_fanout(w, h, 4, Codec::None, window);
    let fanout_z = run_broker_fanout(w, h, 4, Codec::Zlib, window);
    bench::table(
        &format!("Broker fan-out (H case, real sockets, {FANOUT_SHARDS} routing shards)"),
        &["codec", "subscribers", "delivered fps", "copies / delivered", "deflates / published"],
        &[
            vec![
                "none".into(),
                fanout.subscribers.to_string(),
                format!("{:.1}", fanout.delivered_fps),
                format!("{:.3}", fanout.copies_per_delivered_frame),
                "-".into(),
            ],
            vec![
                "zlib".into(),
                fanout_z.subscribers.to_string(),
                format!("{:.1}", fanout_z.delivered_fps),
                format!("{:.3}", fanout_z.copies_per_delivered_frame),
                format!("{:.3}", fanout_z.deflates_per_published_frame),
            ],
        ],
    );
    if fanout.copies_per_delivered_frame.is_finite() {
        assert!(
            fanout.copies_per_delivered_frame <= 2.0,
            "broker hop copied {:.2} payloads per delivered frame (budget: 2)",
            fanout.copies_per_delivered_frame
        );
    }
    if fanout_z.copies_per_delivered_frame.is_finite() {
        assert!(
            fanout_z.copies_per_delivered_frame <= 2.0,
            "compressed broker hop copied {:.2} payloads per delivered frame (budget: 2)",
            fanout_z.copies_per_delivered_frame
        );
    }
    // Compress-once invariant: the publisher deflates each frame exactly
    // once; the broker fans the compressed body out without touching it.
    assert!(
        (fanout_z.deflates_per_published_frame - 1.0).abs() < 1e-9,
        "expected exactly 1 deflate per published frame, got {:.3}",
        fanout_z.deflates_per_published_frame
    );

    // ---- Density: N pipelines on K workers ------------------------------
    // Spin ALL pools up BEFORE taking thread baselines so their workers
    // (which persist for the process lifetime) never pollute the deltas:
    // the global pool plus the three detached queue-architecture arms
    // (Chase-Lev / mutex-stealing / shared) compared below.
    let workers = sched::global().workers() as u64;
    let shared_pool = Scheduler::start_detached(workers as usize, QueueMode::Shared);
    let mutex_pool = Scheduler::start_detached(workers as usize, QueueMode::Stealing);
    let chase_pool = Scheduler::start_detached(workers as usize, QueueMode::ChaseLev);
    let mut drows = Vec::new();
    let mut density_json = Vec::new();
    let mut m1_ratio = 0.0f64;
    let mut reduction_at_64 = 0.0f64;
    for m in [1usize, 8, 64] {
        // Best-of-N like every other gated case: one noisy window on a
        // shared runner must not trip the throughput tripwire.
        let (mut threaded_delta, mut threaded_fps) = (0u64, 0.0f64);
        let (mut pool_delta, mut pool_fps) = (0u64, 0.0f64);
        for run in 0..runs.max(1) {
            let (td, tf) = run_density(m, ExecMode::Threads, window);
            if run == 0 || tf > threaded_fps {
                threaded_fps = tf;
            }
            threaded_delta = threaded_delta.max(td);
            let (pd, pf) = run_density(m, ExecMode::Pool, window);
            if run == 0 || pf > pool_fps {
                pool_fps = pf;
            }
            pool_delta = pool_delta.max(pd);
        }
        // Acceptance: total resident pipeline threads on the pool path
        // stay at K + #Blocking elements. This six-element chain is
        // all-compute, so the pipelines themselves may add NOTHING
        // beyond the persistent workers.
        assert!(
            pool_delta == 0,
            "pool mode spawned {pool_delta} extra threads for {m} pipelines (expected 0 beyond {workers} workers)"
        );
        let pool_threads = workers + pool_delta;
        let reduction = threaded_delta as f64 / pool_threads as f64;
        if m == 1 {
            m1_ratio = pool_fps / threaded_fps.max(1e-9);
        }
        if m == 64 {
            reduction_at_64 = reduction;
        }
        drows.push(vec![
            m.to_string(),
            threaded_delta.to_string(),
            pool_threads.to_string(),
            format!("{reduction:.1}x"),
            format!("{threaded_fps:.0}"),
            format!("{pool_fps:.0}"),
        ]);
        density_json.push(format!(
            concat!(
                "    {{\"pipelines\": {}, \"threaded_threads\": {}, \"pool_threads\": {}, ",
                "\"thread_reduction\": {:.2}, \"threaded_fps\": {:.1}, \"pool_fps\": {:.1}}}"
            ),
            m, threaded_delta, pool_threads, reduction, threaded_fps, pool_fps,
        ));
    }
    bench::table(
        &format!("Density — M pipelines x 6 elements, thread-per-element vs {workers}-worker pool"),
        &["pipelines", "threads (threaded)", "threads (pool)", "reduction", "fps (threaded)", "fps (pool)"],
        &drows,
    );
    assert!(
        reduction_at_64 >= 4.0,
        "thread reduction at 64 pipelines is {reduction_at_64:.1}x, below the 4x acceptance bar"
    );
    // Single-pipeline throughput must not regress. Nominal target is
    // within 5% of the thread-per-element runner; the hard tripwire keeps
    // jitter headroom for short CI windows on shared runners (the
    // deterministic gates above are the thread-count asserts).
    assert!(
        m1_ratio >= 0.75,
        "pool-mode M=1 throughput is {m1_ratio:.2}x of the threaded runner — scheduler hot path regressed"
    );
    let g = metrics::global();
    let (st, sp, ss, so) = (
        g.counter("sched.tasks").count(),
        g.counter("sched.parks").count(),
        g.counter("sched.steals").count(),
        g.counter("sched.polls").count(),
    );
    println!(
        "\nsched counters: tasks={st} parks={sp} steals={ss} polls={so} (M=1 pool/threaded {m1_ratio:.2}x)"
    );

    // ---- Queue architecture: chaselev vs mutex stealing vs shared -------
    // Steal-heavy M=64 density on each architecture (same K), best-of-N.
    // The shared-queue pool IS the schema-3 scheduler: every wake and
    // every pop through one mutex. The mutex-stealing pool is schema 4:
    // per-worker Mutex<VecDeque> deques. The Chase-Lev pool is the
    // schema-8 default: lock-free deques, batch steals, batched injector
    // drains. All three are detached pools, so the arms stay what they
    // claim to be regardless of EDGEPIPE_SCHED_QUEUE (which selects the
    // global pool's architecture for every other scenario).
    let mut shared_fps = 0.0f64;
    let mut steal_fps = 0.0f64;
    let mut chase_fps = 0.0f64;
    let mut shared_lpi = (0.0f64, 0.0f64); // (queue locks, lock waits) per item
    let mut steal_lpi = (0.0f64, 0.0f64);
    let mut chase_lpi = (0.0f64, 0.0f64);
    // Dequeue-source split accumulated ONLY across Chase-Lev runs: the
    // counters are process-global, so raw totals would be polluted by
    // the comparator arms and the density section above.
    let mut chase_split = (0u64, 0u64, 0u64, 0u64);
    for run in 0..runs.max(1) {
        quiesce();
        let snap = lock_snapshot();
        let (fps, delivered) = run_density_on(64, &shared_pool, window);
        quiesce();
        let now = lock_snapshot();
        if run == 0 || fps > shared_fps {
            shared_fps = fps;
            let items = delivered.max(1) as f64;
            shared_lpi = ((now.0 - snap.0) as f64 / items, (now.1 - snap.1) as f64 / items);
        }
        let snap = lock_snapshot();
        let (fps, delivered) = run_density_on(64, &mutex_pool, window);
        quiesce();
        let now = lock_snapshot();
        if run == 0 || fps > steal_fps {
            steal_fps = fps;
            let items = delivered.max(1) as f64;
            steal_lpi = ((now.0 - snap.0) as f64 / items, (now.1 - snap.1) as f64 / items);
        }
        let snap = lock_snapshot();
        let dsnap = dequeue_snapshot();
        let (fps, delivered) = run_density_on(64, &chase_pool, window);
        quiesce();
        let now = lock_snapshot();
        let dnow = dequeue_snapshot();
        chase_split.0 += dnow.0 - dsnap.0;
        chase_split.1 += dnow.1 - dsnap.1;
        chase_split.2 += dnow.2 - dsnap.2;
        chase_split.3 += dnow.3 - dsnap.3;
        if run == 0 || fps > chase_fps {
            chase_fps = fps;
            let items = delivered.max(1) as f64;
            chase_lpi = ((now.0 - snap.0) as f64 / items, (now.1 - snap.1) as f64 / items);
        }
    }
    // Fan-in (batch-wakeup) workload on each architecture; conservation
    // is asserted inside the runner.
    let fanin_shared_fps = run_fanin_on(&shared_pool);
    quiesce();
    let fanin_steal_fps = run_fanin_on(&mutex_pool);
    quiesce();
    let dsnap = dequeue_snapshot();
    let fanin_chase_fps = run_fanin_on(&chase_pool);
    quiesce();
    let dnow = dequeue_snapshot();
    let (sl, si, ssteal, sbatch) = (
        chase_split.0 + (dnow.0 - dsnap.0),
        chase_split.1 + (dnow.1 - dsnap.1),
        chase_split.2 + (dnow.2 - dsnap.2),
        chase_split.3 + (dnow.3 - dsnap.3),
    );
    bench::table(
        &format!("Queue architecture — M=64 density + fan-in, {workers} workers"),
        &["architecture", "density fps (M=64)", "locks/item", "lock waits/item", "fan-in fps"],
        &[
            vec![
                "shared queue".into(),
                format!("{shared_fps:.0}"),
                format!("{:.3}", shared_lpi.0),
                format!("{:.4}", shared_lpi.1),
                format!("{fanin_shared_fps:.0}"),
            ],
            vec![
                "mutex stealing".into(),
                format!("{steal_fps:.0}"),
                format!("{:.3}", steal_lpi.0),
                format!("{:.4}", steal_lpi.1),
                format!("{fanin_steal_fps:.0}"),
            ],
            vec![
                "chase-lev".into(),
                format!("{chase_fps:.0}"),
                format!("{:.3}", chase_lpi.0),
                format!("{:.4}", chase_lpi.1),
                format!("{fanin_chase_fps:.0}"),
            ],
        ],
    );
    println!(
        "sched dequeue split (chase-lev runs only): local_hits={sl} \
         injector_hits={si} steals={ssteal} stolen_tasks={sbatch} \
         (steals counts successful cross-worker steal visits; \
         stolen_tasks counts every task those visits transferred)"
    );
    // Acceptance: the steal-heavy M=64 case must not regress vs the
    // shared queue. Nominal is >=1.0x; the tripwire keeps jitter headroom
    // for short CI windows on shared runners.
    let arch_ratio = steal_fps / shared_fps.max(1e-9);
    assert!(
        arch_ratio >= 0.9,
        "work-stealing M=64 throughput is {arch_ratio:.2}x of the shared queue — queue architecture regressed"
    );
    let fanin_ratio = fanin_steal_fps / fanin_shared_fps.max(1e-9);
    assert!(
        fanin_ratio >= 0.85,
        "work-stealing fan-in throughput is {fanin_ratio:.2}x of the shared queue"
    );
    // The point of per-worker deques: ready-queue lock acquisitions stop
    // WAITING. Waits-per-item must drop measurably vs the single shared
    // mutex (epsilon absorbs an all-but-uncontended fast machine).
    assert!(
        steal_lpi.1 <= shared_lpi.1 * 0.75 + 0.01,
        "lock waits/item did not drop: stealing {:.4} vs shared {:.4}",
        steal_lpi.1,
        shared_lpi.1
    );
    // Chase-Lev gates (schema 8). Throughput: the lock-free pool must
    // at least match the mutex-deque pool (>=1.0x nominal; the 0.9x
    // tripwire keeps jitter headroom for short CI windows).
    let chase_ratio = chase_fps / steal_fps.max(1e-9);
    assert!(
        chase_ratio >= 0.9,
        "chase-lev M=64 throughput is {chase_ratio:.2}x of the mutex-deque pool — \
         the lock-free hot path regressed"
    );
    let fanin_chase_ratio = fanin_chase_fps / fanin_shared_fps.max(1e-9);
    assert!(
        fanin_chase_ratio >= 0.85,
        "chase-lev fan-in throughput is {fanin_chase_ratio:.2}x of the shared queue"
    );
    // Lock-free means lock-free: the Chase-Lev hot path (own-deque
    // pushes/pops, steals) acquires no mutex, so lock WAITS per
    // delivered item must be ~0 — the only counted locks left are the
    // off-hot-path injector (spawn/teardown, cross-thread wakes).
    assert!(
        chase_lpi.1 <= 0.01,
        "chase-lev lock waits/item is {:.4} — expected ~0 (hot-path dequeues must not lock)",
        chase_lpi.1
    );
    // The steals accounting must still split true cross-worker steals
    // from local/injector hits, and batch transfers must be visible:
    // every steal visit moves at least the task it claims.
    assert!(sl > 0, "chase-lev runs recorded no local dequeues — worker-side wakes misrouted");
    assert!(
        sbatch >= ssteal,
        "stolen_tasks ({sbatch}) < steals ({ssteal}) — batch-steal accounting broken"
    );

    // ---- Cross-pipeline inference batching ------------------------------
    // M=64 pipelines sharing one simulated accelerator through a
    // BatchCollector vs the same pipelines paying the dispatch cost per
    // frame. Best-of-N per arm; flush counters are process-global, so
    // their deltas accumulate across the batched M=64 runs only.
    let mut b64_fps = 0.0f64;
    let mut b64_mean = f64::NAN;
    let mut unb64_fps = 0.0f64;
    let mut b1_fps = 0.0f64;
    let mut unb1_fps = 0.0f64;
    let mut flushes_full = 0u64;
    let mut flushes_timer = 0u64;
    let flush_snapshot = || {
        let g = metrics::global();
        (
            g.counter(&format!("batch.{BATCH_LABEL}.flushes_full")).count(),
            g.counter(&format!("batch.{BATCH_LABEL}.flushes_timer")).count(),
        )
    };
    for run in 0..runs.max(1) {
        let snap = flush_snapshot();
        let (fps, mean) = run_batching(64, true, window);
        let now = flush_snapshot();
        flushes_full += now.0 - snap.0;
        flushes_timer += now.1 - snap.1;
        if run == 0 || fps > b64_fps {
            b64_fps = fps;
            b64_mean = mean;
        }
        let (fps, _) = run_batching(64, false, window);
        unb64_fps = unb64_fps.max(fps);
        let (fps, _) = run_batching(1, true, window);
        b1_fps = b1_fps.max(fps);
        let (fps, _) = run_batching(1, false, window);
        unb1_fps = unb1_fps.max(fps);
    }
    let batch_speedup = b64_fps / unb64_fps.max(1e-9);
    let m1_batch_ratio = b1_fps / unb1_fps.max(1e-9);
    bench::table(
        &format!("Cross-pipeline batching — M pipelines, one shared model, {workers} workers"),
        &["pipelines", "batched fps", "unbatched fps", "speedup", "mean batch"],
        &[
            vec![
                "64".into(),
                format!("{b64_fps:.0}"),
                format!("{unb64_fps:.0}"),
                format!("{batch_speedup:.2}x"),
                format!("{b64_mean:.1}"),
            ],
            vec![
                "1".into(),
                format!("{b1_fps:.0}"),
                format!("{unb1_fps:.0}"),
                format!("{m1_batch_ratio:.2}x"),
                "1.0 (adaptive)".into(),
            ],
        ],
    );
    println!(
        "batch flush split (batched M=64 runs): full={flushes_full} timer={flushes_timer}"
    );
    // Acceptance: amortising the per-dispatch cost across coalesced frames
    // must lift throughput-per-model >=1.5x nominal at M=64; the tripwire
    // keeps jitter headroom for short CI windows on shared runners.
    assert!(
        batch_speedup >= 1.2,
        "M=64 batched throughput is {batch_speedup:.2}x unbatched, below the 1.2x CI floor (1.5x nominal)"
    );
    assert!(
        b64_mean > 1.0,
        "mean batch size {b64_mean:.2} — the collector never coalesced frames"
    );
    // The adaptive dispatch target (min(max_batch, members)) must make
    // M=1 batched indistinguishable from direct dispatch: nominal within
    // 5%, CI floor 0.8x (no waiting-for-a-batch-that-never-fills).
    assert!(
        m1_batch_ratio >= 0.8,
        "M=1 batched throughput is {m1_batch_ratio:.2}x of unbatched — batching added single-stream latency"
    );

    // ---- Many-subscriber routing: sharded trie vs flat-list scan --------
    let counts = bench::manysubs::sub_counts();
    let many_shards = Router::new(0).shard_count();
    let mut exact_ns: Vec<(usize, f64)> = Vec::new();
    for &n in &counts {
        let mut best = f64::INFINITY;
        for _ in 0..runs.max(1) {
            best = best.min(bench::manysubs::run_exact_scaling(n, EXACT_PUBLISHES));
        }
        exact_ns.push((n, best));
    }
    // Wildcard mix at the SECOND count (10k nominal, 8k in CI) — large
    // enough that the flat scan hurts, small enough to measure quickly.
    let mix_n = counts.get(1).copied().unwrap_or(*counts.last().unwrap());
    let mut mix_trie_ns = f64::INFINITY;
    let mut mix_flat_ns = f64::INFINITY;
    for _ in 0..runs.max(1) {
        mix_trie_ns = mix_trie_ns.min(bench::manysubs::run_mixed_trie(mix_n, MIXED_TRIE_PUBLISHES));
        mix_flat_ns = mix_flat_ns.min(bench::manysubs::run_mixed_flat(mix_n, MIXED_FLAT_PUBLISHES));
    }
    let mix_speedup = mix_flat_ns / mix_trie_ns.max(1e-9);
    let mut mrows: Vec<Vec<String>> = exact_ns
        .iter()
        .map(|(n, ns)| {
            vec![n.to_string(), "exact (1 match)".into(), format!("{ns:.0}"), "-".into()]
        })
        .collect();
    mrows.push(vec![
        mix_n.to_string(),
        "wildcard mix (trie)".into(),
        format!("{mix_trie_ns:.0}"),
        format!("{mix_speedup:.1}x vs flat"),
    ]);
    mrows.push(vec![
        mix_n.to_string(),
        "wildcard mix (flat scan)".into(),
        format!("{mix_flat_ns:.0}"),
        "1.0x".into(),
    ]);
    bench::table(
        &format!("Many-subscriber routing — {many_shards}-shard trie router, in-process"),
        &["subscriptions", "workload", "ns / publish", "speedup"],
        &mrows,
    );
    // Acceptance: flat cost in total subscription count. The 200ns
    // epsilon absorbs timer noise on sub-microsecond publishes without
    // weakening the gate at real scale.
    let (n_lo, ns_lo) = exact_ns[0];
    let (n_hi, ns_hi) = *exact_ns.last().unwrap();
    assert!(
        ns_hi <= ns_lo * 1.3 + 200.0,
        "exact-match publish cost grew {:.2}x from {n_lo} to {n_hi} subscriptions \
         ({ns_lo:.0}ns -> {ns_hi:.0}ns; flat-cost bar: 1.3x)",
        ns_hi / ns_lo.max(1e-9),
    );
    assert!(
        mix_speedup >= 2.0,
        "trie routed the wildcard mix only {mix_speedup:.2}x faster than the flat-list \
         scan at {mix_n} subscriptions (bar: 2x)"
    );

    // ---- Correlated-frame link codecs: delta + sparse vs plain zlib -----
    // M-case frames, individually incompressible, nearly identical
    // frame-to-frame. Every arm pays full encode + decode per frame.
    let (_, cw, ch) = CASES[1];
    let clen = (cw * ch * 3) as usize;
    let cframes = correlated_sequence(32, clen);
    let zlib_arm = run_codec_arm(Codec::Zlib, &cframes, window);
    let delta_arm = run_codec_arm(Codec::Delta, &cframes, window);
    let auto_arm = run_codec_arm(Codec::Auto, &cframes, window);
    let delta_bytes_ratio = delta_arm.bytes_per_frame / zlib_arm.bytes_per_frame.max(1e-9);
    let delta_fps_ratio = delta_arm.fps / zlib_arm.fps.max(1e-9);
    let auto_bytes_ratio = auto_arm.bytes_per_frame / zlib_arm.bytes_per_frame.max(1e-9);
    bench::table(
        &format!("Correlated-frame link codecs — M case, {clen} B/frame, round-trip"),
        &["arm", "fps", "bytes/frame", "bytes vs zlib"],
        &[
            vec![
                "zlib (per-frame)".into(),
                format!("{:.0}", zlib_arm.fps),
                format!("{:.0}", zlib_arm.bytes_per_frame),
                "1.000x".into(),
            ],
            vec![
                "delta chain".into(),
                format!("{:.0}", delta_arm.fps),
                format!("{:.0}", delta_arm.bytes_per_frame),
                format!("{delta_bytes_ratio:.3}x"),
            ],
            vec![
                "auto".into(),
                format!("{:.0}", auto_arm.fps),
                format!("{:.0}", auto_arm.bytes_per_frame),
                format!("{auto_bytes_ratio:.3}x"),
            ],
        ],
    );
    let sparse_elems = 200_000usize;
    let sparse_dense_bytes = (sparse_elems * 4) as f64;
    let (coo_lo, zlib_lo) = sparse_bytes_at(sparse_elems, 0.0002);
    let (coo_hi, zlib_hi) = sparse_bytes_at(sparse_elems, 0.10);
    println!(
        "sparse link, {sparse_elems} f32: @0.02% {coo_lo:.0} B/frame (dense+zlib {zlib_lo:.0}); \
         @10% {coo_hi:.0} B/frame (dense+zlib {zlib_hi:.0}, raw dense {sparse_dense_bytes:.0})"
    );
    // Acceptance (CI floors): the delta chain must cut bytes-on-wire hard
    // on a correlated stream (0.6x bar; nominal is <0.1x — keyframes every
    // 16 frames dominate the byte count) without costing round-trip
    // throughput, and Auto must converge onto the delta arm (its last
    // emitted frame carries the delta codec byte).
    assert!(
        delta_bytes_ratio <= 0.6,
        "delta chain emitted {delta_bytes_ratio:.3}x the bytes of per-frame zlib on a \
         correlated stream (bar: 0.6x)"
    );
    assert!(
        delta_fps_ratio >= 1.0,
        "delta chain ran at {delta_fps_ratio:.3}x the round-trip fps of per-frame zlib \
         (bar: 1.0x — the chain must not cost throughput where it saves bytes)"
    );
    assert_eq!(
        auto_arm.last_wire_codec,
        Codec::Delta as u8,
        "Codec::Auto did not converge onto the delta arm on a correlated stream \
         (last wire codec byte: {})",
        auto_arm.last_wire_codec
    );
    // COO must beat dense+zlib where it wins on information content
    // alone: at 0.02% density COO carries ~8 B/nnz while deflate still
    // pays its zero-run floor (~1 B per KB of dense zeros) plus ~6 B per
    // scattered literal. Must also never exceed the raw dense payload at
    // 10% — the analytic density guard's job.
    assert!(
        coo_lo <= zlib_lo,
        "sparse COO frame ({coo_lo:.0} B) lost to dense+zlib ({zlib_lo:.0} B) at 0.02% density"
    );
    assert!(
        coo_hi <= sparse_dense_bytes,
        "sparse COO frame ({coo_hi:.0} B) exceeded the raw dense payload \
         ({sparse_dense_bytes:.0} B) at 10% density"
    );

    let out_path = std::env::var("EDGEPIPE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_wirepath.json".to_string());
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"wirepath\",\n",
            "  \"schema\": 8,\n",
            "  \"status\": \"measured\",\n",
            "  \"global_queue_mode\": \"{}\",\n",
            "  \"secs_per_case\": {},\n",
            "  \"runs\": {},\n",
            "  \"cases\": [\n{}\n  ],\n",
            "  \"zlib_cases\": [\n{}\n  ],\n",
            "  \"auto\": {{\"noise_disables_zlib\": {}, \"probe_reenables_zlib\": {}}},\n",
            "  \"broker_fanout\": {{\"case\": \"H\", \"codec\": \"none\", \"shards\": {}, ",
            "\"subscribers\": {}, ",
            "\"delivered_fps\": {:.1}, \"payload_copies_per_delivered_frame\": {:.3}}},\n",
            "  \"broker_fanout_zlib\": {{\"case\": \"H\", \"codec\": \"zlib\", \"shards\": {}, ",
            "\"subscribers\": {}, ",
            "\"delivered_fps\": {:.1}, \"payload_copies_per_delivered_frame\": {:.3}, ",
            "\"deflates_per_published_frame\": {:.3}}},\n",
            "  \"many_subs\": {{\n",
            "    \"shards\": {},\n",
            "    \"exact\": [{}],\n",
            "    \"exact_growth\": {:.3},\n",
            "    \"wildcard_mix\": {{\"subs\": {}, \"trie_ns_per_publish\": {:.1}, ",
            "\"flat_ns_per_publish\": {:.1}, \"speedup\": {:.2}}}\n",
            "  }},\n",
            "  \"density\": {{\n",
            "    \"workers\": {},\n",
            "    \"elements_per_pipeline\": 6,\n",
            "    \"m1_pool_vs_threaded\": {:.3},\n",
            "    \"cases\": [\n{}\n    ],\n",
            "    \"sched\": {{\"tasks\": {}, \"parks\": {}, \"steals\": {}, \"polls\": {}}}\n",
            "  }},\n",
            "  \"sched_arch\": {{\n",
            "    \"workers\": {},\n",
            "    \"m64_shared_fps\": {:.1},\n",
            "    \"m64_stealing_fps\": {:.1},\n",
            "    \"m64_chaselev_fps\": {:.1},\n",
            "    \"m64_stealing_vs_shared\": {:.3},\n",
            "    \"m64_chaselev_vs_stealing\": {:.3},\n",
            "    \"queue_locks_per_item_shared\": {:.4},\n",
            "    \"queue_locks_per_item_stealing\": {:.4},\n",
            "    \"queue_locks_per_item_chaselev\": {:.4},\n",
            "    \"lock_waits_per_item_shared\": {:.5},\n",
            "    \"lock_waits_per_item_stealing\": {:.5},\n",
            "    \"lock_waits_per_item_chaselev\": {:.5},\n",
            "    \"fanin\": {{\"pipelines\": {}, \"sources\": {}, \"buffers_per_source\": {}, ",
            "\"shared_fps\": {:.1}, \"stealing_fps\": {:.1}, \"chaselev_fps\": {:.1}, ",
            "\"conserved\": true}},\n",
            "    \"sched\": {{\"local_hits\": {}, \"injector_hits\": {}, \"steals\": {}, ",
            "\"stolen_tasks\": {}}}\n",
            "  }},\n",
            "  \"batching\": {{\n",
            "    \"workers\": {},\n",
            "    \"pipelines\": 64,\n",
            "    \"max_batch\": 64,\n",
            "    \"timeout_ms\": 2,\n",
            "    \"m64_batched_fps\": {:.1},\n",
            "    \"m64_unbatched_fps\": {:.1},\n",
            "    \"m64_speedup\": {:.3},\n",
            "    \"m64_mean_batch\": {:.2},\n",
            "    \"m1_batched_fps\": {:.1},\n",
            "    \"m1_unbatched_fps\": {:.1},\n",
            "    \"m1_batched_vs_unbatched\": {:.3},\n",
            "    \"flushes_full\": {},\n",
            "    \"flushes_timer\": {}\n",
            "  }},\n",
            "  \"correlated\": {{\n",
            "    \"case\": \"M\",\n",
            "    \"payload_bytes\": {},\n",
            "    \"zlib\": {{\"fps\": {:.1}, \"bytes_per_frame\": {:.0}}},\n",
            "    \"delta\": {{\"fps\": {:.1}, \"bytes_per_frame\": {:.0}}},\n",
            "    \"auto\": {{\"fps\": {:.1}, \"bytes_per_frame\": {:.0}, ",
            "\"converged_to_delta\": {}}},\n",
            "    \"delta_vs_zlib_bytes\": {:.4},\n",
            "    \"delta_vs_zlib_fps\": {:.3},\n",
            "    \"sparse\": [\n",
            "      {{\"density\": 0.0002, \"elements\": {}, \"coo_bytes_per_frame\": {:.0}, ",
            "\"dense_zlib_bytes_per_frame\": {:.0}}},\n",
            "      {{\"density\": 0.10, \"elements\": {}, \"coo_bytes_per_frame\": {:.0}, ",
            "\"dense_zlib_bytes_per_frame\": {:.0}, \"dense_raw_bytes\": {:.0}}}\n",
            "    ]\n",
            "  }}\n",
            "}}\n"
        ),
        format!("{:?}", sched::global().queue_mode()).to_lowercase(),
        secs,
        runs,
        json_cases.join(",\n"),
        zlib_json.join(",\n"),
        auto_noise_off,
        auto_tensor_on,
        FANOUT_SHARDS,
        fanout.subscribers,
        fanout.delivered_fps,
        fanout.copies_per_delivered_frame,
        FANOUT_SHARDS,
        fanout_z.subscribers,
        fanout_z.delivered_fps,
        fanout_z.copies_per_delivered_frame,
        fanout_z.deflates_per_published_frame,
        many_shards,
        exact_ns
            .iter()
            .map(|(n, ns)| format!("{{\"subs\": {n}, \"ns_per_publish\": {ns:.1}}}"))
            .collect::<Vec<_>>()
            .join(", "),
        ns_hi / ns_lo.max(1e-9),
        mix_n,
        mix_trie_ns,
        mix_flat_ns,
        mix_speedup,
        workers,
        m1_ratio,
        density_json.join(",\n"),
        st,
        sp,
        ss,
        so,
        workers,
        shared_fps,
        steal_fps,
        chase_fps,
        arch_ratio,
        chase_ratio,
        shared_lpi.0,
        steal_lpi.0,
        chase_lpi.0,
        shared_lpi.1,
        steal_lpi.1,
        chase_lpi.1,
        FANIN_PIPELINES,
        FANIN_SOURCES,
        FANIN_BUFS,
        fanin_shared_fps,
        fanin_steal_fps,
        fanin_chase_fps,
        sl,
        si,
        ssteal,
        sbatch,
        workers,
        b64_fps,
        unb64_fps,
        batch_speedup,
        b64_mean,
        b1_fps,
        unb1_fps,
        m1_batch_ratio,
        flushes_full,
        flushes_timer,
        clen,
        zlib_arm.fps,
        zlib_arm.bytes_per_frame,
        delta_arm.fps,
        delta_arm.bytes_per_frame,
        auto_arm.fps,
        auto_arm.bytes_per_frame,
        auto_arm.last_wire_codec == Codec::Delta as u8,
        delta_bytes_ratio,
        delta_fps_ratio,
        sparse_elems,
        coo_lo,
        zlib_lo,
        sparse_elems,
        coo_hi,
        zlib_hi,
        sparse_dense_bytes,
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
