//! Bench harness support (no criterion offline): run transport pipelines
//! for a fixed wall-clock window and report the paper's §5.4 metrics —
//! throughput (fps), CPU usage, memory — as markdown tables.

use std::time::Duration;

use crate::metrics::{self, CpuSampler};

/// The paper's three input-stream bandwidths (Fig 6): QQVGA / VGA / FullHD
/// RGB at 60 Hz.
pub const CASES: [(&str, u32, u32); 3] =
    [("L (QQVGA 160x120)", 160, 120), ("M (VGA 640x480)", 640, 480), ("H (FullHD 1920x1080)", 1920, 1080)];

pub const FPS: u32 = 60;

/// Seconds per measurement (paper: 30 s x 5 runs; scaled for CI via
/// EDGEPIPE_BENCH_SECS).
pub fn secs() -> u64 {
    std::env::var("EDGEPIPE_BENCH_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(5)
}

/// Runs per case (EDGEPIPE_BENCH_RUNS; default 1).
pub fn runs() -> u64 {
    std::env::var("EDGEPIPE_BENCH_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// One measured transport run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub delivered: u64,
    pub offered: u64,
    pub bytes: u64,
    pub secs: f64,
    pub cpu_pct: f64,
    pub rss_growth_kb: i64,
}

impl RunStats {
    pub fn fps(&self) -> f64 {
        if self.secs > 0.0 {
            self.delivered as f64 / self.secs
        } else {
            0.0
        }
    }

    pub fn mbps(&self) -> f64 {
        if self.secs > 0.0 {
            self.bytes as f64 / self.secs / 1e6
        } else {
            0.0
        }
    }
}

/// Measure around a closure: CPU% and RSS growth of this process.
pub fn measured<F: FnOnce() -> (u64, u64, f64)>(f: F) -> RunStats {
    let rss0 = metrics::current_rss_kb().unwrap_or(0) as i64;
    let mut cpu = CpuSampler::start();
    let (delivered, bytes, secs) = f();
    let cpu_pct = cpu.sample();
    let rss1 = metrics::current_rss_kb().unwrap_or(0) as i64;
    RunStats { delivered, offered: 0, bytes, secs, cpu_pct, rss_growth_kb: rss1 - rss0 }
}

/// Print a markdown table.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        println!("| {} |", r.join(" | "));
    }
}

/// Wait for a named appsink counter to settle, returning (count, bytes).
pub fn drain_counter(name: &str, settle: Duration) -> (u64, u64) {
    let c = metrics::global().counter(name);
    let mut last = c.count();
    loop {
        std::thread::sleep(settle);
        let now = c.count();
        if now == last {
            return (now, c.bytes());
        }
        last = now;
    }
}
