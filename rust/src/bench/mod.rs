//! Bench harness support (no criterion offline): run transport pipelines
//! for a fixed wall-clock window and report the paper's §5.4 metrics —
//! throughput (fps), CPU usage, memory — as markdown tables.

use std::time::Duration;

use crate::metrics::{self, CpuSampler};

/// The paper's three input-stream bandwidths (Fig 6): QQVGA / VGA / FullHD
/// RGB at 60 Hz.
pub const CASES: [(&str, u32, u32); 3] =
    [("L (QQVGA 160x120)", 160, 120), ("M (VGA 640x480)", 640, 480), ("H (FullHD 1920x1080)", 1920, 1080)];

pub const FPS: u32 = 60;

/// Seconds per measurement (paper: 30 s x 5 runs; scaled for CI via
/// EDGEPIPE_BENCH_SECS).
pub fn secs() -> u64 {
    std::env::var("EDGEPIPE_BENCH_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(5)
}

/// Runs per case (EDGEPIPE_BENCH_RUNS; default 1).
pub fn runs() -> u64 {
    std::env::var("EDGEPIPE_BENCH_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// One measured transport run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub delivered: u64,
    pub offered: u64,
    pub bytes: u64,
    pub secs: f64,
    pub cpu_pct: f64,
    pub rss_growth_kb: i64,
}

impl RunStats {
    pub fn fps(&self) -> f64 {
        if self.secs > 0.0 {
            self.delivered as f64 / self.secs
        } else {
            0.0
        }
    }

    pub fn mbps(&self) -> f64 {
        if self.secs > 0.0 {
            self.bytes as f64 / self.secs / 1e6
        } else {
            0.0
        }
    }
}

/// Measure around a closure: CPU% and RSS growth of this process.
pub fn measured<F: FnOnce() -> (u64, u64, f64)>(f: F) -> RunStats {
    let rss0 = metrics::current_rss_kb().unwrap_or(0) as i64;
    let mut cpu = CpuSampler::start();
    let (delivered, bytes, secs) = f();
    let cpu_pct = cpu.sample();
    let rss1 = metrics::current_rss_kb().unwrap_or(0) as i64;
    RunStats { delivered, offered: 0, bytes, secs, cpu_pct, rss_growth_kb: rss1 - rss0 }
}

/// Print a markdown table.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        println!("| {} |", r.join(" | "));
    }
}

/// Wait for a named appsink counter to settle, returning (count, bytes).
pub fn drain_counter(name: &str, settle: Duration) -> (u64, u64) {
    let c = metrics::global().counter(name);
    let mut last = c.count();
    loop {
        std::thread::sleep(settle);
        let now = c.count();
        if now == last {
            return (now, c.bytes());
        }
        last = now;
    }
}

/// Many-subscriber routing drivers shared by `bench_wirepath` (gated)
/// and `bench_pubsub` (reported): the sharded trie [`Router`] and a
/// flat-list replica of the pre-trie broker, driven in-process — 100k
/// real sockets are infeasible, and the cost under test is
/// matching/fan-out, not TCP.
pub mod manysubs {
    use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
    use std::time::Instant;

    use crate::buffer::Bytes;
    use crate::mqtt::broker::OutMsg;
    use crate::mqtt::{packet, topic, Router};

    /// Subscription counts (`EDGEPIPE_BENCH_SUBS`, comma-separated;
    /// default "1000,10000,100000", CI uses "1000,8000").
    pub fn sub_counts() -> Vec<usize> {
        std::env::var("EDGEPIPE_BENCH_SUBS")
            .ok()
            .map(|v| {
                v.split(',')
                    .filter_map(|s| s.trim().parse().ok())
                    .filter(|n: &usize| *n > 0)
                    .collect()
            })
            .filter(|v: &Vec<usize>| !v.is_empty())
            .unwrap_or_else(|| vec![1_000, 10_000, 100_000])
    }

    fn drain_outbox(rx: &Receiver<OutMsg>) {
        while rx.try_recv().is_ok() {}
    }

    /// ns/publish with `n` exact-match subscriptions spread over 32
    /// first levels (so every shard holds state). Each publish matches
    /// exactly one subscriber; flat cost means this number must not grow
    /// with `n`.
    pub fn run_exact_scaling(n: usize, publishes: u64) -> f64 {
        let router = Router::new(0);
        let (tx, rx) = sync_channel::<OutMsg>(256);
        for i in 0..n {
            router.session_open(i as u64, format!("s{i}"), tx.clone(), None);
            router.subscribe(i as u64, &format!("e{}/s{i}", i % 32), 0);
        }
        let payload = Bytes::from(vec![0u8; 64]);
        let t0 = Instant::now();
        for _ in 0..publishes {
            let (delivered, _) = router.publish("e0/s0", &payload, false);
            debug_assert_eq!(delivered, 1);
            drain_outbox(&rx);
        }
        t0.elapsed().as_nanos() as f64 / publishes as f64
    }

    /// The wildcard-heavy subscription mix: per 100 subscriptions, 60
    /// exact, 20 `+`-filters, 20 group-`#` filters, all in per-group
    /// namespaces so the match set per publish stays small and constant;
    /// plus a fixed handful of global wildcard subscribers.
    fn mixed_filters(n: usize) -> Vec<String> {
        let mut filters: Vec<String> = (0..n)
            .map(|i| {
                let group = i / 100;
                match i % 100 {
                    0..=59 => format!("g{group}/dev/i{i}"),
                    60..=79 => format!("g{group}/+/i{i}"),
                    _ => format!("g{group}/dev/#"),
                }
            })
            .collect();
        for f in ["#", "+/dev/i0", "g0/#", "+/+/#"] {
            filters.push(f.to_string());
        }
        filters
    }

    fn mixed_topic(k: u64, groups: usize) -> String {
        let g = k as usize % groups;
        // Matches that group's one exact filter + its 20 `#` filters +
        // the constant global wildcards — never the unrelated 99% of the
        // table.
        format!("g{g}/dev/i{}", g * 100)
    }

    /// ns/publish for the wildcard mix through the sharded trie router.
    pub fn run_mixed_trie(n: usize, publishes: u64) -> f64 {
        let router = Router::new(0);
        let (tx, rx) = sync_channel::<OutMsg>(1024);
        for (i, f) in mixed_filters(n).iter().enumerate() {
            router.session_open(i as u64, format!("s{i}"), tx.clone(), None);
            router.subscribe(i as u64, f, 0);
        }
        let groups = (n / 100).max(1);
        let payload = Bytes::from(vec![0u8; 64]);
        let t0 = Instant::now();
        for k in 0..publishes {
            router.publish(&mixed_topic(k, groups), &payload, false);
            drain_outbox(&rx);
        }
        t0.elapsed().as_nanos() as f64 / publishes as f64
    }

    struct FlatSub {
        filter: String,
        conn: u64,
        outbox: SyncSender<OutMsg>,
    }

    /// ns/publish for the same mix through a replica of the pre-trie
    /// broker: encode the head once (that invariant predates the trie),
    /// then scan EVERY subscription's filter with the linear
    /// [`topic::matches`].
    pub fn run_mixed_flat(n: usize, publishes: u64) -> f64 {
        let (tx, rx) = sync_channel::<OutMsg>(1024);
        let subs: Vec<FlatSub> = mixed_filters(n)
            .into_iter()
            .enumerate()
            .map(|(i, filter)| FlatSub { filter, conn: i as u64, outbox: tx.clone() })
            .collect();
        let groups = (n / 100).max(1);
        let payload = Bytes::from(vec![0u8; 64]);
        let t0 = Instant::now();
        for k in 0..publishes {
            let topic_name = mixed_topic(k, groups);
            let head = Bytes::from(
                packet::publish_head(&topic_name, 0, false, false, None, payload.len()).unwrap(),
            );
            let mut matched: Vec<&FlatSub> =
                subs.iter().filter(|s| topic::matches(&s.filter, &topic_name)).collect();
            matched.sort_unstable_by_key(|s| s.conn);
            matched.dedup_by_key(|s| s.conn);
            for s in matched {
                let _ = s
                    .outbox
                    .try_send(OutMsg::Pub { head: head.clone(), payload: payload.clone() });
            }
            drain_outbox(&rx);
        }
        t0.elapsed().as_nanos() as f64 / publishes as f64
    }
}
