//! `Bytes` — a cheaply cloneable, sliceable view into a shared byte
//! allocation (the `bytes::Bytes` idea, dependency-free).
//!
//! This is the currency of the zero-copy transport path: a frame is
//! allocated once per hop (producer `Vec` or socket read) and every
//! downstream consumer — tee fan-out, broker fan-out, wire decode, tensor
//! demux — holds an `(Arc, offset, len)` view into that one allocation.
//!
//! Every place that *must* duplicate payload bytes goes through
//! [`Bytes::copy_from_slice`] or records the copy via [`record_copy`], so
//! the process-wide [`bytes_copied`] counter gives an auditable
//! bytes-copied-per-frame figure (asserted by `bench_wirepath` and the
//! zero-copy invariant tests).

use std::ops::{Bound, Deref, RangeBounds};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::metrics::{shard_slot, COUNTER_SHARDS};

/// One padded lane of the copy counter: every frame on every worker
/// records here, so a single atomic would bounce its cache line across
/// cores (same false-sharing fix as `metrics::Counter` sharding).
#[repr(align(128))]
struct CopyShard(AtomicU64);

#[allow(clippy::declare_interior_mutable_const)] // const used only as an array initializer
const COPY_SHARD_ZERO: CopyShard = CopyShard(AtomicU64::new(0));

/// Process-wide count of payload bytes duplicated by explicit copies,
/// sharded per thread and summed on read (monotonic, not a linearizable
/// snapshot — identical semantics to the relaxed single atomic it
/// replaces).
static COPIED: [CopyShard; COUNTER_SHARDS] = [COPY_SHARD_ZERO; COUNTER_SHARDS];

/// Record `n` payload bytes as copied (for code that copies outside
/// [`Bytes::copy_from_slice`], e.g. legacy/baseline paths).
pub fn record_copy(n: usize) {
    COPIED[shard_slot()].0.fetch_add(n as u64, Ordering::Relaxed);
}

/// Total payload bytes duplicated so far in this process.
pub fn bytes_copied() -> u64 {
    COPIED.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
}

/// A shared, immutable byte slice: `Arc<Vec<u8>>` + offset/len.
///
/// `clone()` and [`slice`](Bytes::slice) are O(1) and never touch the
/// payload. Construction from an owned `Vec<u8>` moves the allocation
/// (no copy); construction from a borrowed slice copies once and counts
/// it.
#[derive(Clone, Default)]
pub struct Bytes {
    data: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl Bytes {
    /// Empty bytes (no allocation shared).
    pub fn new() -> Self {
        Self::default()
    }

    /// Copy a borrowed slice into a fresh allocation (counted).
    pub fn copy_from_slice(src: &[u8]) -> Self {
        record_copy(src.len());
        Bytes::from(src.to_vec())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.off..self.off + self.len]
    }

    /// O(1) sub-view sharing the same backing allocation.
    ///
    /// Panics if the range is out of bounds (mirrors slice indexing).
    pub fn slice(&self, range: impl RangeBounds<usize>) -> Bytes {
        let start = match range.start_bound() {
            Bound::Included(&s) => s,
            Bound::Excluded(&s) => s + 1,
            Bound::Unbounded => 0,
        };
        let end = match range.end_bound() {
            Bound::Included(&e) => e + 1,
            Bound::Excluded(&e) => e,
            Bound::Unbounded => self.len,
        };
        assert!(start <= end && end <= self.len, "Bytes::slice {start}..{end} of {}", self.len);
        Bytes { data: self.data.clone(), off: self.off + start, len: end - start }
    }

    /// Do two views share one backing allocation? (zero-copy assertions)
    pub fn same_backing(&self, other: &Bytes) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Copy out into an owned `Vec` (counted).
    pub fn to_vec_counted(&self) -> Vec<u8> {
        record_copy(self.len);
        self.as_slice().to_vec()
    }
}

impl Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Bytes {
    /// Moves the allocation — zero copy.
    fn from(v: Vec<u8>) -> Self {
        let len = v.len();
        Bytes { data: Arc::new(v), off: 0, len }
    }
}

impl From<&[u8]> for Bytes {
    /// Copies (counted) — prefer `From<Vec<u8>>` on owned data.
    fn from(s: &[u8]) -> Self {
        Bytes::copy_from_slice(s)
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bytes({} bytes", self.len)?;
        if self.off != 0 || self.len != self.data.len() {
            write!(f, " @{}..{} of {}", self.off, self.off + self.len, self.data.len())?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_moves_without_copy() {
        let before = bytes_copied();
        let b = Bytes::from(vec![1u8, 2, 3, 4]);
        assert_eq!(bytes_copied(), before);
        assert_eq!(&b[..], &[1, 2, 3, 4]);
    }

    #[test]
    fn copy_from_slice_is_counted() {
        let before = bytes_copied();
        let b = Bytes::copy_from_slice(&[9u8; 100]);
        assert_eq!(bytes_copied(), before + 100);
        assert_eq!(b.len(), 100);
    }

    #[test]
    fn slice_shares_backing() {
        let b = Bytes::from(vec![0u8, 1, 2, 3, 4, 5]);
        let s = b.slice(2..5);
        assert_eq!(&s[..], &[2, 3, 4]);
        assert!(s.same_backing(&b));
        let s2 = s.slice(1..);
        assert_eq!(&s2[..], &[3, 4]);
        assert!(s2.same_backing(&b));
    }

    #[test]
    fn slice_full_and_empty_ranges() {
        let b = Bytes::from(vec![7u8; 8]);
        assert_eq!(b.slice(..).len(), 8);
        assert_eq!(b.slice(8..8).len(), 0);
        assert_eq!(b.slice(..=3).len(), 4);
        assert!(b.slice(3..3).is_empty());
    }

    #[test]
    #[should_panic]
    fn slice_out_of_bounds_panics() {
        let b = Bytes::from(vec![0u8; 4]);
        let _ = b.slice(2..9);
    }

    #[test]
    fn equality_is_by_content() {
        let a = Bytes::from(vec![1u8, 2, 3]);
        let b = Bytes::from(vec![0u8, 1, 2, 3, 4]).slice(1..4);
        assert_eq!(a, b);
        assert!(!a.same_backing(&b));
    }

    #[test]
    fn clone_is_shallow() {
        let a = Bytes::from(vec![5u8; 1024]);
        let before = bytes_copied();
        let b = a.clone();
        assert_eq!(bytes_copied(), before);
        assert!(a.same_backing(&b));
    }
}
