//! Stream buffers: a zero-copy payload ([`Bytes`]) plus timestamps and
//! transport metadata.
//!
//! Payloads are reference-counted slice views so `tee` fan-out, in-process
//! pub/sub, broker fan-out, and wire decode never copy frame data — the
//! hot path is allocation-free apart from one allocation per hop (the
//! producing element's `Vec` or the receiving socket read).

pub mod bytes;

pub use bytes::{bytes_copied, record_copy, Bytes};

use std::sync::Arc;

use crate::clock::Ns;

/// Metadata attached to a buffer as it crosses elements/devices.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Meta {
    /// Query protocol: which client this buffer belongs to
    /// (`tensor_query_serversrc` tags it; `tensor_query_serversink` routes
    /// on it — §4.2.2).
    pub client_id: Option<u64>,
    /// Per-client request sequence number for response matching.
    pub seq: Option<u64>,
    /// Publisher's pipeline base-time in universal ns (§4.2.3 sync).
    pub remote_base_universal: Option<Ns>,
    /// Ground-truth capture instant in the publisher's universal clock
    /// (stamped by transport sinks; used by mux sync accounting).
    pub capture_universal: Option<Ns>,
    /// Arbitrary source tag (element name of origin device).
    pub origin: Option<Arc<str>>,
}

/// A frame travelling through a pipeline.
#[derive(Debug, Clone)]
pub struct Buffer {
    /// Presentation timestamp: running time of the producing pipeline.
    pub pts: Option<Ns>,
    /// Frame duration (1/fps for live video).
    pub duration: Option<Ns>,
    pub data: Bytes,
    pub meta: Meta,
}

impl Buffer {
    pub fn new(data: Vec<u8>) -> Self {
        Self { pts: None, duration: None, data: data.into(), meta: Meta::default() }
    }

    /// Build from an already-shared payload (transport decode paths).
    pub fn from_bytes(data: Bytes) -> Self {
        Self { pts: None, duration: None, data, meta: Meta::default() }
    }

    pub fn with_pts(mut self, pts: Ns) -> Self {
        self.pts = Some(pts);
        self
    }

    pub fn with_duration(mut self, d: Ns) -> Self {
        self.duration = Some(d);
        self
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Replace the payload, keeping timestamps/meta (transform elements).
    /// Accepts an owned `Vec` (moved, no copy) or a `Bytes` view.
    pub fn map_payload(&self, data: impl Into<Bytes>) -> Buffer {
        Buffer { pts: self.pts, duration: self.duration, data: data.into(), meta: self.meta.clone() }
    }
}

impl PartialEq for Buffer {
    fn eq(&self, other: &Self) -> bool {
        self.pts == other.pts
            && self.duration == other.duration
            && self.data == other.data
            && self.meta == other.meta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_fields() {
        let b = Buffer::new(vec![1, 2, 3]).with_pts(5).with_duration(7);
        assert_eq!(b.pts, Some(5));
        assert_eq!(b.duration, Some(7));
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
    }

    #[test]
    fn clone_shares_payload() {
        let b = Buffer::new(vec![0u8; 1024]);
        let c = b.clone();
        assert!(b.data.same_backing(&c.data));
    }

    #[test]
    fn map_payload_keeps_meta() {
        let mut b = Buffer::new(vec![1]).with_pts(9);
        b.meta.client_id = Some(42);
        let m = b.map_payload(vec![2, 3]);
        assert_eq!(m.pts, Some(9));
        assert_eq!(m.meta.client_id, Some(42));
        assert_eq!(&m.data[..], &[2, 3]);
    }

    #[test]
    fn map_payload_accepts_shared_slice() {
        let b = Buffer::new(vec![1, 2, 3, 4]).with_pts(1);
        let view = b.data.slice(1..3);
        let m = b.map_payload(view);
        assert_eq!(&m.data[..], &[2, 3]);
        assert!(m.data.same_backing(&b.data));
    }

    #[test]
    fn equality_covers_payload() {
        let a = Buffer::new(vec![1, 2]).with_pts(1);
        let b = Buffer::new(vec![1, 2]).with_pts(1);
        let c = Buffer::new(vec![9]).with_pts(1);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
