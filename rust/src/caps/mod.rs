//! Stream capabilities (GSTCAP analog): a media type plus key=value
//! fields, e.g. `video/x-raw,width=300,height=300,format=RGB` or
//! `other/tensors,format=flexible`.
//!
//! Caps travel in-band (a sticky `Item::Caps` precedes buffers) and across
//! devices (mqtt/query transports carry the caps string so the receiving
//! pipeline can negotiate — §4.2.1).

use std::collections::BTreeMap;
use std::fmt;

use crate::tensor::{Format, TensorsInfo};
use crate::util::{Error, Result};

/// Media caps: `media` type plus ordered fields.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Caps {
    pub media: String,
    pub fields: BTreeMap<String, String>,
}

pub const MEDIA_VIDEO: &str = "video/x-raw";
pub const MEDIA_TENSORS: &str = "other/tensors";
pub const MEDIA_FLEXBUF: &str = "other/flexbuf";
pub const MEDIA_ANY: &str = "ANY";

impl Caps {
    pub fn new(media: impl Into<String>) -> Self {
        Self { media: media.into(), fields: BTreeMap::new() }
    }

    /// Wildcard caps compatible with everything (source-agnostic sinks).
    pub fn any() -> Self {
        Self::new(MEDIA_ANY)
    }

    pub fn is_any(&self) -> bool {
        self.media == MEDIA_ANY
    }

    pub fn with(mut self, key: impl Into<String>, value: impl fmt::Display) -> Self {
        self.fields.insert(key.into(), value.to_string());
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn get_u32(&self, key: &str) -> Option<u32> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Parse a caps string. Values may be quoted to protect commas
    /// (`dimensions="4:20:1:1,20:1:1:1"`).
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() {
            return Err(Error::Caps("empty caps string".into()));
        }
        let mut parts = split_unquoted(s, ',');
        let media = parts.remove(0).trim().to_string();
        if media.is_empty() || media.contains('=') {
            return Err(Error::Caps(format!("bad media type in `{s}`")));
        }
        let mut caps = Caps::new(media);
        for p in parts {
            let p = p.trim();
            if p.is_empty() {
                continue;
            }
            let (k, v) = p
                .split_once('=')
                .ok_or_else(|| Error::Caps(format!("field `{p}` missing `=`")))?;
            let v = v.trim().trim_matches('"');
            caps.fields.insert(k.trim().to_string(), v.to_string());
        }
        Ok(caps)
    }

    /// Two caps are compatible if media types match (or either is ANY) and
    /// every field present in BOTH has the same value.
    pub fn compatible(&self, other: &Caps) -> bool {
        if self.is_any() || other.is_any() {
            return true;
        }
        if self.media != other.media {
            return false;
        }
        for (k, v) in &self.fields {
            if let Some(ov) = other.fields.get(k) {
                if ov != v {
                    return false;
                }
            }
        }
        true
    }

    /// Intersection: union of fields from both (must be compatible).
    pub fn intersect(&self, other: &Caps) -> Result<Caps> {
        if !self.compatible(other) {
            return Err(Error::Caps(format!("`{self}` not compatible with `{other}`")));
        }
        if self.is_any() {
            return Ok(other.clone());
        }
        let mut out = self.clone();
        for (k, v) in &other.fields {
            out.fields.entry(k.clone()).or_insert_with(|| v.clone());
        }
        Ok(out)
    }

    // ---- typed helpers -------------------------------------------------

    /// Caps for a raw video stream (format fixed to RGB byte-planes).
    pub fn video(width: u32, height: u32, fps: u32) -> Caps {
        Caps::new(MEDIA_VIDEO)
            .with("format", "RGB")
            .with("width", width)
            .with("height", height)
            .with("framerate", format!("{fps}/1"))
    }

    /// Caps for a static tensors stream.
    pub fn tensors(info: &TensorsInfo) -> Caps {
        Caps::new(MEDIA_TENSORS)
            .with("format", Format::Static.name())
            .with("num_tensors", info.len())
            .with("dimensions", info.dimensions_string())
            .with("types", info.types_string())
    }

    /// Caps for a flexible tensors stream (dynamic schema).
    pub fn tensors_flexible() -> Caps {
        Caps::new(MEDIA_TENSORS).with("format", Format::Flexible.name())
    }

    /// Caps for a sparse tensors stream.
    pub fn tensors_sparse() -> Caps {
        Caps::new(MEDIA_TENSORS).with("format", Format::Sparse.name())
    }

    pub fn is_tensors(&self) -> bool {
        self.media == MEDIA_TENSORS
    }

    pub fn is_video(&self) -> bool {
        self.media == MEDIA_VIDEO
    }

    /// Tensor format of an `other/tensors` caps (default static).
    pub fn tensor_format(&self) -> Result<Format> {
        if !self.is_tensors() {
            return Err(Error::Caps(format!("`{}` is not other/tensors", self.media)));
        }
        match self.get("format") {
            None => Ok(Format::Static),
            Some(f) => Format::parse(f),
        }
    }

    /// Extract the static TensorsInfo from caps fields.
    pub fn tensors_info(&self) -> Result<TensorsInfo> {
        let num = self
            .get_u32("num_tensors")
            .ok_or_else(|| Error::Caps(format!("`{self}` missing num_tensors")))? as usize;
        let dims = self.get("dimensions").ok_or_else(|| Error::Caps("missing dimensions".into()))?;
        let types = self.get("types").ok_or_else(|| Error::Caps("missing types".into()))?;
        TensorsInfo::from_caps_fields(num, dims, types)
    }

    /// Video geometry (width, height, fps).
    pub fn video_geometry(&self) -> Result<(u32, u32, u32)> {
        if !self.is_video() {
            return Err(Error::Caps(format!("`{}` is not video/x-raw", self.media)));
        }
        let w = self.get_u32("width").ok_or_else(|| Error::Caps("missing width".into()))?;
        let h = self.get_u32("height").ok_or_else(|| Error::Caps("missing height".into()))?;
        let fps = self
            .get("framerate")
            .and_then(|f| f.split('/').next())
            .and_then(|n| n.parse().ok())
            .unwrap_or(30);
        Ok((w, h, fps))
    }
}

impl fmt::Display for Caps {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.media)?;
        for (k, v) in &self.fields {
            if v.contains(',') {
                write!(f, ",{k}=\"{v}\"")?;
            } else {
                write!(f, ",{k}={v}")?;
            }
        }
        Ok(())
    }
}

/// Split on `sep` outside of double quotes.
fn split_unquoted(s: &str, sep: char) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    for c in s.chars() {
        if c == '"' {
            quoted = !quoted;
            cur.push(c);
        } else if c == sep && !quoted {
            out.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DType, TensorInfo};

    #[test]
    fn parse_simple_video_caps() {
        let c = Caps::parse("video/x-raw, width=300, height=300, format=RGB").unwrap();
        assert_eq!(c.media, "video/x-raw");
        assert_eq!(c.get_u32("width"), Some(300));
        assert_eq!(c.get("format"), Some("RGB"));
    }

    #[test]
    fn parse_quoted_listing2_caps() {
        let s = r#"other/tensors,num_tensors=4,dimensions="4:20:1:1,20:1:1:1,20:1:1:1,1:1:1:1",types="float32,float32,float32,float32""#;
        let c = Caps::parse(s).unwrap();
        let info = c.tensors_info().unwrap();
        assert_eq!(info.len(), 4);
        assert_eq!(info.tensors[0].dims, [4, 20, 1, 1]);
    }

    #[test]
    fn display_parse_roundtrip() {
        let mut ti = TensorsInfo::default();
        ti.push(TensorInfo::new(DType::F32, &[4, 20]).unwrap()).unwrap();
        ti.push(TensorInfo::new(DType::F32, &[20]).unwrap()).unwrap();
        let c = Caps::tensors(&ti);
        let c2 = Caps::parse(&c.to_string()).unwrap();
        assert_eq!(c, c2);
        assert_eq!(c2.tensors_info().unwrap(), ti);
    }

    #[test]
    fn compatibility_rules() {
        let a = Caps::parse("video/x-raw,width=300").unwrap();
        let b = Caps::parse("video/x-raw,width=300,height=200").unwrap();
        let c = Caps::parse("video/x-raw,width=640").unwrap();
        let t = Caps::parse("other/tensors").unwrap();
        assert!(a.compatible(&b));
        assert!(b.compatible(&a));
        assert!(!a.compatible(&c));
        assert!(!a.compatible(&t));
        assert!(Caps::any().compatible(&t));
        assert!(t.compatible(&Caps::any()));
    }

    #[test]
    fn intersect_unions_fields() {
        let a = Caps::parse("video/x-raw,width=300").unwrap();
        let b = Caps::parse("video/x-raw,height=200").unwrap();
        let i = a.intersect(&b).unwrap();
        assert_eq!(i.get_u32("width"), Some(300));
        assert_eq!(i.get_u32("height"), Some(200));
    }

    #[test]
    fn intersect_incompatible_errors() {
        let a = Caps::parse("video/x-raw,width=300").unwrap();
        let c = Caps::parse("video/x-raw,width=640").unwrap();
        assert!(a.intersect(&c).is_err());
    }

    #[test]
    fn tensor_format_defaults_static() {
        let c = Caps::parse("other/tensors,num_tensors=1,dimensions=3:4:1:1,types=uint8").unwrap();
        assert_eq!(c.tensor_format().unwrap(), Format::Static);
        assert_eq!(Caps::tensors_flexible().tensor_format().unwrap(), Format::Flexible);
    }

    #[test]
    fn video_geometry_parses_framerate() {
        let c = Caps::video(640, 480, 60);
        assert_eq!(c.video_geometry().unwrap(), (640, 480, 60));
    }

    #[test]
    fn bad_caps_rejected() {
        assert!(Caps::parse("").is_err());
        assert!(Caps::parse("width=3").is_err());
        assert!(Caps::parse("video/x-raw,badfield").is_err());
    }

    #[test]
    fn non_tensor_caps_tensor_helpers_error() {
        let v = Caps::video(10, 10, 30);
        assert!(v.tensor_format().is_err());
        assert!(v.tensors_info().is_err());
        assert!(Caps::tensors_flexible().video_geometry().is_err());
    }
}
