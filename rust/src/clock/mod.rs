//! Pipeline clocks: monotonic running-time, universal (wall) time, and the
//! base-time arithmetic the paper's timestamp-synchronization mechanism
//! (§4.2.3, Fig 4) relies on.
//!
//! Terminology follows GStreamer:
//! - *clock time*  — monotonic time since an arbitrary epoch (process start)
//! - *base time*   — the clock time at which the pipeline went PLAYING
//! - *running time* = clock time − base time; buffer PTS are running time
//! - *universal time* — wall clock (UNIX epoch ns), used to exchange
//!   base-times between devices (corrected by an NTP offset, see `ntp`).

use std::sync::OnceLock;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Nanoseconds; the unit of all PTS values in the crate.
pub type Ns = u64;

pub const SECOND: Ns = 1_000_000_000;
pub const MSECOND: Ns = 1_000_000;
pub const USECOND: Ns = 1_000;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic clock time (ns since process start). Never goes backwards.
pub fn clock_time() -> Ns {
    epoch().elapsed().as_nanos() as Ns
}

/// Universal (wall) time: ns since UNIX epoch, as i128-safe u64.
pub fn universal_time() -> Ns {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as Ns
}

/// A pipeline clock frozen at PLAYING: converts between running time and
/// universal time for cross-device timestamp correction.
#[derive(Debug, Clone, Copy)]
pub struct PipelineClock {
    /// Monotonic clock time when the pipeline went PLAYING.
    pub base_clock: Ns,
    /// Universal time at the same instant.
    pub base_universal: Ns,
}

impl PipelineClock {
    /// Capture "now" as the pipeline base time.
    pub fn start() -> Self {
        Self { base_clock: clock_time(), base_universal: universal_time() }
    }

    /// Running time of "now" for this pipeline.
    pub fn running_time(&self) -> Ns {
        clock_time().saturating_sub(self.base_clock)
    }

    /// Universal timestamp for a buffer PTS (running time) in this pipeline.
    pub fn pts_to_universal(&self, pts: Ns) -> Ns {
        self.base_universal + pts
    }

    /// Convert a remote buffer's (remote base universal, pts) into a PTS on
    /// *this* pipeline's running clock, applying the estimated clock offset
    /// between the hosts (`remote_universal + offset ≈ local_universal`).
    ///
    /// This is the receiver-side correction of §4.2.3: the publisher sends
    /// its base-time converted to universal time plus relative buffer
    /// timestamps, the subscriber re-bases them on its own base-time.
    pub fn remote_pts_to_local(&self, remote_base_universal: Ns, pts: Ns, offset_ns: i64) -> Ns {
        let remote_universal = remote_base_universal as i128 + pts as i128 + offset_ns as i128;
        let local = remote_universal - self.base_universal as i128;
        if local < 0 {
            0
        } else {
            local as Ns
        }
    }
}

/// Sleep until the given running time on this pipeline clock (frame pacing
/// for live sources).
pub fn sleep_until(clock: &PipelineClock, target_running: Ns) {
    let now = clock.running_time();
    if target_running > now {
        std::thread::sleep(Duration::from_nanos(target_running - now));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let a = clock_time();
        let b = clock_time();
        assert!(b >= a);
    }

    #[test]
    fn running_time_progresses() {
        let c = PipelineClock::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.running_time() >= MSECOND);
    }

    #[test]
    fn pts_universal_roundtrip() {
        let c = PipelineClock::start();
        let pts = 123 * MSECOND;
        let uni = c.pts_to_universal(pts);
        assert_eq!(uni - c.base_universal, pts);
    }

    #[test]
    fn remote_rebase_identity_same_host() {
        // Same base universal and zero offset -> PTS passes through.
        let c = PipelineClock::start();
        let pts = 55 * MSECOND;
        let local = c.remote_pts_to_local(c.base_universal, pts, 0);
        assert_eq!(local, pts);
    }

    #[test]
    fn remote_rebase_applies_offset() {
        let c = PipelineClock::start();
        let pts = 10 * MSECOND;
        let skewed = c.remote_pts_to_local(c.base_universal, pts, 5 * MSECOND as i64);
        assert_eq!(skewed, 15 * MSECOND);
    }

    #[test]
    fn remote_rebase_clamps_negative() {
        let c = PipelineClock::start();
        // Remote base far in the past with huge negative offset.
        let local = c.remote_pts_to_local(0, 0, -1);
        assert_eq!(local, 0);
    }

    #[test]
    fn sleep_until_waits() {
        let c = PipelineClock::start();
        let target = c.running_time() + 3 * MSECOND;
        sleep_until(&c, target);
        assert!(c.running_time() >= target);
    }
}
