//! Capability-based service discovery over MQTT (R3) with liveness via
//! last-will (R4).
//!
//! Query servers advertise on the retained topic
//! `edge/query/<operation>/<server_id>` — payload is a flexbuf map with
//! the direct-connect endpoint plus the "additional specifications"
//! the paper mentions (model name/version, workload status). The broker
//! clears the ad via last-will when a server dies, so subscribed clients
//! fail over without polling.
//!
//! Topic filters let a client pick among compatible servers: subscribing
//! `edge/query/objdetect/#` sees every object-detection server
//! (§4.2.2's `/objdetect/#` example).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mqtt::{ClientOptions, LastWill, MqttClient};
use crate::serial::flexbuf::{self, Value};
use crate::util::{Error, Result};

pub const QUERY_TOPIC_PREFIX: &str = "edge/query";

/// A server advertisement.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceAd {
    pub operation: String,
    pub server_id: String,
    pub host: String,
    pub port: u16,
    /// Model identifier ("mobilenet-ssd v2") — client-visible capability.
    pub model: String,
    /// Advertised workload (0.0 = idle); selection prefers lower.
    pub load: f64,
}

impl ServiceAd {
    pub fn topic(&self) -> String {
        format!("{QUERY_TOPIC_PREFIX}/{}/{}", self.operation, self.server_id)
    }

    pub fn encode(&self) -> Vec<u8> {
        flexbuf::encode(&flexbuf::map(vec![
            ("host", Value::Str(self.host.clone())),
            ("port", Value::UInt(self.port as u64)),
            ("model", Value::Str(self.model.clone())),
            ("load", Value::Float(self.load)),
        ]))
    }

    pub fn decode(operation: &str, server_id: &str, payload: &[u8]) -> Result<ServiceAd> {
        let v = flexbuf::decode(payload)?;
        // The load field is fully peer-controlled (a flexbuf Float off the
        // wire): sanitize non-finite values to +inf so a hostile or buggy
        // peer sorts last and is never preferred — and never reaches the
        // selection sort as NaN.
        let load = v.field("load").and_then(|f| f.as_f64()).unwrap_or(0.0);
        Ok(ServiceAd {
            operation: operation.to_string(),
            server_id: server_id.to_string(),
            host: v.field("host")?.as_str()?.to_string(),
            port: v.field("port")?.as_u64()? as u16,
            model: v.field("model")?.as_str()?.to_string(),
            load: if load.is_finite() { load } else { f64::INFINITY },
        })
    }

    pub fn endpoint(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }
}

/// Parse `edge/query/<operation>/<server_id>` into its parts.
pub fn split_topic(topic: &str) -> Option<(String, String)> {
    let rest = topic.strip_prefix(QUERY_TOPIC_PREFIX)?.strip_prefix('/')?;
    let (op, id) = rest.rsplit_once('/')?;
    if op.is_empty() || id.is_empty() {
        return None;
    }
    Some((op.to_string(), id.to_string()))
}

/// Publish a retained advertisement (server side). The MQTT session should
/// carry a matching last-will (see [`will_for`]) so death clears it.
pub fn advertise(client: &MqttClient, ad: &ServiceAd) -> Result<()> {
    client.publish(&ad.topic(), &ad.encode(), true)
}

/// Clear an advertisement explicitly (clean shutdown).
pub fn clear_advertisement(client: &MqttClient, ad: &ServiceAd) -> Result<()> {
    client.publish(&ad.topic(), &[], true)
}

/// Last-will that clears the retained ad on unclean death.
pub fn will_for(ad: &ServiceAd) -> LastWill {
    LastWill { topic: ad.topic(), payload: Vec::new(), qos: 0, retain: true }
}

/// Client options for an advertising server.
pub fn server_client_options(server_id: &str, ad: &ServiceAd) -> ClientOptions {
    ClientOptions {
        client_id: format!("edgepipe-srv-{server_id}"),
        keep_alive_secs: 2, // fast death detection -> fast failover
        will: Some(will_for(ad)),
        channel_depth: 64,
    }
}

/// Watches `edge/query/<operation>/#` and maintains the live server set.
///
/// The map is keyed by `(operation, server_id)`: under a wildcard watch
/// (`objdetect/#` spans every op below it) the same server id may appear
/// under several operations, and they are distinct services — keying by
/// id alone made them collide, and clearing one operation's ad removed
/// the other operation's live entry.
///
/// Each entry carries a **birth**: a process-wide counter stamped when
/// the ad appears while absent from the map (first sighting, or
/// re-advertisement after the retained ad was cleared by death/last-will).
/// A load-refresh republish of a live ad keeps its birth. The peer-health
/// layer ([`crate::coordinator::health`]) uses a birth change to clear a
/// server's failure history — the fix for the former append-only failover
/// blacklist that kept a restarted server unreachable forever.
pub struct AdWatcher {
    servers: Arc<Mutex<BTreeMap<(String, String), (ServiceAd, u64)>>>,
    #[allow(dead_code)]
    client: MqttClient,
    rx_done: Receiver<()>,
}

impl AdWatcher {
    /// Subscribe and start watching. `operation` may contain MQTT
    /// wildcards itself (e.g. `objdetect/#`).
    pub fn watch(broker: &str, operation: &str) -> Result<AdWatcher> {
        let client = MqttClient::connect(
            broker,
            ClientOptions {
                client_id: format!("edgepipe-watch-{}-{}", operation.replace('/', "_"), std::process::id()),
                keep_alive_secs: 5,
                will: None,
                channel_depth: 64,
            },
        )?;
        let servers: Arc<Mutex<BTreeMap<(String, String), (ServiceAd, u64)>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let s2 = servers.clone();
        // An operation may itself end in a wildcard (`objdetect/#`).
        let filter = if operation.ends_with('#') || operation.ends_with('+') {
            format!("{QUERY_TOPIC_PREFIX}/{operation}")
        } else {
            format!("{QUERY_TOPIC_PREFIX}/{operation}/#")
        };
        let (tx_done, rx_done) = std::sync::mpsc::channel();
        client.subscribe_cb(&filter, move |msg| {
            let _ = &tx_done; // keep sender alive with the subscription
            if let Some((op, id)) = split_topic(&msg.topic) {
                let mut s = s2.lock().unwrap();
                if msg.payload.is_empty() {
                    s.remove(&(op, id));
                } else if let Ok(ad) = ServiceAd::decode(&op, &id, &msg.payload) {
                    // Keep the birth across in-place updates (load
                    // refresh); stamp a new one when the ad (re)appears.
                    let birth = match s.get(&(op.clone(), id.clone())) {
                        Some((_, b)) => *b,
                        None => next_birth(),
                    };
                    s.insert((op, id), (ad, birth));
                }
            }
        })?;
        Ok(AdWatcher { servers, client, rx_done })
    }

    /// Current live servers, sorted by (load, id). `total_cmp` keeps the
    /// sort panic-free no matter what a remote peer advertises (decode
    /// already maps non-finite loads to +inf, which orders last).
    pub fn servers(&self) -> Vec<ServiceAd> {
        self.entries().into_iter().map(|(ad, _)| ad).collect()
    }

    /// Live servers with their ad births, sorted like [`servers`]. The
    /// health layer feeds this to `HealthMap::note_ads`/`select` so a
    /// restarted server (new birth) sheds its failure history.
    pub fn entries(&self) -> Vec<(ServiceAd, u64)> {
        let mut v: Vec<(ServiceAd, u64)> =
            self.servers.lock().unwrap().values().cloned().collect();
        v.sort_by(|(a, _), (b, _)| {
            a.load.total_cmp(&b.load).then_with(|| a.server_id.cmp(&b.server_id))
        });
        v
    }

    /// Pick the best server, excluding given ids (failover path).
    pub fn pick(&self, exclude: &[String]) -> Option<ServiceAd> {
        self.servers().into_iter().find(|s| !exclude.contains(&s.server_id))
    }

    /// Block until at least one server is visible.
    pub fn wait_any(&self, timeout: Duration) -> Option<ServiceAd> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(ad) = self.pick(&[]) {
                return Some(ad);
            }
            if Instant::now() >= deadline {
                return None;
            }
            // The rx_done channel never fires; it just sleeps with wakeups.
            let _ = self.rx_done.recv_timeout(Duration::from_millis(20));
        }
    }
}

/// Process-wide monotonic ad-birth stamp (shared across watchers so a
/// client that recreates its watcher still sees births advance).
fn next_birth() -> u64 {
    static BIRTH: AtomicU64 = AtomicU64::new(1);
    BIRTH.fetch_add(1, Ordering::Relaxed)
}

/// Validate an operation name (becomes a topic level).
pub fn validate_operation(op: &str) -> Result<()> {
    if op.is_empty() || op.contains(['+', '#', '\0']) {
        return Err(Error::Mqtt(format!("bad operation name `{op}`")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mqtt::Broker;

    fn ad(op: &str, id: &str, port: u16, load: f64) -> ServiceAd {
        ServiceAd {
            operation: op.into(),
            server_id: id.into(),
            host: "127.0.0.1".into(),
            port,
            model: "ssd-lite".into(),
            load,
        }
    }

    #[test]
    fn ad_encode_decode_roundtrip() {
        let a = ad("objdetect", "srv1", 4001, 0.25);
        let b = ServiceAd::decode("objdetect", "srv1", &a.encode()).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.endpoint(), "127.0.0.1:4001");
    }

    #[test]
    fn topic_split() {
        assert_eq!(
            split_topic("edge/query/objdetect/srv1"),
            Some(("objdetect".into(), "srv1".into()))
        );
        assert_eq!(
            split_topic("edge/query/objdetect/ssd/srv1"),
            Some(("objdetect/ssd".into(), "srv1".into()))
        );
        assert_eq!(split_topic("other/query/x/y"), None);
    }

    #[test]
    fn watcher_sees_advertised_servers() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let a = ad("objdetect", "srv1", 4001, 0.5);
        let srv = MqttClient::connect(&addr, server_client_options("srv1", &a)).unwrap();
        advertise(&srv, &a).unwrap();
        let watcher = AdWatcher::watch(&addr, "objdetect").unwrap();
        let found = watcher.wait_any(Duration::from_secs(3)).unwrap();
        assert_eq!(found.server_id, "srv1");
    }

    #[test]
    fn watcher_prefers_lower_load() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let c = MqttClient::connect(&addr, ClientOptions::default()).unwrap();
        advertise(&c, &ad("op", "busy", 1, 0.9)).unwrap();
        advertise(&c, &ad("op", "idle", 2, 0.1)).unwrap();
        let watcher = AdWatcher::watch(&addr, "op").unwrap();
        watcher.wait_any(Duration::from_secs(3)).unwrap();
        std::thread::sleep(Duration::from_millis(200)); // both ads land
        assert_eq!(watcher.pick(&[]).unwrap().server_id, "idle");
        assert_eq!(watcher.pick(&["idle".into()]).unwrap().server_id, "busy");
    }

    #[test]
    fn unclean_server_death_clears_ad() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let a = ad("op", "dying", 3, 0.0);
        let srv = MqttClient::connect(&addr, server_client_options("dying", &a)).unwrap();
        advertise(&srv, &a).unwrap();
        let watcher = AdWatcher::watch(&addr, "op").unwrap();
        watcher.wait_any(Duration::from_secs(3)).unwrap();
        // Unclean death: raw socket shutdown, no DISCONNECT.
        srv.inner_stream_for_test().unwrap().shutdown(std::net::Shutdown::Both).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            if watcher.servers().is_empty() {
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        panic!("ad not cleared after unclean death: {:?}", watcher.servers());
    }

    #[test]
    fn clean_clear_removes_ad() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let a = ad("op", "s", 5, 0.0);
        let c = MqttClient::connect(&addr, ClientOptions::default()).unwrap();
        advertise(&c, &a).unwrap();
        let watcher = AdWatcher::watch(&addr, "op").unwrap();
        watcher.wait_any(Duration::from_secs(3)).unwrap();
        clear_advertisement(&c, &a).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        while Instant::now() < deadline && !watcher.servers().is_empty() {
            std::thread::sleep(Duration::from_millis(30));
        }
        assert!(watcher.servers().is_empty());
    }

    #[test]
    fn same_id_under_different_operations_does_not_collide() {
        // Regression: a wildcard watch (`objdetect/#`) spans operations,
        // and the same server id may legitimately exist under several of
        // them. Keying the map by id alone made the second ad overwrite
        // the first, and clearing one op's ad removed the OTHER op's
        // live entry.
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let c = MqttClient::connect(&addr, ClientOptions::default()).unwrap();
        let ssd = ad("objdetect/ssd", "srv1", 4001, 0.2);
        let yolo = ad("objdetect/yolo", "srv1", 4002, 0.4);
        advertise(&c, &ssd).unwrap();
        advertise(&c, &yolo).unwrap();
        let watcher = AdWatcher::watch(&addr, "objdetect/#").unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline && watcher.servers().len() < 2 {
            std::thread::sleep(Duration::from_millis(30));
        }
        let servers = watcher.servers();
        assert_eq!(servers.len(), 2, "ads under different ops collided: {servers:?}");
        assert!(servers.iter().any(|s| s.operation == "objdetect/ssd" && s.port == 4001));
        assert!(servers.iter().any(|s| s.operation == "objdetect/yolo" && s.port == 4002));
        // Clearing the ssd ad must leave the yolo ad (same id!) alive.
        clear_advertisement(&c, &ssd).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline && watcher.servers().len() != 1 {
            std::thread::sleep(Duration::from_millis(30));
        }
        let left = watcher.servers();
        assert_eq!(left.len(), 1, "clear removed the wrong op's ad: {left:?}");
        assert_eq!(left[0].operation, "objdetect/yolo");
    }

    #[test]
    fn non_finite_load_sanitized_at_decode() {
        // Regression: `load` is a fully peer-controlled flexbuf Float; a
        // NaN used to reach `partial_cmp(..).unwrap()` and panic every
        // watcher in the process.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut a = ad("op", "evil", 9, 0.0);
            a.load = bad;
            let decoded = ServiceAd::decode("op", "evil", &a.encode()).unwrap();
            assert_eq!(decoded.load, f64::INFINITY, "{bad} not sanitized");
        }
        let fine = ServiceAd::decode("op", "ok", &ad("op", "ok", 1, 0.25).encode()).unwrap();
        assert_eq!(fine.load, 0.25);
    }

    #[test]
    fn nan_load_ad_sorts_last_and_never_panics() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let c = MqttClient::connect(&addr, ClientOptions::default()).unwrap();
        let mut evil = ad("op", "evil", 1, 0.0);
        evil.load = f64::NAN;
        advertise(&c, &evil).unwrap();
        advertise(&c, &ad("op", "busy", 2, 0.9)).unwrap();
        let watcher = AdWatcher::watch(&addr, "op").unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline && watcher.servers().len() < 2 {
            std::thread::sleep(Duration::from_millis(30));
        }
        let servers = watcher.servers(); // used to panic here
        assert_eq!(servers.len(), 2);
        assert_eq!(servers[0].server_id, "busy", "finite load must be preferred");
        assert_eq!(servers[1].server_id, "evil");
        assert_eq!(servers[1].load, f64::INFINITY);
        assert_eq!(watcher.pick(&[]).unwrap().server_id, "busy");
    }

    #[test]
    fn rebirth_on_clear_and_readvertise_but_not_on_refresh() {
        // Regression (failover blacklist expiry): the health layer keys
        // "did this server restart?" off the ad birth, so a clear (death)
        // followed by a re-advertise under the SAME server_id must bump
        // the birth — while an in-place load refresh must NOT.
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let addr = broker.addr().to_string();
        let c = MqttClient::connect(&addr, ClientOptions::default()).unwrap();
        let mut a = ad("op", "reborn", 7, 0.1);
        advertise(&c, &a).unwrap();
        let watcher = AdWatcher::watch(&addr, "op").unwrap();
        watcher.wait_any(Duration::from_secs(3)).unwrap();
        let birth0 = watcher.entries()[0].1;

        // Load refresh: same retained topic republished while live.
        a.load = 0.8;
        advertise(&c, &a).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        while Instant::now() < deadline && watcher.entries()[0].0.load != 0.8 {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(watcher.entries()[0].1, birth0, "load refresh must keep birth");

        // Death (ad cleared) then restart (re-advertise, same id).
        clear_advertisement(&c, &a).unwrap();
        let deadline = Instant::now() + Duration::from_secs(3);
        while Instant::now() < deadline && !watcher.servers().is_empty() {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(watcher.servers().is_empty());
        advertise(&c, &a).unwrap();
        watcher.wait_any(Duration::from_secs(3)).unwrap();
        assert!(watcher.entries()[0].1 > birth0, "re-advertise after clear must bump birth");
    }

    #[test]
    fn operation_validation() {
        assert!(validate_operation("objdetect/ssd").is_ok());
        assert!(validate_operation("").is_err());
        assert!(validate_operation("a#b").is_err());
    }
}
