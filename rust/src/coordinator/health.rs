//! Peer-health model for elastic query offload (R4): per-server circuit
//! breaker, consecutive-failure tracking, and a latency EWMA + recent-RTT
//! ring, combined with the advertised load into a selection score.
//!
//! One [`HealthMap`] is shared by every `QueryClient` watching the same
//! operation (see [`shared`]), so observations made by one client
//! pipeline (server X is timing out) immediately steer every other
//! client in the process away from X — and the half-open probe budget is
//! spent once per process, not once per client.
//!
//! ## Breaker state machine
//!
//! ```text
//!            N consecutive failures
//!   Closed ──────────────────────────▶ Open (until = now + base·2^(opens-1), capped)
//!     ▲                                  │ open interval elapsed
//!     │ probe succeeds                   ▼
//!     └────────────────────────────── HalfOpen (probe budget)
//!                                        │ probe fails
//!                                        └──────▶ Open (longer)
//! ```
//!
//! `allow()` is the gate: `Closed` always passes, `Open` passes only once
//! the open interval has elapsed (transitioning to `HalfOpen`), and
//! `HalfOpen` passes while probe budget remains. A probe whose outcome is
//! never reported (caller died mid-request) does not wedge the peer: the
//! budget refreshes after another open interval in `HalfOpen`.
//!
//! A *fresh advertisement* — the `AdWatcher` birth counter bumping because
//! the server's retained ad was cleared (death) and re-published
//! (restart) — resets the peer's failure history entirely. This is the
//! fix for the former permanent blacklist: a crashed server that restarts
//! under the same `server_id` becomes selectable the moment it
//! re-advertises.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::coordinator::discovery::ServiceAd;

/// Minimum recorded RTT samples before [`HealthMap::rtt_percentile`]
/// reports (hedging stays off until the latency profile is warm).
pub const MIN_RTT_SAMPLES: usize = 8;

/// Recent-RTT ring capacity per peer.
const RTT_RING: usize = 128;

/// Circuit-breaker + scoring knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BreakerConfig {
    /// Consecutive failures that open the breaker.
    pub failure_threshold: u32,
    /// First open interval; doubles on every re-open, capped at `open_max`.
    pub open_base: Duration,
    pub open_max: Duration,
    /// Requests allowed through while `HalfOpen`.
    pub probe_budget: u32,
    /// Latency EWMA weight for new samples.
    pub ewma_alpha: f64,
    /// Selection-score penalty per consecutive failure (in advertised-load
    /// units: one failure outweighs a `0.5` load difference by default).
    pub failure_penalty: f64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            failure_threshold: 3,
            open_base: Duration::from_millis(500),
            open_max: Duration::from_secs(30),
            probe_budget: 1,
            ewma_alpha: 0.2,
            failure_penalty: 0.5,
        }
    }
}

/// Observable breaker state of a peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

#[derive(Debug)]
struct Peer {
    state: BreakerState,
    consecutive_failures: u32,
    /// How many times the breaker opened without an intervening success
    /// (drives the exponential open interval).
    opens: u32,
    /// When the current `Open` interval ends / the `HalfOpen` budget
    /// refreshes.
    until: Instant,
    probes_left: u32,
    ewma_us: Option<f64>,
    rtts_us: Vec<f64>,
    rtt_next: usize,
    /// Ad birth this state was observed under; a newer birth resets it.
    birth: u64,
}

impl Peer {
    fn new(birth: u64) -> Self {
        Self {
            state: BreakerState::Closed,
            consecutive_failures: 0,
            opens: 0,
            until: Instant::now(),
            probes_left: 0,
            ewma_us: None,
            rtts_us: Vec::new(),
            rtt_next: 0,
            birth,
        }
    }

    fn reset(&mut self, birth: u64) {
        // A restarted server keeps its latency profile (same hardware,
        // same model) but sheds all failure history.
        self.state = BreakerState::Closed;
        self.consecutive_failures = 0;
        self.opens = 0;
        self.probes_left = 0;
        self.birth = birth;
    }

    fn open_interval(&self, cfg: &BreakerConfig) -> Duration {
        let exp = self.opens.saturating_sub(1).min(16);
        cfg.open_max.min(cfg.open_base.saturating_mul(1u32 << exp))
    }
}

/// Shared per-operation peer-health table.
pub struct HealthMap {
    peers: Mutex<HashMap<String, Peer>>,
    cfg: BreakerConfig,
}

impl HealthMap {
    pub fn new(cfg: BreakerConfig) -> Self {
        Self { peers: Mutex::new(HashMap::new()), cfg }
    }

    pub fn config(&self) -> &BreakerConfig {
        &self.cfg
    }

    /// Fold a discovery snapshot in: a peer whose ad birth advanced (its
    /// retained ad was cleared and re-published — i.e. it restarted) has
    /// its failure history cleared so it is immediately selectable again.
    pub fn note_ads(&self, ads: &[(ServiceAd, u64)]) {
        let mut peers = self.peers.lock().unwrap();
        for (ad, birth) in ads {
            let p = peers.entry(ad.server_id.clone()).or_insert_with(|| Peer::new(*birth));
            if p.birth != *birth {
                p.reset(*birth);
            }
        }
    }

    /// Breaker gate; consumes a half-open probe when one is granted.
    /// Unknown peers are allowed (and tracked from first outcome).
    pub fn allow(&self, id: &str) -> bool {
        let mut peers = self.peers.lock().unwrap();
        let Some(p) = peers.get_mut(id) else { return true };
        let now = Instant::now();
        match p.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if now < p.until {
                    return false;
                }
                p.state = BreakerState::HalfOpen;
                p.probes_left = self.cfg.probe_budget;
                p.until = now + p.open_interval(&self.cfg); // budget refresh point
                p.probes_left -= 1;
                true
            }
            BreakerState::HalfOpen => {
                if p.probes_left == 0 && now >= p.until {
                    // Probe outcome was never reported; refresh the budget
                    // rather than wedging the peer in HalfOpen forever.
                    p.probes_left = self.cfg.probe_budget;
                    p.until = now + p.open_interval(&self.cfg);
                }
                if p.probes_left > 0 {
                    p.probes_left -= 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Like [`allow`] but without consuming a probe — for reroute checks
    /// and scoring, where no request is about to be sent.
    pub fn would_allow(&self, id: &str) -> bool {
        let peers = self.peers.lock().unwrap();
        match peers.get(id) {
            None => true,
            Some(p) => match p.state {
                BreakerState::Closed => true,
                BreakerState::HalfOpen => p.probes_left > 0 || Instant::now() >= p.until,
                BreakerState::Open => Instant::now() >= p.until,
            },
        }
    }

    /// Record a completed request. Closes the breaker (from any state)
    /// and folds the RTT into the EWMA + recent-sample ring.
    pub fn record_success(&self, id: &str, rtt_us: f64) {
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(id.to_string()).or_insert_with(|| Peer::new(0));
        p.state = BreakerState::Closed;
        p.consecutive_failures = 0;
        p.opens = 0;
        p.probes_left = 0;
        let a = self.cfg.ewma_alpha;
        p.ewma_us = Some(match p.ewma_us {
            None => rtt_us,
            Some(e) => a * rtt_us + (1.0 - a) * e,
        });
        if p.rtts_us.len() < RTT_RING {
            p.rtts_us.push(rtt_us);
        } else {
            p.rtts_us[p.rtt_next] = rtt_us;
        }
        p.rtt_next = (p.rtt_next + 1) % RTT_RING;
    }

    /// Record a failed request (connect error, write/read error, timeout).
    /// Returns `true` when this failure transitioned the breaker to
    /// `Open` (callers count `breaker_open` metrics on that edge).
    pub fn record_failure(&self, id: &str) -> bool {
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(id.to_string()).or_insert_with(|| Peer::new(0));
        p.consecutive_failures += 1;
        let opened = match p.state {
            // A failed half-open probe re-opens with a longer interval.
            BreakerState::HalfOpen => true,
            BreakerState::Closed => p.consecutive_failures >= self.cfg.failure_threshold,
            BreakerState::Open => false,
        };
        if opened {
            p.state = BreakerState::Open;
            p.opens += 1;
            p.probes_left = 0;
            p.until = Instant::now() + p.open_interval(&self.cfg);
        }
        opened
    }

    pub fn state(&self, id: &str) -> BreakerState {
        self.peers.lock().unwrap().get(id).map(|p| p.state).unwrap_or(BreakerState::Closed)
    }

    pub fn consecutive_failures(&self, id: &str) -> u32 {
        self.peers.lock().unwrap().get(id).map(|p| p.consecutive_failures).unwrap_or(0)
    }

    /// Latency EWMA in microseconds, if any sample has been recorded.
    pub fn ewma_us(&self, id: &str) -> Option<f64> {
        self.peers.lock().unwrap().get(id).and_then(|p| p.ewma_us)
    }

    /// Percentile over the peer's recent-RTT ring. `frac` is the same
    /// 0..=1 fraction as the `hedge-pct` element property (0.95 → p95),
    /// NOT a 0..100 percent — callers must not pre-scale. `None` until
    /// [`MIN_RTT_SAMPLES`] samples exist (hedging stays off while cold).
    pub fn rtt_percentile(&self, id: &str, frac: f64) -> Option<f64> {
        let peers = self.peers.lock().unwrap();
        let p = peers.get(id)?;
        if p.rtts_us.len() < MIN_RTT_SAMPLES {
            return None;
        }
        let mut v = p.rtts_us.clone();
        drop(peers);
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((v.len() - 1) as f64 * frac.clamp(0.0, 1.0)).round() as usize;
        Some(v[idx])
    }

    /// Selection score: advertised load plus observed-health penalties
    /// (lower is better). Consecutive failures dominate; the latency EWMA
    /// breaks ties between equally-loaded healthy peers.
    pub fn score(&self, ad: &ServiceAd) -> f64 {
        let peers = self.peers.lock().unwrap();
        let (fails, ewma) = peers
            .get(&ad.server_id)
            .map(|p| (p.consecutive_failures, p.ewma_us.unwrap_or(0.0)))
            .unwrap_or((0, 0.0));
        ad.load + self.cfg.failure_penalty * fails as f64 + ewma / 1e6
    }

    /// Health-aware selection: candidates ranked by [`score`], gated by
    /// the breaker via [`allow`] (so a granted pick consumes a half-open
    /// probe). `avoid` demotes a peer (the one we just failed on, or the
    /// hedge primary) to last resort without blacklisting it.
    pub fn select(&self, ads: &[(ServiceAd, u64)], avoid: Option<&str>) -> Option<ServiceAd> {
        self.note_ads(ads);
        let mut ranked: Vec<&ServiceAd> = ads.iter().map(|(ad, _)| ad).collect();
        ranked.sort_by(|a, b| {
            self.score(a).total_cmp(&self.score(b)).then_with(|| a.server_id.cmp(&b.server_id))
        });
        if let Some(av) = avoid {
            let (rest, avoided): (Vec<_>, Vec<_>) =
                ranked.into_iter().partition(|ad| ad.server_id != av);
            ranked = rest;
            ranked.extend(avoided);
        }
        ranked.into_iter().find(|ad| self.allow(&ad.server_id)).cloned()
    }
}

/// Process-wide shared maps, keyed by scope (the query operation): every
/// `QueryClient` on one operation shares observations. The first caller's
/// config wins for that scope.
pub fn shared(scope: &str, cfg: BreakerConfig) -> Arc<HealthMap> {
    static MAPS: OnceLock<Mutex<HashMap<String, Arc<HealthMap>>>> = OnceLock::new();
    MAPS.get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .unwrap()
        .entry(scope.to_string())
        .or_insert_with(|| Arc::new(HealthMap::new(cfg)))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BreakerConfig {
        BreakerConfig {
            failure_threshold: 3,
            open_base: Duration::from_millis(40),
            open_max: Duration::from_millis(400),
            probe_budget: 1,
            ..BreakerConfig::default()
        }
    }

    fn ad(id: &str, load: f64) -> ServiceAd {
        ServiceAd {
            operation: "op".into(),
            server_id: id.into(),
            host: "127.0.0.1".into(),
            port: 1,
            model: "m".into(),
            load,
        }
    }

    #[test]
    fn closes_to_open_after_threshold() {
        let h = HealthMap::new(cfg());
        assert!(!h.record_failure("s"));
        assert!(!h.record_failure("s"));
        assert_eq!(h.state("s"), BreakerState::Closed);
        assert!(h.record_failure("s"), "third failure must open");
        assert_eq!(h.state("s"), BreakerState::Open);
        assert!(!h.allow("s"), "open breaker blocks immediately");
        assert!(!h.would_allow("s"));
    }

    #[test]
    fn open_expires_into_half_open_probe_budget() {
        let h = HealthMap::new(cfg());
        for _ in 0..3 {
            h.record_failure("s");
        }
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.would_allow("s"));
        assert!(h.allow("s"), "expired open grants a probe");
        assert_eq!(h.state("s"), BreakerState::HalfOpen);
        assert!(!h.allow("s"), "probe budget of 1 is spent");
    }

    #[test]
    fn probe_success_closes_probe_failure_reopens_longer() {
        let h = HealthMap::new(cfg());
        for _ in 0..3 {
            h.record_failure("s");
        }
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.allow("s"));
        assert!(h.record_failure("s"), "failed probe re-opens");
        assert_eq!(h.state("s"), BreakerState::Open);
        // Second open interval is doubled: not yet expired after base.
        std::thread::sleep(Duration::from_millis(60));
        assert!(!h.allow("s"), "re-open interval must be longer than base");
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.allow("s"));
        h.record_success("s", 1000.0);
        assert_eq!(h.state("s"), BreakerState::Closed);
        assert_eq!(h.consecutive_failures("s"), 0);
        // After a success the exponential restarts from base.
        for _ in 0..3 {
            h.record_failure("s");
        }
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.allow("s"), "open interval resets after success");
    }

    #[test]
    fn unreported_probe_does_not_wedge_half_open() {
        let h = HealthMap::new(cfg());
        for _ in 0..3 {
            h.record_failure("s");
        }
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.allow("s")); // probe granted, outcome never reported
        assert!(!h.allow("s"));
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.allow("s"), "budget refreshes after another interval");
    }

    #[test]
    fn ewma_and_percentile() {
        let h = HealthMap::new(cfg());
        assert!(h.ewma_us("s").is_none());
        assert!(h.rtt_percentile("s", 0.95).is_none());
        for _ in 0..MIN_RTT_SAMPLES - 1 {
            h.record_success("s", 1000.0);
        }
        assert!(h.rtt_percentile("s", 0.95).is_none(), "below sample floor");
        h.record_success("s", 1000.0);
        assert_eq!(h.rtt_percentile("s", 0.5), Some(1000.0));
        h.record_success("s", 100_000.0);
        assert!(h.rtt_percentile("s", 0.99).unwrap() > 50_000.0);
        assert!(h.ewma_us("s").unwrap() > 1000.0);
    }

    /// Regression for the hedge-delay unit bug: `hedge-pct` is a 0..1
    /// fraction, and feeding that fraction straight in must land on the
    /// configured tail percentile — not near the minimum RTT (which a
    /// percent-expecting implementation would return for e.g. 0.95/100).
    #[test]
    fn percentile_fraction_tracks_tail_not_min() {
        let h = HealthMap::new(cfg());
        for i in 1..=100u32 {
            h.record_success("s", f64::from(i) * 1000.0); // 1ms..100ms
        }
        let p95 = h.rtt_percentile("s", 0.95).unwrap();
        let p50 = h.rtt_percentile("s", 0.5).unwrap();
        assert!((94_000.0..=97_000.0).contains(&p95), "p95 ≈ 95ms, got {p95}");
        assert!((49_000.0..=52_000.0).contains(&p50), "p50 ≈ 50ms, got {p50}");
        let min = h.rtt_percentile("s", 0.0).unwrap();
        assert_eq!(min, 1000.0);
        assert!(p95 > 10.0 * min, "hedge delay must track the tail, not the min RTT");
    }

    #[test]
    fn score_combines_load_and_health() {
        let h = HealthMap::new(cfg());
        let idle = ad("idle", 0.1);
        let busy = ad("busy", 0.6);
        assert!(h.score(&idle) < h.score(&busy));
        // One failure on the idle peer outweighs the 0.5 load gap.
        h.record_failure("idle");
        assert!(h.score(&idle) > h.score(&busy));
        // Latency EWMA breaks ties between healthy peers.
        h.record_success("idle", 1000.0); // resets failures
        h.record_success("busy", 900_000.0);
        let slow = ad("busy", 0.1);
        assert!(h.score(&idle) < h.score(&slow));
    }

    #[test]
    fn select_skips_open_breaker_and_demotes_avoided() {
        let h = HealthMap::new(cfg());
        let ads = vec![(ad("a", 0.0), 1), (ad("b", 0.3), 1)];
        assert_eq!(h.select(&ads, None).unwrap().server_id, "a");
        assert_eq!(h.select(&ads, Some("a")).unwrap().server_id, "b", "avoid demotes");
        for _ in 0..3 {
            h.record_failure("b");
        }
        assert_eq!(
            h.select(&ads, Some("a")).unwrap().server_id,
            "a",
            "avoided peer is last resort, not blacklisted"
        );
        for _ in 0..3 {
            h.record_failure("a");
        }
        assert!(h.select(&ads, None).is_none(), "all breakers open -> none");
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.select(&ads, None).is_some(), "expiry re-admits probes");
    }

    #[test]
    fn fresh_ad_birth_resets_failure_history() {
        let h = HealthMap::new(cfg());
        h.note_ads(&[(ad("s", 0.0), 7)]); // selection sees the ad first
        for _ in 0..3 {
            h.record_failure("s");
        }
        h.note_ads(&[(ad("s", 0.0), 7)]);
        assert_eq!(h.state("s"), BreakerState::Open, "same birth keeps state");
        // A later birth means the retained ad was cleared and re-published
        // — the server restarted.
        h.note_ads(&[(ad("s", 0.0), 8)]);
        assert_eq!(h.state("s"), BreakerState::Closed);
        assert_eq!(h.consecutive_failures("s"), 0);
        assert!(h.allow("s"));
    }

    #[test]
    fn shared_maps_are_per_scope() {
        let a = shared("health-test-scope-a", cfg());
        let a2 = shared("health-test-scope-a", cfg());
        let b = shared("health-test-scope-b", cfg());
        a.record_failure("x");
        assert_eq!(a2.consecutive_failures("x"), 1, "same scope shares state");
        assert_eq!(b.consecutive_failures("x"), 0);
    }
}
