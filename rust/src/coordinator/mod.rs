//! Among-device coordination: capability-based service discovery,
//! server selection and failover (R3/R4) — the layer the query elements
//! and NNStreamer-Edge analog build on.

pub mod discovery;

pub use discovery::{advertise, clear_advertisement, AdWatcher, ServiceAd};
