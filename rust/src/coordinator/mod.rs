//! Among-device coordination: capability-based service discovery,
//! server selection, peer health (circuit breakers + latency tracking)
//! and failover (R3/R4) — the layer the query elements and
//! NNStreamer-Edge analog build on.

pub mod discovery;
pub mod health;

pub use discovery::{advertise, clear_advertisement, AdWatcher, ServiceAd};
pub use health::{BreakerConfig, BreakerState, HealthMap};
