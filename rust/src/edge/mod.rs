//! EdgePipe-Edge — the NNStreamer-Edge analog (§4.3): a lightweight
//! library for devices that cannot afford the full pipeline framework
//! (microcontrollers, proprietary middleware, other pipeline frameworks).
//!
//! It deliberately depends ONLY on the transport substrate (mqtt client,
//! serial wire format, tensor metadata) — never on `element`/`pipeline` —
//! mirroring NNStreamer-Edge's independence from GStreamer. Three modules
//! as in the paper:
//!
//! - [`EdgeSensor`]       — publish tensor streams (the "edge_sensor"
//!                           module, e.g. remote cameras/sensors)
//! - [`EdgeOutput`]        — subscribe to published streams ("edge_output")
//! - [`EdgeQueryClient`]  — offload inference ("edge_query_client")

use std::net::TcpStream;
use std::sync::mpsc::Receiver;
use std::time::Duration;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::clock::PipelineClock;
use crate::coordinator::discovery::AdWatcher;
use crate::mqtt::{ClientOptions, MqttClient};
use crate::serial::wire::{self, LinkCodec, LinkDecoder};
use crate::serial::Codec;
use crate::tensor::TensorsInfo;
use crate::util::{Error, Result};

/// Publish tensor frames to a topic, compatible with `mqttsrc`.
pub struct EdgeSensor {
    client: MqttClient,
    topic: String,
    caps: Caps,
    clock: PipelineClock,
    seq: u64,
    link: LinkCodec,
}

impl EdgeSensor {
    /// Connect and declare the stream type this sensor publishes.
    pub fn connect(broker: &str, topic: &str, info: &TensorsInfo) -> Result<EdgeSensor> {
        let client = MqttClient::connect(
            broker,
            ClientOptions {
                client_id: format!("edge-sensor-{}-{}", topic.replace('/', "_"), std::process::id()),
                keep_alive_secs: 10,
                will: None,
                channel_depth: 16,
            },
        )?;
        Ok(EdgeSensor {
            client,
            topic: topic.to_string(),
            caps: Caps::tensors(info),
            clock: PipelineClock::start(),
            seq: 0,
            link: LinkCodec::new(Codec::None, ""),
        })
    }

    /// `Codec::Auto` gets a per-link adaptive state (keyed by topic).
    pub fn with_codec(mut self, codec: Codec) -> Self {
        let interval = self.link.keyframe_interval();
        self.link = LinkCodec::new(codec, &format!("edge_sensor.{}", self.topic))
            .with_keyframe_interval(interval);
        self
    }

    /// Frames per delta-chain keyframe period (`Codec::Delta`/`Auto`).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.link.set_keyframe_interval(interval);
        self
    }

    /// Publish one tensor frame (payload must match the declared info).
    pub fn publish(&mut self, payload: &[u8]) -> Result<()> {
        let info = self.caps.tensors_info()?;
        if payload.len() != info.frame_size() {
            return Err(Error::Tensor(format!(
                "edge_sensor: payload {} != declared frame size {}",
                payload.len(),
                info.frame_size()
            )));
        }
        let mut buf = Buffer::new(payload.to_vec()).with_pts(self.clock.running_time());
        buf.meta.remote_base_universal = Some(self.clock.base_universal);
        self.seq += 1;
        buf.meta.seq = Some(self.seq);
        let frame = self.link.encode(&buf, Some(&self.caps))?;
        self.client.publish_frame(&self.topic, &frame, false)
    }

    pub fn close(self) {
        self.client.disconnect();
    }
}

/// Subscribe to a published stream without a pipeline.
pub struct EdgeOutput {
    rx: Receiver<crate::mqtt::Message>,
    client: MqttClient,
    decoder: LinkDecoder,
}

/// One received frame.
#[derive(Debug, Clone)]
pub struct EdgeFrame {
    pub buffer: Buffer,
    pub caps: Option<Caps>,
}

impl EdgeOutput {
    pub fn connect(broker: &str, topic: &str) -> Result<EdgeOutput> {
        let client = MqttClient::connect(
            broker,
            ClientOptions {
                client_id: format!("edge-output-{}-{}", topic.replace('/', "_"), std::process::id()),
                keep_alive_secs: 10,
                will: None,
                channel_depth: 256,
            },
        )?;
        let rx = client.subscribe(topic)?;
        let decoder = LinkDecoder::new(&format!("edge_output.{topic}"));
        Ok(EdgeOutput { rx, client, decoder })
    }

    /// Blocking receive with timeout.
    ///
    /// Delta-coded links: mid-chain frames that arrive after loss decode
    /// to nothing and are skipped (the publisher re-keys at its next
    /// keyframe); the timeout bounds the whole wait, not one message.
    pub fn recv(&mut self, timeout: Duration) -> Result<EdgeFrame> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let remaining = deadline
                .checked_duration_since(std::time::Instant::now())
                .ok_or_else(|| Error::Transport("edge_output: receive timeout".into()))?;
            let msg = self
                .rx
                .recv_timeout(remaining)
                .map_err(|_| Error::Transport("edge_output: receive timeout".into()))?;
            if let Some((buffer, caps)) = self.decoder.decode(&msg.payload)? {
                return Ok(EdgeFrame { buffer, caps });
            }
        }
    }

    pub fn close(self) {
        self.client.disconnect();
    }
}

/// Inference offloading without a pipeline (TCP-raw or discovered).
pub struct EdgeQueryClient {
    conn: TcpStream,
    caps: Option<Caps>,
    seq: u64,
    link: LinkCodec,
    resp_dec: LinkDecoder,
}

impl EdgeQueryClient {
    /// Connect directly to a query server (`tensor_query_serversrc`).
    pub fn connect(server: &str, timeout: Duration) -> Result<EdgeQueryClient> {
        let conn = TcpStream::connect(server)
            .map_err(|e| Error::Transport(format!("edge query connect {server}: {e}")))?;
        conn.set_nodelay(true).ok();
        conn.set_read_timeout(Some(timeout))?;
        Ok(EdgeQueryClient {
            conn,
            caps: None,
            seq: 0,
            link: LinkCodec::new(Codec::None, ""),
            resp_dec: LinkDecoder::new("edge_query"),
        })
    }

    /// Discover a server for `operation` via the broker, then connect.
    pub fn discover(broker: &str, operation: &str, timeout: Duration) -> Result<EdgeQueryClient> {
        let watcher = AdWatcher::watch(broker, operation)?;
        let ad = watcher
            .wait_any(timeout)
            .ok_or_else(|| Error::Transport(format!("no servers for `{operation}`")))?;
        Self::connect(&ad.endpoint(), timeout)
    }

    /// Request-hop codec (the client owns exactly one connection, so the
    /// delta chain spans the client's lifetime).
    pub fn with_codec(mut self, codec: Codec) -> Self {
        let interval = self.link.keyframe_interval();
        self.link =
            LinkCodec::new(codec, "edge_query_client").with_keyframe_interval(interval);
        self
    }

    /// Frames per delta-chain keyframe period (`Codec::Delta`/`Auto`).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.link.set_keyframe_interval(interval);
        self
    }

    /// Declare the input stream type (sent with each request).
    pub fn set_caps(&mut self, info: &TensorsInfo) {
        self.caps = Some(Caps::tensors(info));
    }

    /// Synchronous inference: send input payload, return output payload.
    pub fn query(&mut self, payload: &[u8]) -> Result<Vec<u8>> {
        self.seq += 1;
        let mut buf = Buffer::new(payload.to_vec());
        buf.meta.seq = Some(self.seq);
        let frame = self.link.encode(&buf, self.caps.as_ref())?;
        wire::write_frame_vectored(&mut self.conn, &frame)?;
        // TCP is lossless, so a delta-coded response never desyncs; the
        // loop only covers a server that rekeys mid-stream.
        loop {
            let resp = wire::read_frame(&mut self.conn)?;
            let Some((out, _caps)) = self.resp_dec.decode(&resp)? else { continue };
            // Handing an owned Vec across the library boundary is a real
            // payload copy — keep it visible to the bytes-copied audit.
            return Ok(out.data.to_vec_counted());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::elements::{MqttSrc, QueryServerSink, QueryServerSrc, TensorFilter};
    use crate::mqtt::Broker;
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo};

    fn info4() -> TensorsInfo {
        TensorsInfo::one(TensorInfo::new(DType::U8, &[4]).unwrap())
    }

    #[test]
    fn edge_sensor_to_pipeline_mqttsrc() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        // Pipeline subscriber: mqttsrc -> appsink
        let mut p = Pipeline::new();
        let (sink, rx) = AppSink::new(8);
        let s = p.add("sub", Box::new(MqttSrc::new(&baddr, "sensor/acc"))).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, k).unwrap();
        let running = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(200));
        // Edge side: no pipeline, just the library.
        let mut sensor = EdgeSensor::connect(&baddr, "sensor/acc", &info4()).unwrap();
        sensor.publish(&[1, 2, 3, 4]).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(3)).unwrap();
        assert_eq!(&out.data[..], &[1, 2, 3, 4]);
        assert!(out.pts.is_some());
        sensor.close();
        let _ = running.stop(Duration::from_secs(5));
    }

    #[test]
    fn edge_sensor_validates_payload_size() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let mut sensor =
            EdgeSensor::connect(&broker.addr().to_string(), "t", &info4()).unwrap();
        assert!(sensor.publish(&[1, 2]).is_err());
    }

    #[test]
    fn pipeline_to_edge_output() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let mut output = EdgeOutput::connect(&baddr, "feed/+").unwrap();
        std::thread::sleep(Duration::from_millis(150));
        let mut sensor = EdgeSensor::connect(&baddr, "feed/a", &info4()).unwrap();
        sensor.publish(&[9, 9, 9, 9]).unwrap();
        let f = output.recv(Duration::from_secs(3)).unwrap();
        assert_eq!(&f.buffer.data[..], &[9, 9, 9, 9]);
        assert!(f.caps.unwrap().is_tensors());
        sensor.close();
        output.close();
    }

    #[test]
    fn edge_sensor_delta_to_edge_output() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[512]).unwrap());
        let mut output = EdgeOutput::connect(&baddr, "feed/delta").unwrap();
        std::thread::sleep(Duration::from_millis(150));
        let mut sensor = EdgeSensor::connect(&baddr, "feed/delta", &info)
            .unwrap()
            .with_codec(Codec::Delta)
            .with_keyframe_interval(4);
        // Correlated frames: one byte steps per frame, rest stays put.
        for i in 0..6u8 {
            let mut payload = vec![7u8; 512];
            payload[17] = i;
            sensor.publish(&payload).unwrap();
        }
        for i in 0..6u8 {
            let f = output.recv(Duration::from_secs(3)).unwrap();
            assert_eq!(f.buffer.data.len(), 512);
            assert_eq!(f.buffer.data[17], i);
            assert_eq!(f.buffer.data[0], 7);
        }
        sensor.close();
        output.close();
    }

    #[test]
    fn edge_query_client_against_pipeline_server() {
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let mut p = Pipeline::new();
        let src = QueryServerSrc::new("edgeop")
            .with_pair_id("edgeop-lib")
            .with_bind(&format!("127.0.0.1:{port}"));
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().rev().copied().collect())
        }));
        let s = p.add("ss", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p.add("sk", Box::new(QueryServerSink::new("edgeop-lib"))).unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let running = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(200));

        let mut qc =
            EdgeQueryClient::connect(&format!("127.0.0.1:{port}"), Duration::from_secs(3)).unwrap();
        qc.set_caps(&info4());
        let out = qc.query(&[1, 2, 3, 4]).unwrap();
        assert_eq!(out, vec![4, 3, 2, 1]);
        let _ = running.stop(Duration::from_secs(5));
    }

    #[test]
    fn edge_query_discovery() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let mut p = Pipeline::new();
        let src = QueryServerSrc::new("edgedisc")
            .with_pair_id("edgedisc-lib")
            .with_bind(&format!("127.0.0.1:{port}"))
            .with_hybrid(&baddr);
        let f = TensorFilter::passthrough();
        let s = p.add("ss", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p.add("sk", Box::new(QueryServerSink::new("edgedisc-lib"))).unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let running = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(300));

        let mut qc = EdgeQueryClient::discover(&baddr, "edgedisc", Duration::from_secs(3)).unwrap();
        qc.set_caps(&info4());
        assert_eq!(qc.query(&[5, 6, 7, 8]).unwrap(), vec![5, 6, 7, 8]);
        let _ = running.stop(Duration::from_secs(5));
    }
}
