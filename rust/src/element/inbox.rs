//! Bounded multi-pad link queues with leaky policies.
//!
//! One [`Inbox`] per element covers all its sink pads under a single lock
//! so a consumer can wait on "any pad has data" (needed by mux/compositor)
//! while producers get per-pad bounded queues with backpressure or leak.
//!
//! Two consumer/producer disciplines share the same queues:
//!
//! - **Thread mode** (blocking): `push` applies backpressure by waiting on
//!   a condvar; `pop_any` blocks until an item arrives.
//! - **Task mode** (cooperative, used by the worker-pool scheduler in
//!   [`crate::element::sched`]): `try_pop_any`/`push_reserved` never
//!   block. A full or empty queue parks the *task* — the peer re-enqueues
//!   it through a registered [`Waker`] — instead of tying a condvar to a
//!   pool worker. `try_reserve` grants one output slot ahead of time so a
//!   pooled producer knows it can emit without blocking mid-`handle`.
//!
//! Both disciplines interoperate on one inbox: reserved slots count
//! against capacity for blocking producers too, so the configured bound
//! is never exceeded no matter who is pushing.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::element::Item;
use crate::metrics::{self, Counter};
use crate::util::{Error, Result};

/// Callback re-enqueueing a parked scheduler task. Registered wakers are
/// consumed (fired once) on the next push / pop / close that makes the
/// awaited transition possible; spurious fires are allowed — the woken
/// task re-checks the queue state and re-parks if nothing changed.
pub type Waker = Arc<dyn Fn() + Send + Sync>;

/// `inbox.wakes`: every waker the inboxes fire (consumer wakes on push,
/// producer wakes on pop/close). One firing per parked-task re-enqueue,
/// i.e. per frame on a parked-heavy pipeline — hot enough to shard
/// (see [`metrics::Registry::sharded_counter`]). Cached so the hot path
/// never touches the registry's name map.
fn wake_counter() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| metrics::global().sharded_counter("inbox.wakes"))
}

/// Consumer wakers taken during a multi-push turn (a fan-out push, an
/// EOS broadcast), fired in ONE pass after every queue was filled instead
/// of one interleaved fire per link. Each inbox holds at most one
/// registered consumer waker (registration consumes), so the inline slot
/// covers the common 1-link case without allocating; `Drop` fires any
/// leftovers so an early-return/error path can never lose a wakeup.
#[derive(Default)]
pub struct WakeBatch {
    first: Option<Waker>,
    rest: Vec<Waker>,
}

impl WakeBatch {
    /// Stash a waker taken by a `*_taking` push.
    pub fn add(&mut self, w: Option<Waker>) {
        let Some(w) = w else { return };
        if self.first.is_none() {
            self.first = Some(w);
        } else {
            self.rest.push(w);
        }
    }

    /// Fire every collected waker (the batch is left empty).
    pub fn fire(&mut self) {
        let mut n = 0u64;
        if let Some(w) = self.first.take() {
            w();
            n += 1;
        }
        for w in self.rest.drain(..) {
            w();
            n += 1;
        }
        if n > 0 {
            wake_counter().add(n);
        }
    }
}

impl Drop for WakeBatch {
    fn drop(&mut self) {
        self.fire();
    }
}

/// Overflow policy of a link queue (GStreamer `queue leaky=` analog).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Leaky {
    /// Block the producer (backpressure).
    #[default]
    No,
    /// Drop the incoming buffer (leaky=upstream / 1).
    Upstream,
    /// Drop the oldest queued buffer (leaky=downstream / 2 — the paper's
    /// `queue leaky=2` for live streams).
    Downstream,
}

impl Leaky {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "no" | "0" => Leaky::No,
            "upstream" | "1" => Leaky::Upstream,
            "downstream" | "2" => Leaky::Downstream,
            other => return Err(Error::Parse(format!("unknown leaky mode `{other}`"))),
        })
    }
}

/// Queue configuration for one sink pad.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueCfg {
    /// Max buffered *buffers* (caps/EOS don't count against the limit).
    pub capacity: usize,
    pub leaky: Leaky,
}

impl Default for QueueCfg {
    fn default() -> Self {
        Self { capacity: 16, leaky: Leaky::No }
    }
}

/// Result of a non-blocking pop.
#[derive(Debug)]
pub enum TryPop {
    /// `(pad, item)` — an item was dequeued.
    Item(usize, Item),
    /// Nothing queued right now; more may arrive.
    Empty,
    /// Closed or every pad is EOS and drained — no item will ever arrive.
    Done,
}

/// Non-destructive variant of [`TryPop`] (used to re-check after waker
/// registration without popping an item the caller can't process yet).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollState {
    Ready,
    Empty,
    Done,
}

/// Result of [`Inbox::try_reserve`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reserve {
    /// One slot reserved; consume it with [`Inbox::push_reserved`] or
    /// return it with [`Inbox::unreserve`].
    Counted,
    /// The pad never blocks (leaky policy, or closed — the push itself
    /// will surface closure); nothing was counted.
    NoNeed,
    /// No slot available; register a producer waker and park.
    Full,
}

struct PadQueue {
    items: VecDeque<Item>,
    buffered: usize, // count of Item::Buffer in `items`
    /// Output slots promised to pooled producers (Leaky::No pads only);
    /// counts against `capacity` for every producer discipline.
    reserved: usize,
    eos: bool,
    cfg: QueueCfg,
    dropped: u64,
    /// Pooled producers parked on this pad, fired when a slot frees.
    producer_wakers: Vec<Waker>,
}

struct Shared {
    pads: Vec<PadQueue>,
    closed: bool,
    rr_next: usize,
    /// The (single) pooled consumer parked on "any pad has data".
    consumer_waker: Option<Waker>,
}

impl Shared {
    fn take_producer_wakers(&mut self, pad: usize) -> Vec<Waker> {
        std::mem::take(&mut self.pads[pad].producer_wakers)
    }
}

/// Multi-pad bounded inbox.
pub struct Inbox {
    shared: Mutex<Shared>,
    not_empty: Condvar,
    not_full: Condvar,
}

fn fire(waker: Option<Waker>) {
    if let Some(w) = waker {
        w();
        wake_counter().inc();
    }
}

fn fire_all(wakers: Vec<Waker>) {
    if wakers.is_empty() {
        return;
    }
    let n = wakers.len() as u64;
    for w in wakers {
        w();
    }
    wake_counter().add(n);
}

impl Inbox {
    pub fn new(cfgs: Vec<QueueCfg>) -> Self {
        let pads = cfgs
            .into_iter()
            .map(|cfg| PadQueue {
                items: VecDeque::new(),
                buffered: 0,
                reserved: 0,
                eos: false,
                cfg,
                dropped: 0,
                producer_wakers: Vec::new(),
            })
            .collect();
        Inbox {
            shared: Mutex::new(Shared { pads, closed: false, rr_next: 0, consumer_waker: None }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    pub fn n_pads(&self) -> usize {
        self.shared.lock().unwrap().pads.len()
    }

    /// Push an item into a pad queue, applying the pad's overflow policy
    /// to buffers. Caps and EOS always enqueue.
    pub fn push(&self, pad: usize, item: Item) -> Result<()> {
        self.push_taking(pad, item).map(fire)
    }

    /// [`Inbox::push`] that RETURNS the consumer waker (if one was
    /// registered) instead of firing it, so a multi-push turn can batch
    /// the fires into one pass — see [`WakeBatch`]. The caller MUST fire
    /// the returned waker.
    pub fn push_taking(&self, pad: usize, item: Item) -> Result<Option<Waker>> {
        let mut s = self.shared.lock().unwrap();
        if pad >= s.pads.len() {
            return Err(Error::Pipeline(format!("push to pad {pad} of {}", s.pads.len())));
        }
        if s.closed {
            return Err(Error::Pipeline("inbox closed".into()));
        }
        if !item.is_buffer() {
            if matches!(item, Item::Eos) {
                s.pads[pad].eos = true;
            }
            s.pads[pad].items.push_back(item);
            let waker = s.consumer_waker.take();
            drop(s);
            // Caps/EOS are rare control events that may change the
            // "all pads EOS" exit condition — wake every waiter.
            self.not_empty.notify_all();
            return Ok(waker);
        }
        loop {
            let p = &mut s.pads[pad];
            // Reserved slots belong to pooled producers; honouring them
            // here keeps the configured capacity a hard bound even when
            // thread and task producers share one pad.
            if p.buffered + p.reserved < p.cfg.capacity {
                p.items.push_back(item);
                p.buffered += 1;
                let waker = s.consumer_waker.take();
                drop(s);
                // One buffer satisfies one pop; notify_one avoids the
                // thundering-herd wakeup storm under multi-producer load
                // (verified by bench_multiclient). Each inbox has a single
                // consumer thread, so one wakeup is always sufficient.
                self.not_empty.notify_one();
                return Ok(waker);
            }
            match p.cfg.leaky {
                Leaky::Upstream => {
                    p.dropped += 1;
                    return Ok(None); // drop incoming
                }
                Leaky::Downstream => {
                    // Drop the oldest buffered item (skip caps).
                    if let Some(pos) = p.items.iter().position(|i| i.is_buffer()) {
                        p.items.remove(pos);
                        p.buffered -= 1;
                        p.dropped += 1;
                    }
                    p.items.push_back(item);
                    p.buffered += 1;
                    let waker = s.consumer_waker.take();
                    drop(s);
                    self.not_empty.notify_one();
                    return Ok(waker);
                }
                Leaky::No => {
                    let (guard, timeout) = self
                        .not_full
                        .wait_timeout(s, Duration::from_millis(100))
                        .map_err(|_| Error::Pipeline("inbox poisoned".into()))?;
                    s = guard;
                    if s.closed {
                        return Err(Error::Pipeline("inbox closed".into()));
                    }
                    let _ = timeout;
                }
            }
        }
    }

    /// Reserve one output slot on a pad ahead of a non-blocking push.
    /// Leaky and closed pads never block, so nothing is counted for them.
    pub fn try_reserve(&self, pad: usize) -> Reserve {
        let mut s = self.shared.lock().unwrap();
        if pad >= s.pads.len() || s.closed {
            return Reserve::NoNeed; // the push itself will report the error
        }
        let p = &mut s.pads[pad];
        if p.cfg.leaky != Leaky::No {
            return Reserve::NoNeed;
        }
        if p.buffered + p.reserved < p.cfg.capacity {
            p.reserved += 1;
            Reserve::Counted
        } else {
            Reserve::Full
        }
    }

    /// Return an unused counted reservation (frees the slot for peers).
    pub fn unreserve(&self, pad: usize) {
        let mut s = self.shared.lock().unwrap();
        if pad >= s.pads.len() {
            return;
        }
        if s.pads[pad].reserved > 0 {
            s.pads[pad].reserved -= 1;
        }
        let wakers = s.take_producer_wakers(pad);
        drop(s);
        self.not_full.notify_all();
        fire_all(wakers);
    }

    /// Non-blocking push consuming a reservation granted by
    /// [`Inbox::try_reserve`]. Must only be called for buffers on
    /// `Leaky::No` pads while holding a counted reservation; control
    /// items and leaky pads take the plain [`Inbox::push`] path (which
    /// never blocks for them). On a closed inbox the reservation is
    /// released and the push errors, mirroring `push`.
    pub fn push_reserved(&self, pad: usize, item: Item) -> Result<()> {
        self.push_reserved_taking(pad, item).map(fire)
    }

    /// [`Inbox::push_reserved`] returning the consumer waker for batched
    /// firing (see [`WakeBatch`]); the caller MUST fire it.
    pub fn push_reserved_taking(&self, pad: usize, item: Item) -> Result<Option<Waker>> {
        if !item.is_buffer() {
            // Control items never block, so the plain path (which already
            // owns the bounds/closed/EOS-flag/wakeup logic) is exact.
            return self.push_taking(pad, item);
        }
        let mut s = self.shared.lock().unwrap();
        if pad >= s.pads.len() {
            return Err(Error::Pipeline(format!("push to pad {pad} of {}", s.pads.len())));
        }
        if s.closed {
            if s.pads[pad].reserved > 0 {
                s.pads[pad].reserved -= 1;
            }
            let wakers = s.take_producer_wakers(pad);
            drop(s);
            self.not_full.notify_all();
            fire_all(wakers);
            return Err(Error::Pipeline("inbox closed".into()));
        }
        let p = &mut s.pads[pad];
        debug_assert!(
            p.cfg.leaky != Leaky::No || p.reserved > 0,
            "push_reserved without a reservation"
        );
        if p.cfg.leaky == Leaky::No && p.reserved > 0 {
            p.reserved -= 1;
        }
        p.items.push_back(item);
        p.buffered += 1;
        let waker = s.consumer_waker.take();
        drop(s);
        self.not_empty.notify_one();
        Ok(waker)
    }

    /// Non-blocking escape hatch for pooled producers pushing a buffer
    /// WITHOUT a reservation onto a full `Leaky::No` pad (an element that
    /// emits more than one buffer per link per input item). Enqueues even
    /// beyond capacity: a transient, bounded overflow is strictly better
    /// than parking a condvar inside a pool worker, which could wedge
    /// every pipeline sharing the pool (all K workers blocked while the
    /// draining consumers sit in the ready queue). Leaky pads and control
    /// items never need this — the plain `push` already cannot block for
    /// them.
    pub fn push_relaxed(&self, pad: usize, item: Item) -> Result<()> {
        self.push_relaxed_taking(pad, item).map(fire)
    }

    /// [`Inbox::push_relaxed`] returning the consumer waker for batched
    /// firing (see [`WakeBatch`]); the caller MUST fire it.
    pub fn push_relaxed_taking(&self, pad: usize, item: Item) -> Result<Option<Waker>> {
        let mut s = self.shared.lock().unwrap();
        if pad >= s.pads.len() {
            return Err(Error::Pipeline(format!("push to pad {pad} of {}", s.pads.len())));
        }
        if s.closed {
            return Err(Error::Pipeline("inbox closed".into()));
        }
        if !item.is_buffer() {
            drop(s);
            return self.push_taking(pad, item);
        }
        let p = &mut s.pads[pad];
        p.items.push_back(item);
        p.buffered += 1;
        let waker = s.consumer_waker.take();
        drop(s);
        self.not_empty.notify_one();
        Ok(waker)
    }

    /// Register a pooled producer parked on `pad` being full. Fired (and
    /// cleared) when a slot frees or the inbox closes.
    pub fn register_producer_waker(&self, pad: usize, w: Waker) {
        let mut s = self.shared.lock().unwrap();
        if pad < s.pads.len() {
            s.pads[pad].producer_wakers.push(w);
        }
    }

    /// Register the pooled consumer parked on "all pads empty". Fired
    /// (and cleared) on the next enqueue or close.
    pub fn set_consumer_waker(&self, w: Waker) {
        self.shared.lock().unwrap().consumer_waker = Some(w);
    }

    fn pop_locked(s: &mut Shared) -> Option<(usize, Item, Vec<Waker>)> {
        let n = s.pads.len();
        if n == 0 {
            return None;
        }
        let start = s.rr_next % n;
        for off in 0..n {
            let pad = (start + off) % n;
            if let Some(item) = s.pads[pad].items.pop_front() {
                let mut wakers = Vec::new();
                if item.is_buffer() {
                    s.pads[pad].buffered -= 1;
                    wakers = s.take_producer_wakers(pad);
                }
                s.rr_next = (pad + 1) % n;
                return Some((pad, item, wakers));
            }
        }
        None
    }

    fn done_locked(s: &Shared) -> bool {
        s.closed || (!s.pads.is_empty() && s.pads.iter().all(|p| p.eos))
    }

    /// Pop the next item from any pad (round-robin across non-empty pads).
    /// Returns None when the inbox is closed or all pads are EOS-drained.
    pub fn pop_any(&self) -> Option<(usize, Item)> {
        let mut s = self.shared.lock().unwrap();
        loop {
            if s.pads.is_empty() {
                return None;
            }
            if let Some((pad, item, wakers)) = Self::pop_locked(&mut s) {
                drop(s);
                self.not_full.notify_all();
                fire_all(wakers);
                return Some((pad, item));
            }
            // All queues empty: finished if closed or every pad hit EOS.
            if Self::done_locked(&s) {
                return None;
            }
            s = self.not_empty.wait(s).ok()?;
        }
    }

    /// Pop from any pad with a timeout; Ok(None) = timed out.
    pub fn pop_any_timeout(&self, timeout: Duration) -> Option<Option<(usize, Item)>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut s = self.shared.lock().unwrap();
        loop {
            if let Some((pad, item, wakers)) = Self::pop_locked(&mut s) {
                drop(s);
                self.not_full.notify_all();
                fire_all(wakers);
                return Some(Some((pad, item)));
            }
            if Self::done_locked(&s) {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Some(None);
            }
            let (guard, _) = self.not_empty.wait_timeout(s, deadline - now).ok()?;
            s = guard;
        }
    }

    /// Non-blocking pop for pooled consumers. Preserves `pop_any`'s
    /// round-robin order and drain-before-done semantics exactly.
    pub fn try_pop_any(&self) -> TryPop {
        let mut s = self.shared.lock().unwrap();
        if s.pads.is_empty() {
            return TryPop::Done;
        }
        if let Some((pad, item, wakers)) = Self::pop_locked(&mut s) {
            drop(s);
            self.not_full.notify_all();
            fire_all(wakers);
            return TryPop::Item(pad, item);
        }
        if Self::done_locked(&s) {
            TryPop::Done
        } else {
            TryPop::Empty
        }
    }

    /// Non-destructive readiness probe (waker re-check before parking).
    pub fn poll_state(&self) -> PollState {
        let s = self.shared.lock().unwrap();
        if s.pads.is_empty() {
            return PollState::Done;
        }
        if s.pads.iter().any(|p| !p.items.is_empty()) {
            return PollState::Ready;
        }
        if Self::done_locked(&s) {
            PollState::Done
        } else {
            PollState::Empty
        }
    }

    /// Unblock all producers/consumers permanently.
    pub fn close(&self) {
        let mut s = self.shared.lock().unwrap();
        s.closed = true;
        let consumer = s.consumer_waker.take();
        let mut producers = Vec::new();
        for pad in 0..s.pads.len() {
            producers.append(&mut s.take_producer_wakers(pad));
        }
        drop(s);
        self.not_empty.notify_all();
        self.not_full.notify_all();
        fire(consumer);
        fire_all(producers);
    }

    /// Buffers dropped by leaky policies on a pad (stats).
    pub fn dropped(&self, pad: usize) -> u64 {
        let s = self.shared.lock().unwrap();
        s.pads.get(pad).map(|p| p.dropped).unwrap_or(0)
    }

    /// Currently queued buffers on a pad.
    pub fn depth(&self, pad: usize) -> usize {
        let s = self.shared.lock().unwrap();
        s.pads.get(pad).map(|p| p.buffered).unwrap_or(0)
    }

    /// Outstanding counted reservations on a pad (stats/tests).
    pub fn reserved(&self, pad: usize) -> usize {
        let s = self.shared.lock().unwrap();
        s.pads.get(pad).map(|p| p.reserved).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    fn buf(n: u8) -> Item {
        Item::Buffer(Buffer::new(vec![n]))
    }

    #[test]
    fn fifo_order_single_pad() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        ib.push(0, buf(1)).unwrap();
        ib.push(0, buf(2)).unwrap();
        let (_, a) = ib.pop_any().unwrap();
        let (_, b) = ib.pop_any().unwrap();
        match (a, b) {
            (Item::Buffer(x), Item::Buffer(y)) => {
                assert_eq!(x.data[0], 1);
                assert_eq!(y.data[0], 2);
            }
            _ => panic!("expected buffers"),
        }
    }

    #[test]
    fn leaky_downstream_drops_oldest() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 2, leaky: Leaky::Downstream }]);
        for i in 1..=5 {
            ib.push(0, buf(i)).unwrap();
        }
        assert_eq!(ib.dropped(0), 3);
        let (_, a) = ib.pop_any().unwrap();
        match a {
            Item::Buffer(x) => assert_eq!(x.data[0], 4), // 1..3 dropped
            _ => panic!(),
        }
    }

    #[test]
    fn leaky_upstream_drops_incoming() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 2, leaky: Leaky::Upstream }]);
        for i in 1..=5 {
            ib.push(0, buf(i)).unwrap();
        }
        assert_eq!(ib.dropped(0), 3);
        let (_, a) = ib.pop_any().unwrap();
        match a {
            Item::Buffer(x) => assert_eq!(x.data[0], 1), // 3..5 dropped
            _ => panic!(),
        }
    }

    #[test]
    fn caps_never_dropped_by_leak() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::Downstream }]);
        ib.push(0, Item::Caps(crate::caps::Caps::any())).unwrap();
        for i in 1..=3 {
            ib.push(0, buf(i)).unwrap();
        }
        let (_, first) = ib.pop_any().unwrap();
        assert!(matches!(first, Item::Caps(_)));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let ib = Arc::new(Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]));
        ib.push(0, buf(1)).unwrap();
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.push(0, buf(2)));
        std::thread::sleep(Duration::from_millis(50));
        let _ = ib.pop_any().unwrap();
        h.join().unwrap().unwrap();
        assert!(matches!(ib.pop_any().unwrap().1, Item::Buffer(_)));
    }

    #[test]
    fn blocking_push_respects_reservations() {
        // A counted reservation withholds the slot from blocking pushers
        // until it is consumed or returned.
        let ib = Arc::new(Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]));
        assert_eq!(ib.try_reserve(0), Reserve::Counted);
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.push(0, buf(1)));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(ib.depth(0), 0); // pusher is parked on the reserved slot
        ib.unreserve(0);
        h.join().unwrap().unwrap();
        assert_eq!(ib.depth(0), 1);
    }

    #[test]
    fn pop_any_round_robins_pads() {
        let ib = Inbox::new(vec![QueueCfg::default(), QueueCfg::default()]);
        ib.push(0, buf(10)).unwrap();
        ib.push(1, buf(20)).unwrap();
        ib.push(0, buf(11)).unwrap();
        let pads: Vec<usize> = (0..3).map(|_| ib.pop_any().unwrap().0).collect();
        assert!(pads.contains(&0) && pads.contains(&1));
    }

    #[test]
    fn all_pads_eos_ends_pop() {
        let ib = Inbox::new(vec![QueueCfg::default(), QueueCfg::default()]);
        ib.push(0, Item::Eos).unwrap();
        ib.push(1, buf(1)).unwrap();
        ib.push(1, Item::Eos).unwrap();
        let mut items = 0;
        while ib.pop_any().is_some() {
            items += 1;
        }
        assert_eq!(items, 3); // eos, buffer, eos drained then None
    }

    #[test]
    fn close_unblocks_consumer() {
        let ib = Arc::new(Inbox::new(vec![QueueCfg::default()]));
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.pop_any());
        std::thread::sleep(Duration::from_millis(50));
        ib.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_unblocks_producer() {
        let ib = Arc::new(Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]));
        ib.push(0, buf(1)).unwrap();
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.push(0, buf(2)));
        std::thread::sleep(Duration::from_millis(50));
        ib.close();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn pop_timeout_expires() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        match ib.pop_any_timeout(Duration::from_millis(30)) {
            Some(None) => {}
            other => panic!("expected timeout, got {:?}", other.map(|o| o.map(|(p, _)| p))),
        }
    }

    #[test]
    fn push_invalid_pad_errors() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        assert!(ib.push(3, buf(1)).is_err());
    }

    #[test]
    fn leaky_parse() {
        assert_eq!(Leaky::parse("2").unwrap(), Leaky::Downstream);
        assert_eq!(Leaky::parse("downstream").unwrap(), Leaky::Downstream);
        assert_eq!(Leaky::parse("no").unwrap(), Leaky::No);
        assert!(Leaky::parse("9").is_err());
    }

    #[test]
    fn depth_tracks_buffers() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        assert_eq!(ib.depth(0), 0);
        ib.push(0, buf(1)).unwrap();
        ib.push(0, Item::Caps(crate::caps::Caps::any())).unwrap();
        assert_eq!(ib.depth(0), 1);
    }

    // -- task-mode (non-blocking) API ------------------------------------

    #[test]
    fn try_pop_matches_pop_semantics() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        assert!(matches!(ib.try_pop_any(), TryPop::Empty));
        ib.push(0, buf(1)).unwrap();
        ib.push(0, Item::Eos).unwrap();
        assert!(matches!(ib.try_pop_any(), TryPop::Item(0, Item::Buffer(_))));
        assert!(matches!(ib.try_pop_any(), TryPop::Item(0, Item::Eos)));
        assert!(matches!(ib.try_pop_any(), TryPop::Done));
    }

    #[test]
    fn reserve_accounting() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 2, leaky: Leaky::No }]);
        assert_eq!(ib.try_reserve(0), Reserve::Counted);
        assert_eq!(ib.try_reserve(0), Reserve::Counted);
        assert_eq!(ib.try_reserve(0), Reserve::Full);
        assert_eq!(ib.reserved(0), 2);
        ib.unreserve(0);
        assert_eq!(ib.try_reserve(0), Reserve::Counted);
        ib.push_reserved(0, buf(1)).unwrap();
        ib.push_reserved(0, buf(2)).unwrap();
        assert_eq!(ib.reserved(0), 0);
        assert_eq!(ib.depth(0), 2);
        assert_eq!(ib.try_reserve(0), Reserve::Full);
    }

    #[test]
    fn leaky_pads_never_need_reservations() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::Downstream }]);
        assert_eq!(ib.try_reserve(0), Reserve::NoNeed);
    }

    #[test]
    fn push_relaxed_exceeds_capacity_without_blocking() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]);
        ib.push(0, buf(1)).unwrap();
        ib.push_relaxed(0, buf(2)).unwrap(); // full: over-capacity enqueue
        assert_eq!(ib.depth(0), 2);
        assert!(matches!(ib.pop_any().unwrap().1, Item::Buffer(_)));
        assert!(matches!(ib.pop_any().unwrap().1, Item::Buffer(_)));
        ib.close();
        assert!(ib.push_relaxed(0, buf(3)).is_err());
    }

    #[test]
    fn push_reserved_on_closed_releases_and_errors() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]);
        assert_eq!(ib.try_reserve(0), Reserve::Counted);
        ib.close();
        assert!(ib.push_reserved(0, buf(1)).is_err());
        assert_eq!(ib.reserved(0), 0);
    }

    #[test]
    fn consumer_waker_fires_on_push() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        ib.set_consumer_waker(Arc::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        ib.push(0, buf(1)).unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        // Consumed: a second push does not re-fire.
        ib.push(0, buf(2)).unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn producer_waker_fires_on_pop_and_close() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]);
        ib.push(0, buf(1)).unwrap();
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        ib.register_producer_waker(0, Arc::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        let _ = ib.pop_any().unwrap(); // space freed -> waker fires
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        let h2 = hits.clone();
        ib.register_producer_waker(0, Arc::new(move || {
            h2.fetch_add(1, Ordering::Relaxed);
        }));
        ib.close();
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn push_taking_defers_consumer_wake_to_caller() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        ib.set_consumer_waker(Arc::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        let w = ib.push_taking(0, buf(1)).unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 0, "taken, not fired");
        let mut batch = WakeBatch::default();
        batch.add(w);
        batch.add(ib.push_taking(0, buf(2)).unwrap()); // None: already taken
        batch.fire();
        assert_eq!(hits.load(Ordering::Relaxed), 1, "one wake per burst");
    }

    #[test]
    fn wake_batch_drop_fires_leftovers() {
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        {
            let mut batch = WakeBatch::default();
            batch.add(Some(Arc::new(move || {
                h.fetch_add(1, Ordering::Relaxed);
            })));
            // Dropped without an explicit fire() — e.g. an error return.
        }
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn poll_state_tracks_readiness() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        assert_eq!(ib.poll_state(), PollState::Empty);
        ib.push(0, buf(1)).unwrap();
        assert_eq!(ib.poll_state(), PollState::Ready);
        let _ = ib.pop_any().unwrap();
        assert_eq!(ib.poll_state(), PollState::Empty);
        ib.push(0, Item::Eos).unwrap();
        assert_eq!(ib.poll_state(), PollState::Ready); // EOS still drains
        let _ = ib.pop_any();
        assert_eq!(ib.poll_state(), PollState::Done);
    }
}
