//! Bounded multi-pad link queues with leaky policies.
//!
//! One [`Inbox`] per element covers all its sink pads under a single lock
//! so a consumer can wait on "any pad has data" (needed by mux/compositor)
//! while producers get per-pad bounded queues with backpressure or leak.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::element::Item;
use crate::util::{Error, Result};

/// Overflow policy of a link queue (GStreamer `queue leaky=` analog).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Leaky {
    /// Block the producer (backpressure).
    #[default]
    No,
    /// Drop the incoming buffer (leaky=upstream / 1).
    Upstream,
    /// Drop the oldest queued buffer (leaky=downstream / 2 — the paper's
    /// `queue leaky=2` for live streams).
    Downstream,
}

impl Leaky {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "no" | "0" => Leaky::No,
            "upstream" | "1" => Leaky::Upstream,
            "downstream" | "2" => Leaky::Downstream,
            other => return Err(Error::Parse(format!("unknown leaky mode `{other}`"))),
        })
    }
}

/// Queue configuration for one sink pad.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueCfg {
    /// Max buffered *buffers* (caps/EOS don't count against the limit).
    pub capacity: usize,
    pub leaky: Leaky,
}

impl Default for QueueCfg {
    fn default() -> Self {
        Self { capacity: 16, leaky: Leaky::No }
    }
}

struct PadQueue {
    items: VecDeque<Item>,
    buffered: usize, // count of Item::Buffer in `items`
    eos: bool,
    cfg: QueueCfg,
    dropped: u64,
}

struct Shared {
    pads: Vec<PadQueue>,
    closed: bool,
    rr_next: usize,
}

/// Multi-pad bounded inbox.
pub struct Inbox {
    shared: Mutex<Shared>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl Inbox {
    pub fn new(cfgs: Vec<QueueCfg>) -> Self {
        let pads = cfgs
            .into_iter()
            .map(|cfg| PadQueue { items: VecDeque::new(), buffered: 0, eos: false, cfg, dropped: 0 })
            .collect();
        Inbox { shared: Mutex::new(Shared { pads, closed: false, rr_next: 0 }), not_empty: Condvar::new(), not_full: Condvar::new() }
    }

    pub fn n_pads(&self) -> usize {
        self.shared.lock().unwrap().pads.len()
    }

    /// Push an item into a pad queue, applying the pad's overflow policy
    /// to buffers. Caps and EOS always enqueue.
    pub fn push(&self, pad: usize, item: Item) -> Result<()> {
        let mut s = self.shared.lock().unwrap();
        if pad >= s.pads.len() {
            return Err(Error::Pipeline(format!("push to pad {pad} of {}", s.pads.len())));
        }
        if s.closed {
            return Err(Error::Pipeline("inbox closed".into()));
        }
        if !item.is_buffer() {
            if matches!(item, Item::Eos) {
                s.pads[pad].eos = true;
            }
            s.pads[pad].items.push_back(item);
            // Caps/EOS are rare control events that may change the
            // "all pads EOS" exit condition — wake every waiter.
            self.not_empty.notify_all();
            return Ok(());
        }
        loop {
            let p = &mut s.pads[pad];
            if p.buffered < p.cfg.capacity {
                p.items.push_back(item);
                p.buffered += 1;
                // One buffer satisfies one pop; notify_one avoids the
                // thundering-herd wakeup storm under multi-producer load
                // (verified by bench_multiclient). Each inbox has a single
                // consumer thread, so one wakeup is always sufficient.
                self.not_empty.notify_one();
                return Ok(());
            }
            match p.cfg.leaky {
                Leaky::Upstream => {
                    p.dropped += 1;
                    return Ok(()); // drop incoming
                }
                Leaky::Downstream => {
                    // Drop the oldest buffered item (skip caps).
                    if let Some(pos) = p.items.iter().position(|i| i.is_buffer()) {
                        p.items.remove(pos);
                        p.buffered -= 1;
                        p.dropped += 1;
                    }
                    p.items.push_back(item);
                    p.buffered += 1;
                    self.not_empty.notify_one();
                    return Ok(());
                }
                Leaky::No => {
                    let (guard, timeout) = self
                        .not_full
                        .wait_timeout(s, Duration::from_millis(100))
                        .map_err(|_| Error::Pipeline("inbox poisoned".into()))?;
                    s = guard;
                    if s.closed {
                        return Err(Error::Pipeline("inbox closed".into()));
                    }
                    let _ = timeout;
                }
            }
        }
    }

    /// Pop the next item from any pad (round-robin across non-empty pads).
    /// Returns None when the inbox is closed or all pads are EOS-drained.
    pub fn pop_any(&self) -> Option<(usize, Item)> {
        let mut s = self.shared.lock().unwrap();
        loop {
            let n = s.pads.len();
            if n == 0 {
                return None;
            }
            let start = s.rr_next % n;
            for off in 0..n {
                let pad = (start + off) % n;
                if let Some(item) = s.pads[pad].items.pop_front() {
                    if item.is_buffer() {
                        s.pads[pad].buffered -= 1;
                    }
                    s.rr_next = (pad + 1) % n;
                    self.not_full.notify_all();
                    return Some((pad, item));
                }
            }
            // All queues empty: finished if closed or every pad hit EOS.
            if s.closed || s.pads.iter().all(|p| p.eos) {
                return None;
            }
            s = self.not_empty.wait(s).ok()?;
        }
    }

    /// Pop from any pad with a timeout; Ok(None) = timed out.
    pub fn pop_any_timeout(&self, timeout: Duration) -> Option<Option<(usize, Item)>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut s = self.shared.lock().unwrap();
        loop {
            let n = s.pads.len();
            let start = if n == 0 { 0 } else { s.rr_next % n };
            for off in 0..n {
                let pad = (start + off) % n;
                if let Some(item) = s.pads[pad].items.pop_front() {
                    if item.is_buffer() {
                        s.pads[pad].buffered -= 1;
                    }
                    s.rr_next = (pad + 1) % n;
                    self.not_full.notify_all();
                    return Some(Some((pad, item)));
                }
            }
            if s.closed || (n > 0 && s.pads.iter().all(|p| p.eos)) {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Some(None);
            }
            let (guard, _) = self.not_empty.wait_timeout(s, deadline - now).ok()?;
            s = guard;
        }
    }

    /// Unblock all producers/consumers permanently.
    pub fn close(&self) {
        let mut s = self.shared.lock().unwrap();
        s.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Buffers dropped by leaky policies on a pad (stats).
    pub fn dropped(&self, pad: usize) -> u64 {
        let s = self.shared.lock().unwrap();
        s.pads.get(pad).map(|p| p.dropped).unwrap_or(0)
    }

    /// Currently queued buffers on a pad.
    pub fn depth(&self, pad: usize) -> usize {
        let s = self.shared.lock().unwrap();
        s.pads.get(pad).map(|p| p.buffered).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use std::sync::Arc;

    fn buf(n: u8) -> Item {
        Item::Buffer(Buffer::new(vec![n]))
    }

    #[test]
    fn fifo_order_single_pad() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        ib.push(0, buf(1)).unwrap();
        ib.push(0, buf(2)).unwrap();
        let (_, a) = ib.pop_any().unwrap();
        let (_, b) = ib.pop_any().unwrap();
        match (a, b) {
            (Item::Buffer(x), Item::Buffer(y)) => {
                assert_eq!(x.data[0], 1);
                assert_eq!(y.data[0], 2);
            }
            _ => panic!("expected buffers"),
        }
    }

    #[test]
    fn leaky_downstream_drops_oldest() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 2, leaky: Leaky::Downstream }]);
        for i in 1..=5 {
            ib.push(0, buf(i)).unwrap();
        }
        assert_eq!(ib.dropped(0), 3);
        let (_, a) = ib.pop_any().unwrap();
        match a {
            Item::Buffer(x) => assert_eq!(x.data[0], 4), // 1..3 dropped
            _ => panic!(),
        }
    }

    #[test]
    fn leaky_upstream_drops_incoming() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 2, leaky: Leaky::Upstream }]);
        for i in 1..=5 {
            ib.push(0, buf(i)).unwrap();
        }
        assert_eq!(ib.dropped(0), 3);
        let (_, a) = ib.pop_any().unwrap();
        match a {
            Item::Buffer(x) => assert_eq!(x.data[0], 1), // 3..5 dropped
            _ => panic!(),
        }
    }

    #[test]
    fn caps_never_dropped_by_leak() {
        let ib = Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::Downstream }]);
        ib.push(0, Item::Caps(crate::caps::Caps::any())).unwrap();
        for i in 1..=3 {
            ib.push(0, buf(i)).unwrap();
        }
        let (_, first) = ib.pop_any().unwrap();
        assert!(matches!(first, Item::Caps(_)));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let ib = Arc::new(Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]));
        ib.push(0, buf(1)).unwrap();
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.push(0, buf(2)));
        std::thread::sleep(Duration::from_millis(50));
        let _ = ib.pop_any().unwrap();
        h.join().unwrap().unwrap();
        assert!(matches!(ib.pop_any().unwrap().1, Item::Buffer(_)));
    }

    #[test]
    fn pop_any_round_robins_pads() {
        let ib = Inbox::new(vec![QueueCfg::default(), QueueCfg::default()]);
        ib.push(0, buf(10)).unwrap();
        ib.push(1, buf(20)).unwrap();
        ib.push(0, buf(11)).unwrap();
        let pads: Vec<usize> =
            (0..3).map(|_| ib.pop_any().unwrap().0).collect();
        assert!(pads.contains(&0) && pads.contains(&1));
    }

    #[test]
    fn all_pads_eos_ends_pop() {
        let ib = Inbox::new(vec![QueueCfg::default(), QueueCfg::default()]);
        ib.push(0, Item::Eos).unwrap();
        ib.push(1, buf(1)).unwrap();
        ib.push(1, Item::Eos).unwrap();
        let mut items = 0;
        while ib.pop_any().is_some() {
            items += 1;
        }
        assert_eq!(items, 3); // eos, buffer, eos drained then None
    }

    #[test]
    fn close_unblocks_consumer() {
        let ib = Arc::new(Inbox::new(vec![QueueCfg::default()]));
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.pop_any());
        std::thread::sleep(Duration::from_millis(50));
        ib.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_unblocks_producer() {
        let ib = Arc::new(Inbox::new(vec![QueueCfg { capacity: 1, leaky: Leaky::No }]));
        ib.push(0, buf(1)).unwrap();
        let ib2 = ib.clone();
        let h = std::thread::spawn(move || ib2.push(0, buf(2)));
        std::thread::sleep(Duration::from_millis(50));
        ib.close();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn pop_timeout_expires() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        match ib.pop_any_timeout(Duration::from_millis(30)) {
            Some(None) => {}
            other => panic!("expected timeout, got {:?}", other.map(|o| o.map(|(p, _)| p))),
        }
    }

    #[test]
    fn push_invalid_pad_errors() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        assert!(ib.push(3, buf(1)).is_err());
    }

    #[test]
    fn leaky_parse() {
        assert_eq!(Leaky::parse("2").unwrap(), Leaky::Downstream);
        assert_eq!(Leaky::parse("downstream").unwrap(), Leaky::Downstream);
        assert_eq!(Leaky::parse("no").unwrap(), Leaky::No);
        assert!(Leaky::parse("9").is_err());
    }

    #[test]
    fn depth_tracks_buffers() {
        let ib = Inbox::new(vec![QueueCfg::default()]);
        assert_eq!(ib.depth(0), 0);
        ib.push(0, buf(1)).unwrap();
        ib.push(0, Item::Caps(crate::caps::Caps::any())).unwrap();
        assert_eq!(ib.depth(0), 1);
    }
}
