//! The element model: pipe-and-filter nodes exchanging [`Item`]s over
//! bounded link queues (GStreamer pads/queues analog).
//!
//! Execution is hybrid (see [`sched`]): `Compute` elements run as
//! cooperative tasks on a process-wide worker pool, so pipeline count
//! scales independently of thread count; `Blocking` elements (sockets,
//! app channels, live pacing) keep a dedicated thread. Items flow
//! push-based on both paths; caps are sticky in-band events preceding
//! buffers; EOS propagates per pad and is forwarded downstream by the
//! runner once every sink pad saw it.
//!
//! Leaky queues (the paper's `queue leaky=2` tuning knob, §5.1) drop
//! *buffers* under overflow but never caps/EOS, so negotiation and
//! shutdown stay reliable no matter the policy.

pub mod inbox;
pub mod registry;
pub mod sched;

pub use inbox::{Inbox, Leaky, QueueCfg};
pub use registry::{ElementFactory, PipelineEnv, Registry};
pub use sched::{Progress, Workload};

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::clock::PipelineClock;
use crate::element::inbox::{Reserve, WakeBatch, Waker};
use crate::log_warn;
use crate::util::Result;

/// One unit travelling over a link.
#[derive(Debug, Clone)]
pub enum Item {
    /// Sticky stream caps; always precedes the first buffer of a stream.
    Caps(Caps),
    Buffer(Buffer),
    /// End of stream for this pad.
    Eos,
}

impl Item {
    pub fn is_buffer(&self) -> bool {
        matches!(self, Item::Buffer(_))
    }
}

/// Bus messages surfaced to the application.
#[derive(Debug, Clone)]
pub enum BusMsg {
    /// A sink element consumed EOS on all pads.
    Eos { element: String },
    Error { element: String, message: String },
    Info { element: String, message: String },
}

/// Where an element pushes output items (filled by the runner).
pub struct Downstream {
    /// outputs[src_pad] = fan-out list of (inbox, sink pad idx).
    pub outputs: Vec<Vec<(Arc<Inbox>, usize)>>,
}

impl Downstream {
    pub fn none() -> Self {
        Downstream { outputs: Vec::new() }
    }
}

/// Per-element runtime context handed to callbacks.
pub struct Ctx {
    pub name: String,
    pub clock: PipelineClock,
    downstream: Downstream,
    bus: Sender<BusMsg>,
    /// Cooperative stop flag (sources poll it).
    pub stop: Arc<std::sync::atomic::AtomicBool>,
    /// Counted output-slot reservations per (src pad, link) when the
    /// element runs as a pooled task; None on a dedicated thread.
    rsv: Option<Vec<Vec<bool>>>,
    /// One-shot flag: a pooled task pushed a buffer without a reserved
    /// slot onto a full link (multi-buffer emitter — should be Blocking).
    warned_unreserved: bool,
    /// This element's pooled-task waker (None on a dedicated thread).
    /// Elements hand it to external completion sources — e.g. a
    /// [`crate::runtime::BatchCollector`] — so finishing async work
    /// re-queues the parked task.
    task_waker: Option<Waker>,
}

impl Ctx {
    pub fn new(
        name: String,
        clock: PipelineClock,
        downstream: Downstream,
        bus: Sender<BusMsg>,
        stop: Arc<std::sync::atomic::AtomicBool>,
    ) -> Self {
        Self {
            name,
            clock,
            downstream,
            bus,
            stop,
            rsv: None,
            warned_unreserved: false,
            task_waker: None,
        }
    }

    /// Install this element's pooled-task waker (scheduler, at spawn).
    pub(crate) fn set_task_waker(&mut self, w: Waker) {
        self.task_waker = Some(w);
    }

    /// The element's own task waker when it runs as a pooled task; None
    /// on a dedicated thread (thread elements block inline instead of
    /// parking). Firing it re-queues the task, which re-enters
    /// [`Element::pump`].
    pub fn task_waker(&self) -> Option<Waker> {
        self.task_waker.clone()
    }

    /// True once the pipeline asked live sources to wind down.
    pub fn stopped(&self) -> bool {
        self.stop.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Switch pushes to the cooperative (reservation-consuming) protocol.
    /// Called once by the scheduler when the element becomes a task.
    pub(crate) fn enable_reservations(&mut self) {
        self.rsv =
            Some(self.downstream.outputs.iter().map(|links| vec![false; links.len()]).collect());
    }

    /// Reserve one output slot on every backpressured downstream link so
    /// the next item can be pushed without blocking a pool worker.
    /// Returns false when some link is full: a producer waker is left on
    /// that inbox and every already-acquired slot is released first (no
    /// hold-and-wait — two tasks fanning into each other's inboxes can
    /// never deadlock on half-acquired reservations).
    pub(crate) fn acquire_output_slots(&mut self, waker: &Waker) -> bool {
        let Some(rsv) = self.rsv.as_mut() else { return true };
        let outputs = &self.downstream.outputs;
        for (pad, links) in outputs.iter().enumerate() {
            for (i, (inbox, sink_pad)) in links.iter().enumerate() {
                if rsv[pad][i] {
                    continue;
                }
                match inbox.try_reserve(*sink_pad) {
                    Reserve::Counted => rsv[pad][i] = true,
                    Reserve::NoNeed => {}
                    Reserve::Full => {
                        inbox.register_producer_waker(*sink_pad, waker.clone());
                        // Lost-wakeup guard: a slot may have freed between
                        // the failed reserve and the registration.
                        match inbox.try_reserve(*sink_pad) {
                            Reserve::Counted => rsv[pad][i] = true,
                            Reserve::NoNeed => {}
                            Reserve::Full => {
                                for (p2, l2) in outputs.iter().enumerate() {
                                    for (i2, (ib2, sp2)) in l2.iter().enumerate() {
                                        if rsv[p2][i2] {
                                            rsv[p2][i2] = false;
                                            ib2.unreserve(*sp2);
                                        }
                                    }
                                }
                                return false;
                            }
                        }
                    }
                }
            }
        }
        true
    }

    /// Return every slot still reserved (after an item that didn't push
    /// to all links, or before parking) so peers aren't starved.
    pub(crate) fn release_output_slots(&mut self) {
        let Some(rsv) = self.rsv.as_mut() else { return };
        for (pad, links) in self.downstream.outputs.iter().enumerate() {
            for (i, (inbox, sink_pad)) in links.iter().enumerate() {
                if rsv[pad][i] {
                    rsv[pad][i] = false;
                    inbox.unreserve(*sink_pad);
                }
            }
        }
    }

    /// Push an item out of `src_pad`, fanning out to all linked inboxes.
    /// Returns Err only when every downstream is gone (pipeline teardown).
    pub fn push(&mut self, src_pad: usize, item: Item) -> Result<()> {
        let mut wakes = WakeBatch::default();
        let r = self.push_with(src_pad, item, &mut wakes);
        wakes.fire();
        r
    }

    /// Fan-out core of [`Ctx::push`]: enqueues on every link, stashing
    /// the consumer wakers it takes into `wakes` instead of firing them
    /// inline — the caller fires the whole batch in one pass once every
    /// queue of the turn is filled (see [`WakeBatch`]).
    fn push_with(&mut self, src_pad: usize, item: Item, wakes: &mut WakeBatch) -> Result<()> {
        let Some(links) = self.downstream.outputs.get(src_pad) else {
            return Ok(()); // unlinked pad: drop silently (fakesink semantics)
        };
        if links.is_empty() {
            return Ok(());
        }
        let mut alive = false;
        let last = links.len() - 1;
        // Fan-out: clone for every link except the last, which consumes
        // the item (buffer payloads are Arc-shared, so clones are cheap).
        let mut item = Some(item);
        for (i, (inbox, sink_pad)) in links.iter().enumerate() {
            let it = if i == last {
                item.take().expect("item consumed only by the last link")
            } else {
                item.as_ref().expect("item lives until the last link").clone()
            };
            // A pooled task pushes buffers through its pre-acquired slot
            // (never blocks); control items and thread elements use the
            // plain path.
            let reserved = it.is_buffer() && self.rsv.as_ref().is_some_and(|r| r[src_pad][i]);
            let pushed = if reserved {
                if let Some(r) = self.rsv.as_mut() {
                    r[src_pad][i] = false;
                }
                inbox.push_reserved_taking(*sink_pad, it)
            } else if it.is_buffer() && self.rsv.is_some() {
                // Pooled task emitting more buffers than the one slot the
                // scheduler reserved per link: grab a slot non-blockingly
                // when one is free; a genuinely full link enqueues beyond
                // capacity (`push_relaxed`) rather than parking a condvar
                // inside a pool worker — with K such producers that would
                // wedge the whole pool while the draining consumers wait
                // in the ready queue. Warn once so the misclassified
                // element (it should be Workload::Blocking) is visible.
                match inbox.try_reserve(*sink_pad) {
                    Reserve::Counted => inbox.push_reserved_taking(*sink_pad, it),
                    Reserve::NoNeed => inbox.push_taking(*sink_pad, it),
                    Reserve::Full => {
                        if !self.warned_unreserved {
                            self.warned_unreserved = true;
                            log_warn!(
                                "element",
                                "{}: unreserved buffer push on a full link (transient over-capacity enqueue); multi-buffer emitters should be Workload::Blocking",
                                self.name
                            );
                        }
                        inbox.push_relaxed_taking(*sink_pad, it)
                    }
                }
            } else if it.is_buffer() {
                // Thread-mode buffer push (`rsv` is None here — pooled
                // buffers all took the reservation branches above). It
                // may BLOCK on a full `Leaky::No` pad, so fire everything
                // collected so far and let the inbox fire its own waker
                // inline: batching across a blocking push would withhold
                // an earlier link's only wake for the whole stall,
                // starving (or deadlocking) a pooled consumer on the
                // other branch of the fan-out.
                wakes.fire();
                inbox.push(*sink_pad, it).map(|()| None)
            } else {
                // Control items (caps/EOS, any mode): never block.
                inbox.push_taking(*sink_pad, it)
            };
            if let Ok(w) = pushed {
                alive = true;
                wakes.add(w);
            }
        }
        if alive {
            Ok(())
        } else {
            Err(crate::util::Error::Pipeline(format!("{}: all downstream links closed", self.name)))
        }
    }

    /// Push a buffer out of pad 0 (the common case).
    pub fn push_buffer(&mut self, buf: Buffer) -> Result<()> {
        self.push(0, Item::Buffer(buf))
    }

    pub fn push_caps(&mut self, caps: Caps) -> Result<()> {
        self.push(0, Item::Caps(caps))
    }

    pub fn n_src_pads_linked(&self) -> usize {
        self.downstream.outputs.len()
    }

    /// Broadcast EOS on all src pads (runner calls this on teardown).
    /// One pass: every downstream queue receives its EOS first, then all
    /// consumer wakers fire as a single batch — a fan-out teardown wakes
    /// each downstream once instead of interleaving queue ops and wakes.
    pub fn push_eos_all(&mut self) {
        let mut wakes = WakeBatch::default();
        for pad in 0..self.downstream.outputs.len() {
            let _ = self.push_with(pad, Item::Eos, &mut wakes);
        }
        wakes.fire();
    }

    pub fn post_error(&self, message: impl std::fmt::Display) {
        let _ = self
            .bus
            .send(BusMsg::Error { element: self.name.clone(), message: message.to_string() });
    }

    pub fn post_info(&self, message: impl std::fmt::Display) {
        let _ = self
            .bus
            .send(BusMsg::Info { element: self.name.clone(), message: message.to_string() });
    }

    pub fn post_eos(&self) {
        let _ = self.bus.send(BusMsg::Eos { element: self.name.clone() });
    }
}

/// Outcome of an [`Element::pump`] poll (async in-flight work).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Async {
    /// No async work pending — the runner proceeds to pop input.
    Idle,
    /// Async work completed and output was pushed this call; the runner
    /// re-acquires output slots before anything else (the push consumed
    /// the reservations it was holding).
    Delivered,
    /// Async work still in flight — the runner parks the task without
    /// popping input (per-pipeline order: nothing overtakes the
    /// in-flight frame). The element must have handed its task waker to
    /// whatever completes the work, or the task sleeps forever.
    Pending,
}

/// A pipeline element. Implementations are single-threaded — the runner
/// gives each element its own thread (`Workload::Blocking`) or drives it
/// as a pooled task (`Workload::Compute`), never both at once — and
/// communicate only via `Ctx`.
pub trait Element: Send {
    /// Number of sink (input) pads. 0 = source element.
    fn n_sink_pads(&self) -> usize {
        1
    }

    /// Number of src (output) pads. 0 = sink element.
    fn n_src_pads(&self) -> usize {
        1
    }

    /// Grow pads (mux/demux/compositor request pads). Called by the parser
    /// when a pad reference exceeds the current count. Default: error via
    /// returning false.
    fn ensure_sink_pads(&mut self, _n: usize) -> bool {
        false
    }

    fn ensure_src_pads(&mut self, _n: usize) -> bool {
        false
    }

    /// Inbox queue configuration for a sink pad.
    fn sink_queue_cfg(&self, _pad: usize) -> QueueCfg {
        QueueCfg::default()
    }

    /// Scheduling class: `Compute` (default) joins the worker pool;
    /// override to `Blocking` when `start`/`handle`/`produce` may block
    /// on sockets, app channels, or wall-clock pacing.
    fn workload(&self) -> Workload {
        Workload::Compute
    }

    /// Called once before streaming starts.
    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        Ok(())
    }

    /// Handle one inbound item (non-source elements).
    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<()>;

    /// Non-blocking step model driven by both runners. The default
    /// adapter wraps the push-based [`Element::handle`] so existing
    /// elements keep compiling; override to yield the worker after a
    /// bursty item (`NeedOutput`) or finish before EOS (`Done`).
    fn process(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Progress> {
        self.handle(pad, item, ctx)?;
        Ok(Progress::Ready)
    }

    /// Poll async in-flight work (pooled runner only; called each turn
    /// with output slots already acquired, before popping input). Thread
    /// runners never call it — thread-mode elements finish async work
    /// inline in `handle` (blocking their own thread is fine there).
    /// Default: no async work, ever.
    fn pump(&mut self, _ctx: &mut Ctx) -> Result<Async> {
        Ok(Async::Idle)
    }

    /// Produce items (source elements). Return Ok(false) for natural EOS.
    fn produce(&mut self, _ctx: &mut Ctx) -> Result<bool> {
        Ok(false)
    }

    /// Called once after streaming (flush/teardown).
    fn stop(&mut self, _ctx: &mut Ctx) {}
}

/// Helper tracking per-pad EOS for multi-input elements.
#[derive(Debug, Default)]
pub struct EosTracker {
    seen: Vec<bool>,
}

impl EosTracker {
    pub fn new(pads: usize) -> Self {
        Self { seen: vec![false; pads] }
    }

    /// Mark a pad EOS; returns true when ALL pads are done.
    pub fn mark(&mut self, pad: usize) -> bool {
        if pad < self.seen.len() {
            self.seen[pad] = true;
        }
        self.all_eos()
    }

    pub fn all_eos(&self) -> bool {
        self.seen.iter().all(|&b| b)
    }

    pub fn is_eos(&self, pad: usize) -> bool {
        self.seen.get(pad).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eos_tracker_requires_all_pads() {
        let mut t = EosTracker::new(3);
        assert!(!t.mark(0));
        assert!(!t.mark(2));
        assert!(!t.is_eos(1));
        assert!(t.mark(1));
        assert!(t.all_eos());
    }

    #[test]
    fn eos_tracker_out_of_range_ignored() {
        let mut t = EosTracker::new(1);
        assert!(!t.mark(7) || t.is_eos(0) == false);
        assert!(t.mark(0));
    }

    #[test]
    fn item_is_buffer() {
        assert!(Item::Buffer(Buffer::new(vec![])).is_buffer());
        assert!(!Item::Eos.is_buffer());
        assert!(!Item::Caps(Caps::any()).is_buffer());
    }
}
