//! The element model: pipe-and-filter nodes exchanging [`Item`]s over
//! bounded link queues (GStreamer pads/queues analog).
//!
//! Each element runs on its own thread. Items flow push-based; caps are
//! sticky in-band events preceding buffers; EOS propagates per pad and is
//! forwarded downstream by the runner once every sink pad saw it.
//!
//! Leaky queues (the paper's `queue leaky=2` tuning knob, §5.1) drop
//! *buffers* under overflow but never caps/EOS, so negotiation and
//! shutdown stay reliable no matter the policy.

pub mod inbox;
pub mod registry;

pub use inbox::{Inbox, Leaky, QueueCfg};
pub use registry::{ElementFactory, PipelineEnv, Registry};

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::clock::PipelineClock;
use crate::util::Result;

/// One unit travelling over a link.
#[derive(Debug, Clone)]
pub enum Item {
    /// Sticky stream caps; always precedes the first buffer of a stream.
    Caps(Caps),
    Buffer(Buffer),
    /// End of stream for this pad.
    Eos,
}

impl Item {
    pub fn is_buffer(&self) -> bool {
        matches!(self, Item::Buffer(_))
    }
}

/// Bus messages surfaced to the application.
#[derive(Debug, Clone)]
pub enum BusMsg {
    /// A sink element consumed EOS on all pads.
    Eos { element: String },
    Error { element: String, message: String },
    Info { element: String, message: String },
}

/// Where an element pushes output items (filled by the runner).
pub struct Downstream {
    /// outputs[src_pad] = fan-out list of (inbox, sink pad idx).
    pub outputs: Vec<Vec<(Arc<Inbox>, usize)>>,
}

impl Downstream {
    pub fn none() -> Self {
        Downstream { outputs: Vec::new() }
    }
}

/// Per-element runtime context handed to callbacks.
pub struct Ctx {
    pub name: String,
    pub clock: PipelineClock,
    downstream: Downstream,
    bus: Sender<BusMsg>,
    /// Cooperative stop flag (sources poll it).
    pub stop: Arc<std::sync::atomic::AtomicBool>,
}

impl Ctx {
    pub fn new(
        name: String,
        clock: PipelineClock,
        downstream: Downstream,
        bus: Sender<BusMsg>,
        stop: Arc<std::sync::atomic::AtomicBool>,
    ) -> Self {
        Self { name, clock, downstream, bus, stop }
    }

    /// True once the pipeline asked live sources to wind down.
    pub fn stopped(&self) -> bool {
        self.stop.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Push an item out of `src_pad`, fanning out to all linked inboxes.
    /// Returns Err only when every downstream is gone (pipeline teardown).
    pub fn push(&self, src_pad: usize, item: Item) -> Result<()> {
        let Some(links) = self.downstream.outputs.get(src_pad) else {
            return Ok(()); // unlinked pad: drop silently (fakesink semantics)
        };
        if links.is_empty() {
            return Ok(());
        }
        let mut alive = false;
        let last = links.len() - 1;
        for (i, (inbox, pad)) in links[..last].iter().enumerate() {
            let _ = i;
            // Clone is cheap: buffer payloads are Arc-shared.
            if inbox.push(*pad, item.clone()).is_ok() {
                alive = true;
            }
        }
        let (inbox, pad) = &links[last];
        if inbox.push(*pad, item).is_ok() {
            alive = true;
        }
        if alive {
            Ok(())
        } else {
            Err(crate::util::Error::Pipeline(format!("{}: all downstream links closed", self.name)))
        }
    }

    /// Push a buffer out of pad 0 (the common case).
    pub fn push_buffer(&self, buf: Buffer) -> Result<()> {
        self.push(0, Item::Buffer(buf))
    }

    pub fn push_caps(&self, caps: Caps) -> Result<()> {
        self.push(0, Item::Caps(caps))
    }

    pub fn n_src_pads_linked(&self) -> usize {
        self.downstream.outputs.len()
    }

    /// Broadcast EOS on all src pads (runner calls this on teardown).
    pub fn push_eos_all(&self) {
        for pad in 0..self.downstream.outputs.len() {
            let _ = self.push(pad, Item::Eos);
        }
    }

    pub fn post_error(&self, message: impl std::fmt::Display) {
        let _ = self
            .bus
            .send(BusMsg::Error { element: self.name.clone(), message: message.to_string() });
    }

    pub fn post_info(&self, message: impl std::fmt::Display) {
        let _ = self
            .bus
            .send(BusMsg::Info { element: self.name.clone(), message: message.to_string() });
    }

    pub fn post_eos(&self) {
        let _ = self.bus.send(BusMsg::Eos { element: self.name.clone() });
    }
}

/// A pipeline element. Implementations are single-threaded (the runner
/// gives each element its own thread) and communicate only via `Ctx`.
pub trait Element: Send {
    /// Number of sink (input) pads. 0 = source element.
    fn n_sink_pads(&self) -> usize {
        1
    }

    /// Number of src (output) pads. 0 = sink element.
    fn n_src_pads(&self) -> usize {
        1
    }

    /// Grow pads (mux/demux/compositor request pads). Called by the parser
    /// when a pad reference exceeds the current count. Default: error via
    /// returning false.
    fn ensure_sink_pads(&mut self, _n: usize) -> bool {
        false
    }

    fn ensure_src_pads(&mut self, _n: usize) -> bool {
        false
    }

    /// Inbox queue configuration for a sink pad.
    fn sink_queue_cfg(&self, _pad: usize) -> QueueCfg {
        QueueCfg::default()
    }

    /// Called once before streaming starts.
    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        Ok(())
    }

    /// Handle one inbound item (non-source elements).
    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<()>;

    /// Produce items (source elements). Return Ok(false) for natural EOS.
    fn produce(&mut self, _ctx: &mut Ctx) -> Result<bool> {
        Ok(false)
    }

    /// Called once after streaming (flush/teardown).
    fn stop(&mut self, _ctx: &mut Ctx) {}
}

/// Helper tracking per-pad EOS for multi-input elements.
#[derive(Debug, Default)]
pub struct EosTracker {
    seen: Vec<bool>,
}

impl EosTracker {
    pub fn new(pads: usize) -> Self {
        Self { seen: vec![false; pads] }
    }

    /// Mark a pad EOS; returns true when ALL pads are done.
    pub fn mark(&mut self, pad: usize) -> bool {
        if pad < self.seen.len() {
            self.seen[pad] = true;
        }
        self.all_eos()
    }

    pub fn all_eos(&self) -> bool {
        self.seen.iter().all(|&b| b)
    }

    pub fn is_eos(&self, pad: usize) -> bool {
        self.seen.get(pad).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eos_tracker_requires_all_pads() {
        let mut t = EosTracker::new(3);
        assert!(!t.mark(0));
        assert!(!t.mark(2));
        assert!(!t.is_eos(1));
        assert!(t.mark(1));
        assert!(t.all_eos());
    }

    #[test]
    fn eos_tracker_out_of_range_ignored() {
        let mut t = EosTracker::new(1);
        assert!(!t.mark(7) || t.is_eos(0) == false);
        assert!(t.mark(0));
    }

    #[test]
    fn item_is_buffer() {
        assert!(Item::Buffer(Buffer::new(vec![])).is_buffer());
        assert!(!Item::Eos.is_buffer());
        assert!(!Item::Caps(Caps::any()).is_buffer());
    }
}
