//! Element factory registry: maps element kind names (the words of a
//! pipeline description, e.g. `videotestsrc`, `tensor_query_client`) to
//! constructors.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::element::Element;
use crate::util::{Error, Result};

/// Element properties as parsed from a pipeline description.
pub type Props = BTreeMap<String, String>;

/// Shared environment factories may need (artifact locations etc.).
#[derive(Debug, Clone)]
pub struct PipelineEnv {
    /// Directory containing `<model>.hlo.txt` + manifests (AOT outputs).
    pub artifacts_dir: String,
}

impl Default for PipelineEnv {
    fn default() -> Self {
        let dir = std::env::var("EDGEPIPE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self { artifacts_dir: dir }
    }
}

pub type ElementFactory = Arc<dyn Fn(&Props, &PipelineEnv) -> Result<Box<dyn Element>> + Send + Sync>;

/// Factory registry; clone-cheap.
#[derive(Clone, Default)]
pub struct Registry {
    factories: BTreeMap<String, ElementFactory>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry with every built-in element registered.
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        crate::elements::register_all(&mut r);
        r
    }

    pub fn register<F>(&mut self, kind: &str, f: F)
    where
        F: Fn(&Props, &PipelineEnv) -> Result<Box<dyn Element>> + Send + Sync + 'static,
    {
        self.factories.insert(kind.to_string(), Arc::new(f));
    }

    pub fn make(&self, kind: &str, props: &Props, env: &PipelineEnv) -> Result<Box<dyn Element>> {
        let f = self
            .factories
            .get(kind)
            .ok_or_else(|| Error::Parse(format!("unknown element `{kind}`")))?;
        f(props, env)
    }

    pub fn contains(&self, kind: &str) -> bool {
        self.factories.contains_key(kind)
    }

    pub fn kinds(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }
}

/// Property parse helpers shared by element constructors.
pub fn prop_u32(props: &Props, key: &str, default: u32) -> Result<u32> {
    match props.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| Error::Parse(format!("bad {key}={v}"))),
    }
}

pub fn prop_u64(props: &Props, key: &str, default: u64) -> Result<u64> {
    match props.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| Error::Parse(format!("bad {key}={v}"))),
    }
}

pub fn prop_f64(props: &Props, key: &str, default: f64) -> Result<f64> {
    match props.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| Error::Parse(format!("bad {key}={v}"))),
    }
}

pub fn prop_bool(props: &Props, key: &str, default: bool) -> Result<bool> {
    match props.get(key).map(|s| s.as_str()) {
        None => Ok(default),
        Some("true" | "1" | "yes") => Ok(true),
        Some("false" | "0" | "no") => Ok(false),
        Some(v) => Err(Error::Parse(format!("bad {key}={v}"))),
    }
}

pub fn prop_str<'a>(props: &'a Props, key: &str, default: &'a str) -> &'a str {
    props.get(key).map(|s| s.as_str()).unwrap_or(default)
}

pub fn require_str<'a>(props: &'a Props, key: &str, element: &str) -> Result<&'a str> {
    props
        .get(key)
        .map(|s| s.as_str())
        .ok_or_else(|| Error::Parse(format!("{element}: missing required property `{key}`")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::{Ctx, Item};

    struct Dummy;
    impl Element for Dummy {
        fn handle(&mut self, _pad: usize, _item: Item, _ctx: &mut Ctx) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn register_and_make() {
        let mut r = Registry::new();
        r.register("dummy", |_p, _e| Ok(Box::new(Dummy)));
        assert!(r.contains("dummy"));
        let el = r.make("dummy", &Props::new(), &PipelineEnv::default());
        assert!(el.is_ok());
        assert!(r.make("nope", &Props::new(), &PipelineEnv::default()).is_err());
    }

    #[test]
    fn prop_helpers() {
        let mut p = Props::new();
        p.insert("n".into(), "42".into());
        p.insert("b".into(), "true".into());
        p.insert("s".into(), "hello".into());
        assert_eq!(prop_u32(&p, "n", 0).unwrap(), 42);
        assert_eq!(prop_u32(&p, "missing", 7).unwrap(), 7);
        assert!(prop_bool(&p, "b", false).unwrap());
        assert_eq!(prop_str(&p, "s", "d"), "hello");
        assert_eq!(prop_str(&p, "x", "d"), "d");
        assert!(require_str(&p, "s", "el").is_ok());
        assert!(require_str(&p, "zz", "el").is_err());
        p.insert("bad".into(), "xyz".into());
        assert!(prop_u32(&p, "bad", 0).is_err());
        assert!(prop_bool(&p, "bad", false).is_err());
    }
}
