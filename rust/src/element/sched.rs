//! Cooperative worker-pool scheduler: run N pipelines on K threads.
//!
//! The thread-per-element runner burns `pipelines x elements` OS threads
//! before doing any work — the density bottleneck for low-power consumer
//! devices hosting many concurrent AI pipelines (§2, §5.1 tuning). This
//! module decouples pipeline count from thread count: a process-wide pool
//! of K workers (`EDGEPIPE_WORKERS`, default `available_parallelism`)
//! drives element state machines off ready queues.
//!
//! Elements declare a [`Workload`] hint: `Compute` elements (converters,
//! filters, mux/demux, tensor ops, runtime inference) become schedulable
//! tasks; `Blocking` elements (socket-bound sources/sinks, app channels,
//! live-paced capture) keep a dedicated thread exactly as before.
//!
//! ## Queue architecture (work stealing)
//!
//! At 64 pipelines x 6 elements every park/wake/yield used to serialize
//! through ONE shared `Mutex<VecDeque>`; now each worker owns a local
//! deque and steals when empty ([`QueueMode::Stealing`], the default):
//!
//! - A wake issued **on a worker thread** (the overwhelmingly common
//!   case: a push re-enqueueing its downstream consumer) lands on that
//!   worker's own local queue — an uncontended lock.
//! - Wakes from **non-worker threads** (`Blocking` elements, MQTT/zmq
//!   callback threads, pipeline spawn/teardown) fall back to a global
//!   **injector** queue. Workers poll the injector ahead of local work
//!   every [`INJECTOR_TICK`] turns so it can never starve behind a busy
//!   local queue.
//! - A worker with nothing local and an empty injector **steals** from
//!   the front of a victim's deque (round-robin over peers) before
//!   going to sleep.
//!
//! Every dequeue claims the task with a `QUEUED -> RUNNING` CAS, so a
//! wake racing a pop can never be clobbered into a double-run: a stale
//! queue entry simply fails the CAS and is dropped. Idle workers sleep
//! on a signal-counting condvar; wakes issued during a worker's turn are
//! **batched** — the sleep lock is taken once per turn (covering a whole
//! multi-buffer burst plus an EOS fan-out), not once per enqueued task.
//! `EDGEPIPE_SCHED_QUEUE=shared` opts the global pool back into the
//! single shared queue (the pre-work-stealing architecture, kept as the
//! bench comparator).
//!
//! A task never blocks a worker on queue state:
//!
//! - **Input**: [`Inbox::try_pop_any`] instead of the condvar pop; an
//!   empty inbox parks the task with a consumer [`Waker`] that the next
//!   push re-enqueues.
//! - **Output**: before processing an item, the task reserves one slot on
//!   every backpressured (`Leaky::No`) downstream link
//!   ([`Ctx::acquire_output_slots`]); a full link parks the task with a
//!   producer waker fired when the peer pops. Reservations already held
//!   are released before parking (no hold-and-wait, hence no reservation
//!   deadlock) and whenever the task parks, yields, or finishes. A slot
//!   held across items within one turn is harmless: every sink pad has
//!   exactly one producer (enforced by `Pipeline::link_pads`), so the
//!   holder only ever gates itself.
//!
//! Leaky policies, capacity bounds, and caps/EOS ordering are enforced by
//! the same [`Inbox`] code on both paths, so scheduler semantics match the
//! condvar runner bit-for-bit.
//!
//! Observability: `sched.tasks` (spawned), `sched.parks` (task parked),
//! `sched.polls` (step-loop iterations), `sched.local_hits` /
//! `sched.injector_hits` / `sched.steals` (where each dequeue came from —
//! steals is a true cross-worker steal count), and `sched.queue_locks` /
//! `sched.lock_waits` (ready-queue lock acquisitions / acquisitions that
//! had to wait) in the global metrics registry.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, TryLockError, Weak};

use crate::element::inbox::{PollState, TryPop, Waker};
use crate::element::{Async, Ctx, Element, EosTracker, Inbox, Item};
use crate::log_debug;
use crate::metrics::{self, Counter};

/// Scheduling class of an element (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Workload {
    /// CPU-bound, non-blocking callbacks: runs as a pooled task.
    #[default]
    Compute,
    /// May block on sockets/channels/clocks: keeps a dedicated thread.
    Blocking,
}

/// Ready-queue architecture of a pool (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueMode {
    /// Per-worker deques + injector + stealing (the default).
    #[default]
    Stealing,
    /// One shared queue every worker pops (the pre-work-stealing
    /// architecture; `EDGEPIPE_SCHED_QUEUE=shared`, bench comparator).
    Shared,
}

impl QueueMode {
    pub fn from_env() -> Self {
        match std::env::var("EDGEPIPE_SCHED_QUEUE").ok().as_deref() {
            Some("shared") => QueueMode::Shared,
            _ => QueueMode::Stealing,
        }
    }
}

/// Outcome of one non-blocking element step (the `process` model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Progress {
    /// Item handled; keep feeding.
    Ready,
    /// Item handled; nothing to emit until more input arrives
    /// (informational — treated like `Ready` by both runners).
    NeedInput,
    /// Item handled, but yield the worker before the next item — a
    /// cooperative fairness hint for bursty emitters. The threaded
    /// runner (which owns its thread) treats it like `Ready`.
    NeedOutput,
    /// Element finished early; tear it down as if all pads saw EOS.
    Done,
}

/// Items processed per scheduler turn before a task yields the worker.
const STEP_BUDGET: usize = 32;

/// Every Nth dequeue polls the injector BEFORE local work so wakes from
/// non-worker threads can't starve behind a busy local queue.
const INJECTOR_TICK: usize = 61;

// Task lifecycle states (AtomicU8).
const PARKED: u8 = 0;
const QUEUED: u8 = 1;
const RUNNING: u8 = 2;
/// Running, and a waker fired mid-step: re-enqueue instead of parking.
const NOTIFIED: u8 = 3;
const DONE: u8 = 4;

/// Live-task countdown a pipeline joins on at teardown.
pub struct TaskGroup {
    live: Mutex<usize>,
    cv: Condvar,
}

impl TaskGroup {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self { live: Mutex::new(n), cv: Condvar::new() })
    }

    pub fn finish(&self) {
        let mut l = self.live.lock().unwrap();
        *l = l.saturating_sub(1);
        if *l == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every task in the group finished (the pool analog of
    /// joining element threads).
    pub fn wait(&self) {
        let mut l = self.live.lock().unwrap();
        while *l > 0 {
            l = self.cv.wait(l).unwrap();
        }
    }
}

pub(crate) struct SchedMetrics {
    pub tasks: Arc<Counter>,
    pub parks: Arc<Counter>,
    pub steals: Arc<Counter>,
    pub polls: Arc<Counter>,
    pub local_hits: Arc<Counter>,
    pub injector_hits: Arc<Counter>,
    pub queue_locks: Arc<Counter>,
    pub lock_waits: Arc<Counter>,
}

impl SchedMetrics {
    fn new() -> Self {
        let g = metrics::global();
        Self {
            tasks: g.counter("sched.tasks"),
            parks: g.counter("sched.parks"),
            steals: g.counter("sched.steals"),
            polls: g.counter("sched.polls"),
            local_hits: g.counter("sched.local_hits"),
            injector_hits: g.counter("sched.injector_hits"),
            queue_locks: g.counter("sched.queue_locks"),
            lock_waits: g.counter("sched.lock_waits"),
        }
    }
}

/// One element running as a pooled task: the state the per-element thread
/// used to keep on its stack.
pub struct NodeRun {
    element: Box<dyn Element>,
    ctx: Ctx,
    inbox: Option<Arc<Inbox>>,
    tracker: EosTracker,
    started: bool,
    /// All sink pads saw EOS but async in-flight work ([`Element::pump`])
    /// is still draining; finish once the element reports `Async::Idle`.
    draining: bool,
    group: Arc<TaskGroup>,
    waker: Option<Waker>,
}

impl NodeRun {
    pub fn new(
        element: Box<dyn Element>,
        mut ctx: Ctx,
        inbox: Option<Arc<Inbox>>,
        group: Arc<TaskGroup>,
    ) -> Self {
        ctx.enable_reservations();
        let tracker = EosTracker::new(inbox.as_ref().map(|i| i.n_pads()).unwrap_or(0));
        Self { element, ctx, inbox, tracker, started: false, draining: false, group, waker: None }
    }

    /// Drive the element until it parks, exhausts its budget, or ends.
    /// Mirrors `pipeline::spawn_node`'s loop: same start/produce/handle
    /// error paths, same EOS fan-out and bus messages, in the same order.
    fn step(&mut self, m: &SchedMetrics) -> StepOutcome {
        let waker = self.waker.clone().expect("waker installed at spawn");
        if !self.started {
            self.started = true;
            if let Err(e) = self.element.start(&mut self.ctx) {
                self.ctx.post_error(format!("start: {e}"));
                self.ctx.push_eos_all();
                self.group.finish();
                return StepOutcome::Done;
            }
        }
        let inbox = self.inbox.clone();
        for _ in 0..STEP_BUDGET {
            m.polls.inc();
            if !self.ctx.acquire_output_slots(&waker) {
                return StepOutcome::Parked; // producer waker registered
            }
            // Async in-flight work first (e.g. a batched inference the
            // element is waiting on): its output must go downstream
            // before any new input is popped, or per-pipeline frame
            // order breaks.
            match self.element.pump(&mut self.ctx) {
                Ok(Async::Idle) => {}
                Ok(Async::Delivered) => continue, // re-acquire spent slots
                Ok(Async::Pending) => {
                    self.ctx.release_output_slots();
                    return StepOutcome::Parked; // completion fires our waker
                }
                Err(e) => {
                    self.ctx.post_error(format!("pump: {e}"));
                    return self.finish();
                }
            }
            if self.draining {
                return self.finish(); // EOS seen and async work drained
            }
            match &inbox {
                None => {
                    // Source: produce until EOS/stop/error.
                    if self.ctx.stopped() {
                        return self.finish();
                    }
                    match self.element.produce(&mut self.ctx) {
                        Ok(true) => {}
                        Ok(false) => return self.finish(),
                        Err(e) => {
                            self.ctx.post_error(format!("produce: {e}"));
                            return self.finish();
                        }
                    }
                }
                Some(ib) => match ib.try_pop_any() {
                    TryPop::Item(pad, item) => {
                        let eos = matches!(item, Item::Eos);
                        let mut yield_after = false;
                        match self.element.process(pad, item, &mut self.ctx) {
                            Ok(Progress::Ready) | Ok(Progress::NeedInput) => {}
                            Ok(Progress::NeedOutput) => yield_after = true,
                            Ok(Progress::Done) => return self.finish(),
                            Err(e) => {
                                self.ctx.post_error(format!("handle: {e}"));
                                return self.finish();
                            }
                        }
                        // EOS accounting runs on every handled item so the
                        // pooled and threaded runners never diverge. Defer
                        // the actual finish through `draining` so async
                        // in-flight work (pump) delivers before teardown.
                        if eos && self.tracker.mark(pad) {
                            self.draining = true;
                            continue;
                        }
                        if yield_after {
                            self.ctx.release_output_slots();
                            return StepOutcome::Yield;
                        }
                    }
                    TryPop::Empty => {
                        self.ctx.release_output_slots();
                        ib.set_consumer_waker(waker.clone());
                        // Re-check after registration: a push that landed
                        // in between would otherwise be a lost wakeup.
                        return match ib.poll_state() {
                            PollState::Empty => StepOutcome::Parked,
                            PollState::Ready => StepOutcome::Yield,
                            PollState::Done => self.finish(),
                        };
                    }
                    TryPop::Done => return self.finish(),
                },
            }
        }
        self.ctx.release_output_slots();
        StepOutcome::Yield
    }

    fn finish(&mut self) -> StepOutcome {
        self.ctx.release_output_slots();
        self.ctx.push_eos_all();
        self.element.stop(&mut self.ctx);
        if self.ctx.n_src_pads_linked() == 0 {
            self.ctx.post_eos();
        }
        log_debug!("pipeline", "element `{}` done", self.ctx.name);
        self.group.finish();
        StepOutcome::Done
    }

    /// Panic fallback: surface the crash on the bus and release the group
    /// so teardown doesn't hang (a panicking element used to kill only
    /// its own thread; it must not wedge a shared worker's pipelines).
    fn abort(&mut self, what: &str) {
        self.ctx.release_output_slots();
        self.ctx.post_error(what);
        self.ctx.push_eos_all();
        self.group.finish();
    }
}

enum StepOutcome {
    Yield,
    Parked,
    Done,
}

/// A schedulable element (handle kept by the owning pipeline; wakers hold
/// weak refs so dropped pipelines free their elements).
pub struct Task {
    state: AtomicU8,
    run: Mutex<Option<NodeRun>>,
}

/// Idle-worker bookkeeping: `idle` workers are waiting on the condvar,
/// `signals` of them have an unconsumed wakeup. Counting signals (instead
/// of bare notifies) makes wakeups lossless: a notify issued before the
/// sleeper reaches `wait` is banked, not dropped.
struct Sleep {
    idle: usize,
    signals: usize,
}

type ReadyQueue = Mutex<VecDeque<Arc<Task>>>;

thread_local! {
    /// (scheduler address, worker index) when this thread is a pool
    /// worker; wake routing uses it to pick local queue vs injector.
    static WORKER: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
    /// Wakes issued during the current worker turn whose idle-worker
    /// signal is deferred to one end-of-turn batch.
    static PENDING_WAKES: Cell<usize> = const { Cell::new(0) };
}

/// The worker pool. Exactly one process-wide instance serves pipelines
/// ([`global`]): workers are daemon threads with no shutdown path, so
/// constructing additional pools leaks threads (and distorts the
/// resident-thread metric the scheduler exists to minimise) — hence only
/// the hidden bench/test constructor [`Scheduler::start_detached`]
/// besides the global.
pub struct Scheduler {
    injector: ReadyQueue,
    locals: Vec<ReadyQueue>,
    sleep: Mutex<Sleep>,
    cv: Condvar,
    workers: usize,
    queues: QueueMode,
    m: SchedMetrics,
}

/// Pool size: `EDGEPIPE_WORKERS` when set (>0), else the machine's
/// available parallelism.
pub fn workers_from_env() -> usize {
    std::env::var("EDGEPIPE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// The process-wide scheduler (workers spawn lazily on first use).
pub fn global() -> &'static Arc<Scheduler> {
    static G: OnceLock<Arc<Scheduler>> = OnceLock::new();
    G.get_or_init(|| Scheduler::start(workers_from_env(), QueueMode::from_env()))
}

impl Scheduler {
    /// Spawn `k` workers (named `ep-worker-<n>`). They are daemons: idle
    /// workers block on the sleep condvar and never exit.
    fn start(k: usize, queues: QueueMode) -> Arc<Scheduler> {
        let k = k.max(1);
        let s = Arc::new(Scheduler {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..k).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Mutex::new(Sleep { idle: 0, signals: 0 }),
            cv: Condvar::new(),
            workers: k,
            queues,
            m: SchedMetrics::new(),
        });
        for i in 0..s.workers {
            let s2 = s.clone();
            std::thread::Builder::new()
                .name(format!("ep-worker-{i}"))
                .spawn(move || s2.worker_loop(i))
                .expect("spawn scheduler worker");
        }
        s
    }

    /// Extra pool for benches/tests that must compare queue architectures
    /// in one process (the global pool is a singleton). The `k` workers
    /// leak for the process lifetime — never use this on a serving path.
    #[doc(hidden)]
    pub fn start_detached(k: usize, queues: QueueMode) -> Arc<Scheduler> {
        Scheduler::start(k, queues)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn queue_mode(&self) -> QueueMode {
        self.queues
    }

    /// Hand an element to the pool; returns the handle the pipeline keeps
    /// alive until teardown.
    pub fn spawn(self: &Arc<Self>, mut run: NodeRun) -> Arc<Task> {
        let sched = self.clone();
        let task = Arc::new_cyclic(|weak: &Weak<Task>| {
            let w = weak.clone();
            let waker: Waker = Arc::new(move || {
                if let Some(t) = w.upgrade() {
                    sched.wake(&t);
                }
            });
            // The element gets its own task waker too, for async
            // completion sources (batch collectors) to re-queue it.
            run.ctx.set_task_waker(waker.clone());
            run.waker = Some(waker);
            Task { state: AtomicU8::new(QUEUED), run: Mutex::new(Some(run)) }
        });
        self.m.tasks.inc();
        self.enqueue(task.clone());
        task
    }

    /// Counted queue lock: total acquisitions + how many had to wait
    /// (the contention the per-worker deques exist to eliminate).
    fn lock_queue<'a>(&self, q: &'a ReadyQueue) -> MutexGuard<'a, VecDeque<Arc<Task>>> {
        self.m.queue_locks.inc();
        match q.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.m.lock_waits.inc();
                q.lock().unwrap_or_else(|p| p.into_inner())
            }
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }

    /// True when the calling thread is one of THIS pool's workers.
    fn current_worker(self: &Arc<Self>) -> Option<usize> {
        let (addr, id) = WORKER.with(|w| w.get());
        (id != usize::MAX && addr == Arc::as_ptr(self) as usize).then_some(id)
    }

    /// Make a QUEUED task runnable. On a worker thread of this pool the
    /// task lands on that worker's own (uncontended) local queue and the
    /// idle-worker signal is deferred to the end-of-turn batch; any other
    /// thread routes through the injector with an immediate signal.
    fn enqueue(self: &Arc<Self>, task: Arc<Task>) {
        match self.current_worker() {
            Some(id) if self.queues == QueueMode::Stealing => {
                self.lock_queue(&self.locals[id]).push_back(task);
                PENDING_WAKES.with(|p| p.set(p.get() + 1));
            }
            _ => {
                self.lock_queue(&self.injector).push_back(task);
                self.notify(1);
            }
        }
    }

    /// Grant up to `n` banked wakeups to idle workers (one sleep-lock
    /// acquisition covers the whole batch).
    fn notify(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut s = self.sleep.lock().unwrap();
        let grant = n.min(s.idle.saturating_sub(s.signals));
        s.signals += grant;
        drop(s);
        for _ in 0..grant {
            self.cv.notify_one();
        }
    }

    /// Fire the turn's deferred idle-worker signals in one batch.
    fn flush_wakes(&self) {
        let n = PENDING_WAKES.with(|p| p.replace(0));
        self.notify(n);
    }

    /// Re-enqueue a parked task (called from inbox wakers). Safe from any
    /// thread and any task state: a fire during RUNNING is latched as
    /// NOTIFIED so the worker re-queues instead of parking.
    fn wake(self: &Arc<Self>, task: &Arc<Task>) {
        loop {
            match task.state.load(Ordering::SeqCst) {
                PARKED => {
                    if task
                        .state
                        .compare_exchange(PARKED, QUEUED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        self.enqueue(task.clone());
                        return;
                    }
                }
                RUNNING => {
                    if task
                        .state
                        .compare_exchange(RUNNING, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        return;
                    }
                }
                _ => return, // QUEUED / NOTIFIED / DONE: nothing to do
            }
        }
    }

    /// Pop entries off one queue until one wins the `QUEUED -> RUNNING`
    /// claim CAS. A stale entry — its task already claimed by a racing
    /// worker, re-queued elsewhere, or finished — fails the CAS and is
    /// dropped, so a task can never run on two workers at once no matter
    /// how wakes interleave with pops.
    fn claim_from(&self, q: &ReadyQueue) -> Option<Arc<Task>> {
        loop {
            let task = self.lock_queue(q).pop_front()?;
            if task
                .state
                .compare_exchange(QUEUED, RUNNING, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return Some(task);
            }
        }
    }

    /// One full dequeue attempt: local, injector, then steal (see module
    /// docs for the ordering rationale).
    fn scan(&self, id: usize, tick: usize) -> Option<Arc<Task>> {
        if self.queues == QueueMode::Shared {
            let t = self.claim_from(&self.injector)?;
            self.m.injector_hits.inc();
            return Some(t);
        }
        if tick % INJECTOR_TICK == 0 {
            if let Some(t) = self.claim_from(&self.injector) {
                self.m.injector_hits.inc();
                return Some(t);
            }
        }
        if let Some(t) = self.claim_from(&self.locals[id]) {
            self.m.local_hits.inc();
            return Some(t);
        }
        if let Some(t) = self.claim_from(&self.injector) {
            self.m.injector_hits.inc();
            return Some(t);
        }
        for off in 1..self.workers {
            if let Some(t) = self.claim_from(&self.locals[(id + off) % self.workers]) {
                self.m.steals.inc();
                return Some(t);
            }
        }
        None
    }

    /// Block until a task is claimable. The pre-sleep re-scan runs under
    /// the sleep lock: an enqueue landing between a failed scan and
    /// `idle += 1` would find no idle worker to signal, so the re-scan
    /// (which observes every push completed before it) closes that
    /// lost-wakeup window. Lock order is sleep -> queue here; producers
    /// take queue and sleep sequentially, never nested — no deadlock.
    fn next_task(&self, id: usize, tick: &mut usize) -> Arc<Task> {
        loop {
            *tick = tick.wrapping_add(1);
            if let Some(t) = self.scan(id, *tick) {
                return t;
            }
            let mut s = self.sleep.lock().unwrap();
            if let Some(t) = self.scan(id, *tick) {
                return t;
            }
            s.idle += 1;
            while s.signals == 0 {
                s = self.cv.wait(s).unwrap();
            }
            s.signals -= 1;
            s.idle -= 1;
            drop(s);
        }
    }

    fn worker_loop(self: Arc<Self>, id: usize) {
        WORKER.with(|w| w.set((Arc::as_ptr(&self) as usize, id)));
        let mut tick = 0usize;
        loop {
            let task = self.next_task(id, &mut tick);
            // The claim CAS in next_task already moved QUEUED -> RUNNING.
            let outcome = {
                let mut guard = task.run.lock().unwrap_or_else(|p| p.into_inner());
                match guard.as_mut() {
                    Some(run) => {
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            run.step(&self.m)
                        })) {
                            Ok(o) => o,
                            Err(_) => {
                                run.abort("element panicked");
                                StepOutcome::Done
                            }
                        }
                    }
                    None => StepOutcome::Done,
                }
            };
            match outcome {
                StepOutcome::Yield => {
                    task.state.store(QUEUED, Ordering::SeqCst);
                    self.enqueue(task);
                }
                StepOutcome::Parked => {
                    if task
                        .state
                        .compare_exchange(RUNNING, PARKED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        self.m.parks.inc();
                    } else {
                        // A waker fired mid-step (NOTIFIED): run again.
                        task.state.store(QUEUED, Ordering::SeqCst);
                        self.enqueue(task);
                    }
                }
                StepOutcome::Done => {
                    task.state.store(DONE, Ordering::SeqCst);
                    // Drop element + ctx promptly (sockets, channels).
                    *task.run.lock().unwrap_or_else(|p| p.into_inner()) = None;
                }
            }
            // One sleep-lock pass covers every wake this turn issued —
            // a multi-buffer burst or an EOS fan-out signals idle
            // workers once, not once per enqueued task.
            self.flush_wakes();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_group_counts_down() {
        let g = TaskGroup::new(2);
        let g2 = g.clone();
        let h = std::thread::spawn(move || g2.wait());
        g.finish();
        assert!(!h.is_finished());
        g.finish();
        h.join().unwrap();
    }

    #[test]
    fn workers_from_env_default_positive() {
        assert!(workers_from_env() >= 1);
    }

    #[test]
    fn workload_defaults_to_compute() {
        assert_eq!(Workload::default(), Workload::Compute);
    }

    #[test]
    fn queue_mode_defaults_to_stealing() {
        assert_eq!(QueueMode::default(), QueueMode::Stealing);
    }

    #[test]
    fn detached_pools_report_their_shape() {
        let s = Scheduler::start_detached(2, QueueMode::Shared);
        assert_eq!(s.workers(), 2);
        assert_eq!(s.queue_mode(), QueueMode::Shared);
        // Zero workers is clamped, not accepted.
        let s1 = Scheduler::start_detached(0, QueueMode::Stealing);
        assert_eq!(s1.workers(), 1);
    }

    #[test]
    fn notify_banks_signals_for_idle_workers_only() {
        let s = Scheduler::start_detached(1, QueueMode::Stealing);
        // No worker can be idle-registered AND signalled without consuming:
        // the grant never exceeds registered idles.
        s.notify(1000);
        let sl = s.sleep.lock().unwrap();
        assert!(sl.signals <= sl.idle);
    }
}
