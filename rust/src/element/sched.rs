//! Cooperative worker-pool scheduler: run N pipelines on K threads.
//!
//! The thread-per-element runner burns `pipelines x elements` OS threads
//! before doing any work — the density bottleneck for low-power consumer
//! devices hosting many concurrent AI pipelines (§2, §5.1 tuning). This
//! module decouples pipeline count from thread count: a process-wide pool
//! of K workers (`EDGEPIPE_WORKERS`, default `available_parallelism`)
//! drives element state machines off a ready queue.
//!
//! Elements declare a [`Workload`] hint: `Compute` elements (converters,
//! filters, mux/demux, tensor ops, runtime inference) become schedulable
//! tasks; `Blocking` elements (socket-bound sources/sinks, app channels,
//! live-paced capture) keep a dedicated thread exactly as before.
//!
//! A task never blocks a worker on queue state:
//!
//! - **Input**: [`Inbox::try_pop_any`] instead of the condvar pop; an
//!   empty inbox parks the task with a consumer [`Waker`] that the next
//!   push re-enqueues.
//! - **Output**: before processing an item, the task reserves one slot on
//!   every backpressured (`Leaky::No`) downstream link
//!   ([`Ctx::acquire_output_slots`]); a full link parks the task with a
//!   producer waker fired when the peer pops. Reservations already held
//!   are released before parking (no hold-and-wait, hence no reservation
//!   deadlock) and whenever the task parks, yields, or finishes. A slot
//!   held across items within one turn is harmless: every sink pad has
//!   exactly one producer (enforced by `Pipeline::link_pads`), so the
//!   holder only ever gates itself.
//!
//! Leaky policies, capacity bounds, and caps/EOS ordering are enforced by
//! the same [`Inbox`] code on both paths, so scheduler semantics match the
//! condvar runner bit-for-bit.
//!
//! Observability: `sched.tasks` (spawned), `sched.parks` (task parked),
//! `sched.steals` (task continued on a different worker than last time),
//! `sched.polls` (step-loop iterations) in the global metrics registry.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};

use crate::element::inbox::{PollState, TryPop, Waker};
use crate::element::{Ctx, Element, EosTracker, Inbox, Item};
use crate::log_debug;
use crate::metrics::{self, Counter};

/// Scheduling class of an element (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Workload {
    /// CPU-bound, non-blocking callbacks: runs as a pooled task.
    #[default]
    Compute,
    /// May block on sockets/channels/clocks: keeps a dedicated thread.
    Blocking,
}

/// Outcome of one non-blocking element step (the `process` model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Progress {
    /// Item handled; keep feeding.
    Ready,
    /// Item handled; nothing to emit until more input arrives
    /// (informational — treated like `Ready` by both runners).
    NeedInput,
    /// Item handled, but yield the worker before the next item — a
    /// cooperative fairness hint for bursty emitters. The threaded
    /// runner (which owns its thread) treats it like `Ready`.
    NeedOutput,
    /// Element finished early; tear it down as if all pads saw EOS.
    Done,
}

/// Items processed per scheduler turn before a task yields the worker.
const STEP_BUDGET: usize = 32;

// Task lifecycle states (AtomicU8).
const PARKED: u8 = 0;
const QUEUED: u8 = 1;
const RUNNING: u8 = 2;
/// Running, and a waker fired mid-step: re-enqueue instead of parking.
const NOTIFIED: u8 = 3;
const DONE: u8 = 4;

/// Live-task countdown a pipeline joins on at teardown.
pub struct TaskGroup {
    live: Mutex<usize>,
    cv: Condvar,
}

impl TaskGroup {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self { live: Mutex::new(n), cv: Condvar::new() })
    }

    pub fn finish(&self) {
        let mut l = self.live.lock().unwrap();
        *l = l.saturating_sub(1);
        if *l == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every task in the group finished (the pool analog of
    /// joining element threads).
    pub fn wait(&self) {
        let mut l = self.live.lock().unwrap();
        while *l > 0 {
            l = self.cv.wait(l).unwrap();
        }
    }
}

pub(crate) struct SchedMetrics {
    pub tasks: Arc<Counter>,
    pub parks: Arc<Counter>,
    pub steals: Arc<Counter>,
    pub polls: Arc<Counter>,
}

impl SchedMetrics {
    fn new() -> Self {
        let g = metrics::global();
        Self {
            tasks: g.counter("sched.tasks"),
            parks: g.counter("sched.parks"),
            steals: g.counter("sched.steals"),
            polls: g.counter("sched.polls"),
        }
    }
}

/// One element running as a pooled task: the state the per-element thread
/// used to keep on its stack.
pub struct NodeRun {
    element: Box<dyn Element>,
    ctx: Ctx,
    inbox: Option<Arc<Inbox>>,
    tracker: EosTracker,
    started: bool,
    group: Arc<TaskGroup>,
    waker: Option<Waker>,
}

impl NodeRun {
    pub fn new(
        element: Box<dyn Element>,
        mut ctx: Ctx,
        inbox: Option<Arc<Inbox>>,
        group: Arc<TaskGroup>,
    ) -> Self {
        ctx.enable_reservations();
        let tracker = EosTracker::new(inbox.as_ref().map(|i| i.n_pads()).unwrap_or(0));
        Self { element, ctx, inbox, tracker, started: false, group, waker: None }
    }

    /// Drive the element until it parks, exhausts its budget, or ends.
    /// Mirrors `pipeline::spawn_node`'s loop: same start/produce/handle
    /// error paths, same EOS fan-out and bus messages, in the same order.
    fn step(&mut self, m: &SchedMetrics) -> StepOutcome {
        let waker = self.waker.clone().expect("waker installed at spawn");
        if !self.started {
            self.started = true;
            if let Err(e) = self.element.start(&mut self.ctx) {
                self.ctx.post_error(format!("start: {e}"));
                self.ctx.push_eos_all();
                self.group.finish();
                return StepOutcome::Done;
            }
        }
        let inbox = self.inbox.clone();
        for _ in 0..STEP_BUDGET {
            m.polls.inc();
            if !self.ctx.acquire_output_slots(&waker) {
                return StepOutcome::Parked; // producer waker registered
            }
            match &inbox {
                None => {
                    // Source: produce until EOS/stop/error.
                    if self.ctx.stopped() {
                        return self.finish();
                    }
                    match self.element.produce(&mut self.ctx) {
                        Ok(true) => {}
                        Ok(false) => return self.finish(),
                        Err(e) => {
                            self.ctx.post_error(format!("produce: {e}"));
                            return self.finish();
                        }
                    }
                }
                Some(ib) => match ib.try_pop_any() {
                    TryPop::Item(pad, item) => {
                        let eos = matches!(item, Item::Eos);
                        let mut yield_after = false;
                        match self.element.process(pad, item, &mut self.ctx) {
                            Ok(Progress::Ready) | Ok(Progress::NeedInput) => {}
                            Ok(Progress::NeedOutput) => yield_after = true,
                            Ok(Progress::Done) => return self.finish(),
                            Err(e) => {
                                self.ctx.post_error(format!("handle: {e}"));
                                return self.finish();
                            }
                        }
                        // EOS accounting runs on every handled item so the
                        // pooled and threaded runners never diverge.
                        if eos && self.tracker.mark(pad) {
                            return self.finish();
                        }
                        if yield_after {
                            self.ctx.release_output_slots();
                            return StepOutcome::Yield;
                        }
                    }
                    TryPop::Empty => {
                        self.ctx.release_output_slots();
                        ib.set_consumer_waker(waker.clone());
                        // Re-check after registration: a push that landed
                        // in between would otherwise be a lost wakeup.
                        return match ib.poll_state() {
                            PollState::Empty => StepOutcome::Parked,
                            PollState::Ready => StepOutcome::Yield,
                            PollState::Done => self.finish(),
                        };
                    }
                    TryPop::Done => return self.finish(),
                },
            }
        }
        self.ctx.release_output_slots();
        StepOutcome::Yield
    }

    fn finish(&mut self) -> StepOutcome {
        self.ctx.release_output_slots();
        self.ctx.push_eos_all();
        self.element.stop(&mut self.ctx);
        if self.ctx.n_src_pads_linked() == 0 {
            self.ctx.post_eos();
        }
        log_debug!("pipeline", "element `{}` done", self.ctx.name);
        self.group.finish();
        StepOutcome::Done
    }

    /// Panic fallback: surface the crash on the bus and release the group
    /// so teardown doesn't hang (a panicking element used to kill only
    /// its own thread; it must not wedge a shared worker's pipelines).
    fn abort(&mut self, what: &str) {
        self.ctx.release_output_slots();
        self.ctx.post_error(what);
        self.ctx.push_eos_all();
        self.group.finish();
    }
}

enum StepOutcome {
    Yield,
    Parked,
    Done,
}

/// A schedulable element (handle kept by the owning pipeline; wakers hold
/// weak refs so dropped pipelines free their elements).
pub struct Task {
    state: AtomicU8,
    last_worker: AtomicUsize,
    run: Mutex<Option<NodeRun>>,
}

/// The worker pool. Exactly one process-wide instance exists
/// ([`global`]): workers are daemon threads with no shutdown path, so
/// constructing additional pools would leak threads (and distort the
/// resident-thread metric the scheduler exists to minimise) — hence no
/// public constructor.
pub struct Scheduler {
    ready: Mutex<VecDeque<Arc<Task>>>,
    cv: Condvar,
    workers: usize,
    m: SchedMetrics,
}

/// Pool size: `EDGEPIPE_WORKERS` when set (>0), else the machine's
/// available parallelism.
pub fn workers_from_env() -> usize {
    std::env::var("EDGEPIPE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// The process-wide scheduler (workers spawn lazily on first use).
pub fn global() -> &'static Arc<Scheduler> {
    static G: OnceLock<Arc<Scheduler>> = OnceLock::new();
    G.get_or_init(|| Scheduler::start(workers_from_env()))
}

impl Scheduler {
    /// Spawn `k` workers (named `ep-worker-<n>`). They are daemons: idle
    /// workers block on the ready-queue condvar and never exit.
    fn start(k: usize) -> Arc<Scheduler> {
        let s = Arc::new(Scheduler {
            ready: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            workers: k.max(1),
            m: SchedMetrics::new(),
        });
        for i in 0..s.workers {
            let s2 = s.clone();
            std::thread::Builder::new()
                .name(format!("ep-worker-{i}"))
                .spawn(move || s2.worker_loop(i))
                .expect("spawn scheduler worker");
        }
        s
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Hand an element to the pool; returns the handle the pipeline keeps
    /// alive until teardown.
    pub fn spawn(self: &Arc<Self>, mut run: NodeRun) -> Arc<Task> {
        let sched = self.clone();
        let task = Arc::new_cyclic(|weak: &Weak<Task>| {
            let w = weak.clone();
            run.waker = Some(Arc::new(move || {
                if let Some(t) = w.upgrade() {
                    sched.wake(&t);
                }
            }));
            Task {
                state: AtomicU8::new(QUEUED),
                last_worker: AtomicUsize::new(usize::MAX),
                run: Mutex::new(Some(run)),
            }
        });
        self.m.tasks.inc();
        self.enqueue(task.clone());
        task
    }

    fn enqueue(&self, task: Arc<Task>) {
        self.ready.lock().unwrap().push_back(task);
        self.cv.notify_one();
    }

    /// Re-enqueue a parked task (called from inbox wakers). Safe from any
    /// thread and any task state: a fire during RUNNING is latched as
    /// NOTIFIED so the worker re-queues instead of parking.
    fn wake(self: &Arc<Self>, task: &Arc<Task>) {
        loop {
            match task.state.load(Ordering::SeqCst) {
                PARKED => {
                    if task
                        .state
                        .compare_exchange(PARKED, QUEUED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        self.enqueue(task.clone());
                        return;
                    }
                }
                RUNNING => {
                    if task
                        .state
                        .compare_exchange(RUNNING, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        return;
                    }
                }
                _ => return, // QUEUED / NOTIFIED / DONE: nothing to do
            }
        }
    }

    fn worker_loop(self: Arc<Self>, id: usize) {
        loop {
            let task = {
                let mut q = self.ready.lock().unwrap();
                loop {
                    if let Some(t) = q.pop_front() {
                        break t;
                    }
                    q = self.cv.wait(q).unwrap();
                }
            };
            task.state.store(RUNNING, Ordering::SeqCst);
            let prev = task.last_worker.swap(id, Ordering::Relaxed);
            if prev != usize::MAX && prev != id {
                self.m.steals.inc();
            }
            let outcome = {
                let mut guard = task.run.lock().unwrap_or_else(|p| p.into_inner());
                match guard.as_mut() {
                    Some(run) => {
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            run.step(&self.m)
                        })) {
                            Ok(o) => o,
                            Err(_) => {
                                run.abort("element panicked");
                                StepOutcome::Done
                            }
                        }
                    }
                    None => StepOutcome::Done,
                }
            };
            match outcome {
                StepOutcome::Yield => {
                    task.state.store(QUEUED, Ordering::SeqCst);
                    self.enqueue(task);
                }
                StepOutcome::Parked => {
                    if task
                        .state
                        .compare_exchange(RUNNING, PARKED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        self.m.parks.inc();
                    } else {
                        // A waker fired mid-step (NOTIFIED): run again.
                        task.state.store(QUEUED, Ordering::SeqCst);
                        self.enqueue(task);
                    }
                }
                StepOutcome::Done => {
                    task.state.store(DONE, Ordering::SeqCst);
                    // Drop element + ctx promptly (sockets, channels).
                    *task.run.lock().unwrap_or_else(|p| p.into_inner()) = None;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_group_counts_down() {
        let g = TaskGroup::new(2);
        let g2 = g.clone();
        let h = std::thread::spawn(move || g2.wait());
        g.finish();
        assert!(!h.is_finished());
        g.finish();
        h.join().unwrap();
    }

    #[test]
    fn workers_from_env_default_positive() {
        assert!(workers_from_env() >= 1);
    }

    #[test]
    fn workload_defaults_to_compute() {
        assert_eq!(Workload::default(), Workload::Compute);
    }
}
