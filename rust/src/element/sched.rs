//! Cooperative worker-pool scheduler: run N pipelines on K threads.
//!
//! The thread-per-element runner burns `pipelines x elements` OS threads
//! before doing any work — the density bottleneck for low-power consumer
//! devices hosting many concurrent AI pipelines (§2, §5.1 tuning). This
//! module decouples pipeline count from thread count: a process-wide pool
//! of K workers (`EDGEPIPE_WORKERS`, default `available_parallelism`)
//! drives element state machines off ready queues.
//!
//! Elements declare a [`Workload`] hint: `Compute` elements (converters,
//! filters, mux/demux, tensor ops, runtime inference) become schedulable
//! tasks; `Blocking` elements (socket-bound sources/sinks, app channels,
//! live-paced capture) keep a dedicated thread exactly as before.
//!
//! ## Queue architecture (lock-free work stealing)
//!
//! At 64 pipelines x 6 elements every park/wake/yield used to serialize
//! through ONE shared `Mutex<VecDeque>`; now each worker owns a
//! **lock-free Chase-Lev deque** ([`QueueMode::ChaseLev`], the default):
//!
//! - A wake issued **on a worker thread** (the overwhelmingly common
//!   case: a push re-enqueueing its downstream consumer) is a lock-free
//!   bottom push onto that worker's own deque — no mutex, no wait, and
//!   the worker's own pops never contend with it.
//! - Wakes from **non-worker threads** (`Blocking` elements, MQTT/zmq
//!   callback threads, pipeline spawn/teardown) fall back to a global
//!   **injector** queue. The injector keeps its `Mutex` — it is off the
//!   per-frame hot path — but workers drain it in half-the-queue
//!   **batches** (one lock hold moves many tasks) and poll it ahead of
//!   local work every [`INJECTOR_TICK`] turns so it can never starve
//!   behind a busy local queue.
//! - A worker with nothing local and an empty injector **steals a
//!   batch** — up to half the victim's visible queue, each element
//!   claimed by its own top CAS — runs the first claimable task and
//!   parks the rest on its own deque (they surface as `local_hits`).
//!
//! ### Chase-Lev memory-ordering notes
//!
//! The deque is the classic Chase-Lev growable ring with the C11
//! orderings of Lê et al. (PPoPP '13): the owner pushes/pops `bottom`
//! with relaxed loads/stores plus a release fence publishing each slot
//! write; thieves `Acquire`-load `top`, fence, `Acquire`-load `bottom`,
//! read the slot, then claim index `top` with a SeqCst CAS. The owner's
//! pop reserves `bottom - 1` first and re-reads `top` after a SeqCst
//! fence, so a pop racing a steal resolves through `top`: on the
//! one-element boundary both sides CAS `top` and exactly one wins.
//! `top` only ever increases, so the CAS is ABA-free. Growth doubles
//! the power-of-two ring and **retires** (never frees, until `Drop`)
//! the old buffer: a thief holding a stale buffer pointer reads a
//! frozen cell whose value for any still-claimable index is identical
//! in every later generation — its top CAS then certifies the read.
//! A **range** steal (one CAS over `top..top+n`) would be unsound with
//! a bottom-popping owner (the owner can pop inside the claimed range
//! without touching `top`), hence the per-element CAS batch.
//!
//! Every dequeue claims the task with a `QUEUED -> RUNNING` CAS, so a
//! wake racing a pop can never be clobbered into a double-run: a stale
//! queue entry simply fails the CAS and is dropped. Idle workers sleep
//! on a signal-counting condvar; wakes issued during a worker's turn are
//! **batched** — the sleep lock is taken once per turn (covering a whole
//! multi-buffer burst plus an EOS fan-out), not once per enqueued task.
//! A thief that loses a steal CAS treats the scan as "work may remain"
//! and rescans instead of sleeping, preserving the lost-wakeup-free
//! sleep protocol. `EDGEPIPE_SCHED_QUEUE=stealing` opts the global pool
//! back into the schema-4 `Mutex<VecDeque>` per-worker deques and
//! `EDGEPIPE_SCHED_QUEUE=shared` into the single shared queue (both
//! kept as bench comparators).
//!
//! A task never blocks a worker on queue state:
//!
//! - **Input**: [`Inbox::try_pop_any`] instead of the condvar pop; an
//!   empty inbox parks the task with a consumer [`Waker`] that the next
//!   push re-enqueues.
//! - **Output**: before processing an item, the task reserves one slot on
//!   every backpressured (`Leaky::No`) downstream link
//!   ([`Ctx::acquire_output_slots`]); a full link parks the task with a
//!   producer waker fired when the peer pops. Reservations already held
//!   are released before parking (no hold-and-wait, hence no reservation
//!   deadlock) and whenever the task parks, yields, or finishes. A slot
//!   held across items within one turn is harmless: every sink pad has
//!   exactly one producer (enforced by `Pipeline::link_pads`), so the
//!   holder only ever gates itself.
//!
//! Leaky policies, capacity bounds, and caps/EOS ordering are enforced by
//! the same [`Inbox`] code on both paths, so scheduler semantics match the
//! condvar runner bit-for-bit.
//!
//! Observability: `sched.tasks` (spawned), `sched.parks` (task parked),
//! `sched.polls` (step-loop iterations), `sched.local_hits` /
//! `sched.injector_hits` / `sched.steals` (where each claimed dequeue
//! came from — steals counts successful cross-worker steal *visits*),
//! `sched.stolen_tasks` (total tasks transferred by those visits,
//! >= steals when batches move more than one), and `sched.queue_locks` /
//! `sched.lock_waits` (ready-queue lock acquisitions / acquisitions that
//! had to wait — injector-only under `ChaseLev`) in the global metrics
//! registry. All of them are per-thread **sharded** counters
//! ([`metrics::Registry::sharded_counter`]): K workers bumping them per
//! frame would otherwise false-share one cache line.

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, TryLockError, Weak};

use crate::element::inbox::{PollState, TryPop, Waker};
use crate::element::{Async, Ctx, Element, EosTracker, Inbox, Item};
use crate::log_debug;
use crate::metrics::{self, Counter};

/// Scheduling class of an element (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Workload {
    /// CPU-bound, non-blocking callbacks: runs as a pooled task.
    #[default]
    Compute,
    /// May block on sockets/channels/clocks: keeps a dedicated thread.
    Blocking,
}

/// Ready-queue architecture of a pool (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueMode {
    /// Per-worker lock-free Chase-Lev deques + batched injector drains +
    /// batch stealing (the default).
    #[default]
    ChaseLev,
    /// Per-worker `Mutex<VecDeque>` deques + injector + one-task steals
    /// (the schema-4 architecture; `EDGEPIPE_SCHED_QUEUE=stealing`,
    /// bench comparator).
    Stealing,
    /// One shared queue every worker pops (the pre-work-stealing
    /// architecture; `EDGEPIPE_SCHED_QUEUE=shared`, bench comparator).
    Shared,
}

impl QueueMode {
    pub fn from_env() -> Self {
        match std::env::var("EDGEPIPE_SCHED_QUEUE").ok().as_deref() {
            Some("shared") => QueueMode::Shared,
            Some("stealing") => QueueMode::Stealing,
            _ => QueueMode::ChaseLev,
        }
    }
}

/// Outcome of one non-blocking element step (the `process` model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Progress {
    /// Item handled; keep feeding.
    Ready,
    /// Item handled; nothing to emit until more input arrives
    /// (informational — treated like `Ready` by both runners).
    NeedInput,
    /// Item handled, but yield the worker before the next item — a
    /// cooperative fairness hint for bursty emitters. The threaded
    /// runner (which owns its thread) treats it like `Ready`.
    NeedOutput,
    /// Element finished early; tear it down as if all pads saw EOS.
    Done,
}

/// Items processed per scheduler turn before a task yields the worker.
const STEP_BUDGET: usize = 32;

/// Every Nth dequeue polls the injector BEFORE local work so wakes from
/// non-worker threads can't starve behind a busy local queue.
const INJECTOR_TICK: usize = 61;

// Task lifecycle states (AtomicU8).
const PARKED: u8 = 0;
const QUEUED: u8 = 1;
const RUNNING: u8 = 2;
/// Running, and a waker fired mid-step: re-enqueue instead of parking.
const NOTIFIED: u8 = 3;
const DONE: u8 = 4;

// ---------------------------------------------------------------------------
// Chase-Lev lock-free work-stealing deque (hand-rolled; module docs carry
// the memory-ordering discipline and the batch-steal soundness argument).
// ---------------------------------------------------------------------------

/// Initial ring capacity (power of two).
const MIN_DEQUE_CAP: usize = 32;

/// Hard cap on tasks one steal visit transfers (half the victim's queue,
/// but never more than this — a huge victim shouldn't stall the thief).
const MAX_STEAL_BATCH: usize = 16;

/// Tasks one injector lock hold may drain in `ChaseLev` mode.
const INJECTOR_BATCH: usize = 32;

/// Result of a thief's [`ChaseLev::steal`] attempt.
enum Steal<T> {
    /// Claimed the element at `top`.
    Taken(T),
    /// Nothing visible to steal.
    Empty,
    /// Lost the top CAS to the owner or another thief. Work may still
    /// exist — the caller must rescan, never sleep, on this answer.
    Retry,
}

/// Power-of-two ring of raw `Arc` payload pointers. Slots are atomics
/// because a thief reads the cell for index `top` while the owner may be
/// storing into *other* indices of the same ring; a cell holding a
/// still-claimable index is never overwritten within one generation
/// (growth triggers before the ring wraps onto live entries).
struct DequeBuf {
    slots: Box<[AtomicUsize]>,
    mask: usize,
}

impl DequeBuf {
    fn new(cap: usize) -> DequeBuf {
        debug_assert!(cap.is_power_of_two());
        DequeBuf { slots: (0..cap).map(|_| AtomicUsize::new(0)).collect(), mask: cap - 1 }
    }

    fn cap(&self) -> usize {
        self.mask + 1
    }

    fn slot(&self, i: isize) -> &AtomicUsize {
        &self.slots[(i as usize) & self.mask]
    }
}

/// Chase-Lev deque of `Arc<T>` payloads: the owner pushes and pops the
/// bottom without locks or (in the common case) CAS; thieves claim the
/// top with a CAS. `top` is monotonically increasing, so the CAS is
/// ABA-free. See the module docs for the full ordering discipline.
pub(crate) struct ChaseLev<T> {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: AtomicPtr<DequeBuf>,
    /// Rings replaced by growth, freed only on `Drop` (epoch-by-lifetime
    /// retirement): a thief that loaded the old pointer may still read a
    /// frozen cell, and every cell it can certify with a top CAS holds
    /// the same value in all later generations.
    retired: Mutex<Vec<*mut DequeBuf>>,
    _payload: PhantomData<Arc<T>>,
}

// Safety: the deque owns `Arc<T>` payloads (stored as raw pointers) and
// hands them across threads; `*mut DequeBuf` is owned exclusively by the
// deque. Both are safe to send/share exactly when `Arc<T>` is.
unsafe impl<T: Send + Sync> Send for ChaseLev<T> {}
unsafe impl<T: Send + Sync> Sync for ChaseLev<T> {}

impl<T> ChaseLev<T> {
    fn new() -> Self {
        ChaseLev {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Box::into_raw(Box::new(DequeBuf::new(MIN_DEQUE_CAP)))),
            retired: Mutex::new(Vec::new()),
            _payload: PhantomData,
        }
    }

    /// Entries visible right now — exact for the owner, a racy hint for
    /// thieves sizing a batch.
    fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        b.saturating_sub(t).max(0) as usize
    }

    /// Owner-only: push one element on the bottom.
    fn push(&self, v: Arc<T>) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        if b - t >= buf.cap() as isize {
            self.grow(t, b);
            buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        }
        buf.slot(b).store(Arc::into_raw(v) as usize, Ordering::Relaxed);
        // Publish the slot BEFORE the new bottom: a thief observing the
        // incremented bottom must also observe the slot write.
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner-only (called from `push`): double the ring, copying live
    /// indices `t..b`; retire the old ring until `Drop`.
    fn grow(&self, t: isize, b: isize) {
        let old = unsafe { &*self.buf.load(Ordering::Relaxed) };
        let new = Box::new(DequeBuf::new(old.cap() * 2));
        for i in t..b {
            new.slot(i).store(old.slot(i).load(Ordering::Relaxed), Ordering::Relaxed);
        }
        let old_ptr = self.buf.swap(Box::into_raw(new), Ordering::Release);
        self.retired.lock().unwrap_or_else(|p| p.into_inner()).push(old_ptr);
    }

    /// Owner-only: pop one element off the bottom (LIFO).
    fn pop(&self) -> Option<Arc<T>> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        self.bottom.store(b, Ordering::Relaxed);
        // Order our bottom reservation against thief top reads: either a
        // racing thief observes the reservation, or we observe its CAS —
        // never both taking the same element.
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Empty: undo the reservation.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let raw = buf.slot(b).load(Ordering::Relaxed);
        if t == b {
            // Last element: race thieves for it through the top CAS.
            let won =
                self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok();
            self.bottom.store(b + 1, Ordering::Relaxed);
            if !won {
                return None; // a thief got it first
            }
        }
        Some(unsafe { Arc::from_raw(raw as *const T) })
    }

    /// Thief: claim the element at `top`. The slot is read BEFORE the
    /// CAS (afterwards the owner may legally overwrite the cell); CAS
    /// success certifies the value read really was index `top`'s.
    fn steal(&self) -> Steal<Arc<T>> {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        // Observing b > t (released by the owner's push fence) implies
        // this Acquire load observes a generation holding index t; an
        // older generation read keeps a frozen copy of the same value
        // alive via `retired`.
        let buf = unsafe { &*self.buf.load(Ordering::Acquire) };
        let raw = buf.slot(t).load(Ordering::Relaxed);
        if self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_err() {
            return Steal::Retry;
        }
        Steal::Taken(unsafe { Arc::from_raw(raw as *const T) })
    }
}

impl<T> Drop for ChaseLev<T> {
    fn drop(&mut self) {
        // Exclusive access: release remaining payloads, then every ring
        // generation.
        while self.pop().is_some() {}
        let cur = *self.buf.get_mut();
        drop(unsafe { Box::from_raw(cur) });
        let retired = self.retired.get_mut().unwrap_or_else(|p| p.into_inner());
        for p in retired.drain(..) {
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

/// Live-task countdown a pipeline joins on at teardown.
pub struct TaskGroup {
    live: Mutex<usize>,
    cv: Condvar,
}

impl TaskGroup {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self { live: Mutex::new(n), cv: Condvar::new() })
    }

    pub fn finish(&self) {
        let mut l = self.live.lock().unwrap();
        *l = l.saturating_sub(1);
        if *l == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every task in the group finished (the pool analog of
    /// joining element threads).
    pub fn wait(&self) {
        let mut l = self.live.lock().unwrap();
        while *l > 0 {
            l = self.cv.wait(l).unwrap();
        }
    }
}

/// Sharded throughout: every counter here is bumped per frame (or per
/// dequeue) by K workers at once — the false-sharing hot set the sharded
/// counter variant exists for.
pub(crate) struct SchedMetrics {
    pub tasks: Arc<Counter>,
    pub parks: Arc<Counter>,
    pub steals: Arc<Counter>,
    pub stolen_tasks: Arc<Counter>,
    pub polls: Arc<Counter>,
    pub local_hits: Arc<Counter>,
    pub injector_hits: Arc<Counter>,
    pub queue_locks: Arc<Counter>,
    pub lock_waits: Arc<Counter>,
}

impl SchedMetrics {
    fn new() -> Self {
        let g = metrics::global();
        Self {
            tasks: g.sharded_counter("sched.tasks"),
            parks: g.sharded_counter("sched.parks"),
            steals: g.sharded_counter("sched.steals"),
            stolen_tasks: g.sharded_counter("sched.stolen_tasks"),
            polls: g.sharded_counter("sched.polls"),
            local_hits: g.sharded_counter("sched.local_hits"),
            injector_hits: g.sharded_counter("sched.injector_hits"),
            queue_locks: g.sharded_counter("sched.queue_locks"),
            lock_waits: g.sharded_counter("sched.lock_waits"),
        }
    }
}

/// One element running as a pooled task: the state the per-element thread
/// used to keep on its stack.
pub struct NodeRun {
    element: Box<dyn Element>,
    ctx: Ctx,
    inbox: Option<Arc<Inbox>>,
    tracker: EosTracker,
    started: bool,
    /// All sink pads saw EOS but async in-flight work ([`Element::pump`])
    /// is still draining; finish once the element reports `Async::Idle`.
    draining: bool,
    group: Arc<TaskGroup>,
    waker: Option<Waker>,
}

impl NodeRun {
    pub fn new(
        element: Box<dyn Element>,
        mut ctx: Ctx,
        inbox: Option<Arc<Inbox>>,
        group: Arc<TaskGroup>,
    ) -> Self {
        ctx.enable_reservations();
        let tracker = EosTracker::new(inbox.as_ref().map(|i| i.n_pads()).unwrap_or(0));
        Self { element, ctx, inbox, tracker, started: false, draining: false, group, waker: None }
    }

    /// Drive the element until it parks, exhausts its budget, or ends.
    /// Mirrors `pipeline::spawn_node`'s loop: same start/produce/handle
    /// error paths, same EOS fan-out and bus messages, in the same order.
    fn step(&mut self, m: &SchedMetrics) -> StepOutcome {
        let waker = self.waker.clone().expect("waker installed at spawn");
        if !self.started {
            self.started = true;
            if let Err(e) = self.element.start(&mut self.ctx) {
                self.ctx.post_error(format!("start: {e}"));
                self.ctx.push_eos_all();
                self.group.finish();
                return StepOutcome::Done;
            }
        }
        let inbox = self.inbox.clone();
        for _ in 0..STEP_BUDGET {
            m.polls.inc();
            if !self.ctx.acquire_output_slots(&waker) {
                return StepOutcome::Parked; // producer waker registered
            }
            // Async in-flight work first (e.g. a batched inference the
            // element is waiting on): its output must go downstream
            // before any new input is popped, or per-pipeline frame
            // order breaks.
            match self.element.pump(&mut self.ctx) {
                Ok(Async::Idle) => {}
                Ok(Async::Delivered) => continue, // re-acquire spent slots
                Ok(Async::Pending) => {
                    self.ctx.release_output_slots();
                    return StepOutcome::Parked; // completion fires our waker
                }
                Err(e) => {
                    self.ctx.post_error(format!("pump: {e}"));
                    return self.finish();
                }
            }
            if self.draining {
                return self.finish(); // EOS seen and async work drained
            }
            match &inbox {
                None => {
                    // Source: produce until EOS/stop/error.
                    if self.ctx.stopped() {
                        return self.finish();
                    }
                    match self.element.produce(&mut self.ctx) {
                        Ok(true) => {}
                        Ok(false) => return self.finish(),
                        Err(e) => {
                            self.ctx.post_error(format!("produce: {e}"));
                            return self.finish();
                        }
                    }
                }
                Some(ib) => match ib.try_pop_any() {
                    TryPop::Item(pad, item) => {
                        let eos = matches!(item, Item::Eos);
                        let mut yield_after = false;
                        match self.element.process(pad, item, &mut self.ctx) {
                            Ok(Progress::Ready) | Ok(Progress::NeedInput) => {}
                            Ok(Progress::NeedOutput) => yield_after = true,
                            Ok(Progress::Done) => return self.finish(),
                            Err(e) => {
                                self.ctx.post_error(format!("handle: {e}"));
                                return self.finish();
                            }
                        }
                        // EOS accounting runs on every handled item so the
                        // pooled and threaded runners never diverge. Defer
                        // the actual finish through `draining` so async
                        // in-flight work (pump) delivers before teardown.
                        if eos && self.tracker.mark(pad) {
                            self.draining = true;
                            continue;
                        }
                        if yield_after {
                            self.ctx.release_output_slots();
                            return StepOutcome::Yield;
                        }
                    }
                    TryPop::Empty => {
                        self.ctx.release_output_slots();
                        ib.set_consumer_waker(waker.clone());
                        // Re-check after registration: a push that landed
                        // in between would otherwise be a lost wakeup.
                        return match ib.poll_state() {
                            PollState::Empty => StepOutcome::Parked,
                            PollState::Ready => StepOutcome::Yield,
                            PollState::Done => self.finish(),
                        };
                    }
                    TryPop::Done => return self.finish(),
                },
            }
        }
        self.ctx.release_output_slots();
        StepOutcome::Yield
    }

    fn finish(&mut self) -> StepOutcome {
        self.ctx.release_output_slots();
        self.ctx.push_eos_all();
        self.element.stop(&mut self.ctx);
        if self.ctx.n_src_pads_linked() == 0 {
            self.ctx.post_eos();
        }
        log_debug!("pipeline", "element `{}` done", self.ctx.name);
        self.group.finish();
        StepOutcome::Done
    }

    /// Panic fallback: surface the crash on the bus and release the group
    /// so teardown doesn't hang (a panicking element used to kill only
    /// its own thread; it must not wedge a shared worker's pipelines).
    fn abort(&mut self, what: &str) {
        self.ctx.release_output_slots();
        self.ctx.post_error(what);
        self.ctx.push_eos_all();
        self.group.finish();
    }
}

enum StepOutcome {
    Yield,
    Parked,
    Done,
}

/// Result of one dequeue scan over every source a worker polls.
enum Scan {
    /// A task was claimed; run it.
    Task(Arc<Task>),
    /// Nothing claimable anywhere — sleeping is safe.
    Empty,
    /// A steal lost its CAS: work may remain whose wake signal was
    /// already consumed, so the worker must rescan, not sleep.
    Retry,
}

/// A schedulable element (handle kept by the owning pipeline; wakers hold
/// weak refs so dropped pipelines free their elements).
pub struct Task {
    state: AtomicU8,
    run: Mutex<Option<NodeRun>>,
}

/// Idle-worker bookkeeping: `idle` workers are waiting on the condvar,
/// `signals` of them have an unconsumed wakeup. Counting signals (instead
/// of bare notifies) makes wakeups lossless: a notify issued before the
/// sleeper reaches `wait` is banked, not dropped.
struct Sleep {
    idle: usize,
    signals: usize,
}

type ReadyQueue = Mutex<VecDeque<Arc<Task>>>;

thread_local! {
    /// (scheduler address, worker index) when this thread is a pool
    /// worker; wake routing uses it to pick local queue vs injector.
    static WORKER: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
    /// Wakes issued during the current worker turn whose idle-worker
    /// signal is deferred to one end-of-turn batch.
    static PENDING_WAKES: Cell<usize> = const { Cell::new(0) };
}

/// The worker pool. Exactly one process-wide instance serves pipelines
/// ([`global`]): workers are daemon threads with no shutdown path, so
/// constructing additional pools leaks threads (and distorts the
/// resident-thread metric the scheduler exists to minimise) — hence only
/// the hidden bench/test constructor [`Scheduler::start_detached`]
/// besides the global.
pub struct Scheduler {
    injector: ReadyQueue,
    /// `Stealing`-mode per-worker deques (mutex comparator).
    locals: Vec<ReadyQueue>,
    /// `ChaseLev`-mode per-worker lock-free deques.
    deques: Vec<ChaseLev<Task>>,
    sleep: Mutex<Sleep>,
    cv: Condvar,
    workers: usize,
    queues: QueueMode,
    m: SchedMetrics,
}

/// Pool size: `EDGEPIPE_WORKERS` when set (>0), else the machine's
/// available parallelism.
pub fn workers_from_env() -> usize {
    std::env::var("EDGEPIPE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// The process-wide scheduler (workers spawn lazily on first use).
pub fn global() -> &'static Arc<Scheduler> {
    static G: OnceLock<Arc<Scheduler>> = OnceLock::new();
    G.get_or_init(|| Scheduler::start(workers_from_env(), QueueMode::from_env()))
}

impl Scheduler {
    /// Spawn `k` workers (named `ep-worker-<n>`). They are daemons: idle
    /// workers block on the sleep condvar and never exit.
    fn start(k: usize, queues: QueueMode) -> Arc<Scheduler> {
        let k = k.max(1);
        let s = Arc::new(Scheduler {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..k).map(|_| Mutex::new(VecDeque::new())).collect(),
            deques: (0..k).map(|_| ChaseLev::new()).collect(),
            sleep: Mutex::new(Sleep { idle: 0, signals: 0 }),
            cv: Condvar::new(),
            workers: k,
            queues,
            m: SchedMetrics::new(),
        });
        for i in 0..s.workers {
            let s2 = s.clone();
            std::thread::Builder::new()
                .name(format!("ep-worker-{i}"))
                .spawn(move || s2.worker_loop(i))
                .expect("spawn scheduler worker");
        }
        s
    }

    /// Extra pool for benches/tests that must compare queue architectures
    /// in one process (the global pool is a singleton). The `k` workers
    /// leak for the process lifetime — never use this on a serving path.
    #[doc(hidden)]
    pub fn start_detached(k: usize, queues: QueueMode) -> Arc<Scheduler> {
        Scheduler::start(k, queues)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn queue_mode(&self) -> QueueMode {
        self.queues
    }

    /// Hand an element to the pool; returns the handle the pipeline keeps
    /// alive until teardown.
    pub fn spawn(self: &Arc<Self>, mut run: NodeRun) -> Arc<Task> {
        let sched = self.clone();
        let task = Arc::new_cyclic(|weak: &Weak<Task>| {
            let w = weak.clone();
            let waker: Waker = Arc::new(move || {
                if let Some(t) = w.upgrade() {
                    sched.wake(&t);
                }
            });
            // The element gets its own task waker too, for async
            // completion sources (batch collectors) to re-queue it.
            run.ctx.set_task_waker(waker.clone());
            run.waker = Some(waker);
            Task { state: AtomicU8::new(QUEUED), run: Mutex::new(Some(run)) }
        });
        self.m.tasks.inc();
        self.enqueue(task.clone());
        task
    }

    /// Counted queue lock: total acquisitions + how many had to wait
    /// (the contention the per-worker deques exist to eliminate).
    fn lock_queue<'a>(&self, q: &'a ReadyQueue) -> MutexGuard<'a, VecDeque<Arc<Task>>> {
        self.m.queue_locks.inc();
        match q.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.m.lock_waits.inc();
                q.lock().unwrap_or_else(|p| p.into_inner())
            }
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }

    /// True when the calling thread is one of THIS pool's workers.
    fn current_worker(self: &Arc<Self>) -> Option<usize> {
        let (addr, id) = WORKER.with(|w| w.get());
        (id != usize::MAX && addr == Arc::as_ptr(self) as usize).then_some(id)
    }

    /// Make a QUEUED task runnable. On a worker thread of this pool the
    /// task lands on that worker's own deque — a lock-free bottom push
    /// under `ChaseLev`, an uncontended lock under `Stealing` — and the
    /// idle-worker signal is deferred to the end-of-turn batch; any other
    /// thread routes through the injector with an immediate signal.
    fn enqueue(self: &Arc<Self>, task: Arc<Task>) {
        match (self.current_worker(), self.queues) {
            (Some(id), QueueMode::ChaseLev) => {
                self.deques[id].push(task);
                PENDING_WAKES.with(|p| p.set(p.get() + 1));
            }
            (Some(id), QueueMode::Stealing) => {
                self.lock_queue(&self.locals[id]).push_back(task);
                PENDING_WAKES.with(|p| p.set(p.get() + 1));
            }
            _ => {
                self.lock_queue(&self.injector).push_back(task);
                self.notify(1);
            }
        }
    }

    /// Grant up to `n` banked wakeups to idle workers (one sleep-lock
    /// acquisition covers the whole batch).
    fn notify(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut s = self.sleep.lock().unwrap();
        let grant = n.min(s.idle.saturating_sub(s.signals));
        s.signals += grant;
        drop(s);
        for _ in 0..grant {
            self.cv.notify_one();
        }
    }

    /// Fire the turn's deferred idle-worker signals in one batch.
    fn flush_wakes(&self) {
        let n = PENDING_WAKES.with(|p| p.replace(0));
        self.notify(n);
    }

    /// Re-enqueue a parked task (called from inbox wakers). Safe from any
    /// thread and any task state: a fire during RUNNING is latched as
    /// NOTIFIED so the worker re-queues instead of parking.
    fn wake(self: &Arc<Self>, task: &Arc<Task>) {
        loop {
            match task.state.load(Ordering::SeqCst) {
                PARKED => {
                    if task
                        .state
                        .compare_exchange(PARKED, QUEUED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        self.enqueue(task.clone());
                        return;
                    }
                }
                RUNNING => {
                    if task
                        .state
                        .compare_exchange(RUNNING, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        return;
                    }
                }
                _ => return, // QUEUED / NOTIFIED / DONE: nothing to do
            }
        }
    }

    /// Pop entries off one queue until one wins the `QUEUED -> RUNNING`
    /// claim CAS. A stale entry — its task already claimed by a racing
    /// worker, re-queued elsewhere, or finished — fails the CAS and is
    /// dropped, so a task can never run on two workers at once no matter
    /// how wakes interleave with pops.
    fn claim_from(&self, q: &ReadyQueue) -> Option<Arc<Task>> {
        loop {
            let task = self.lock_queue(q).pop_front()?;
            if task
                .state
                .compare_exchange(QUEUED, RUNNING, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return Some(task);
            }
        }
    }

    /// Pop the worker's own Chase-Lev deque until an entry wins the
    /// `QUEUED -> RUNNING` claim CAS (stale entries drop, as in
    /// [`Scheduler::claim_from`]).
    fn pop_own(&self, id: usize) -> Option<Arc<Task>> {
        while let Some(task) = self.deques[id].pop() {
            if task
                .state
                .compare_exchange(QUEUED, RUNNING, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return Some(task);
            }
        }
        None
    }

    /// `ChaseLev`-mode injector drain: ONE counted lock hold takes up to
    /// half the injector (capped at [`INJECTOR_BATCH`]); the first
    /// claimable task runs now, the rest land on this worker's own deque
    /// (surfacing as `local_hits` later). Their original enqueues
    /// already signalled sleepers; extra deferred wakes invite idle
    /// peers to steal the surplus back. Loops while whole batches turn
    /// out stale so a live entry deeper in the queue can't be missed
    /// right before a sleep.
    fn drain_injector(&self, id: usize) -> Option<Arc<Task>> {
        loop {
            let mut q = self.lock_queue(&self.injector);
            if q.is_empty() {
                return None;
            }
            let n = ((q.len() + 1) / 2).min(INJECTOR_BATCH);
            let batch: Vec<Arc<Task>> = q.drain(..n).collect();
            drop(q);
            let mut claimed = None;
            let mut extras = 0usize;
            for task in batch {
                if claimed.is_none() {
                    if task
                        .state
                        .compare_exchange(QUEUED, RUNNING, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        claimed = Some(task);
                    }
                } else {
                    self.deques[id].push(task);
                    extras += 1;
                }
            }
            if extras > 0 {
                PENDING_WAKES.with(|p| p.set(p.get() + extras));
            }
            if claimed.is_some() {
                return claimed;
            }
        }
    }

    /// Batch steal from `victim`: per-element top CASes claim up to half
    /// the victim's visible queue (capped at [`MAX_STEAL_BATCH`]). The
    /// first task winning the `QUEUED -> RUNNING` claim is returned to
    /// run; the rest stay QUEUED and move onto the thief's own deque.
    /// One *range* CAS over `top..top+n` would be unsound here — see the
    /// module docs. Returns `(claimed, lost_a_cas)`; a lost CAS means
    /// work may remain, so the scan must not conclude "empty".
    fn steal_batch(&self, id: usize, victim: usize) -> (Option<Arc<Task>>, bool) {
        let v = &self.deques[victim];
        let budget = ((v.len() + 1) / 2).clamp(1, MAX_STEAL_BATCH);
        let mut claimed: Option<Arc<Task>> = None;
        let mut moved = 0u64;
        let mut extras = 0usize;
        for _ in 0..budget {
            match v.steal() {
                Steal::Empty => break,
                Steal::Retry => {
                    if extras > 0 {
                        PENDING_WAKES.with(|p| p.set(p.get() + extras));
                    }
                    if moved > 0 {
                        self.m.stolen_tasks.add(moved);
                    }
                    return (claimed, true);
                }
                Steal::Taken(task) => {
                    if claimed.is_none() {
                        if task
                            .state
                            .compare_exchange(QUEUED, RUNNING, Ordering::SeqCst, Ordering::SeqCst)
                            .is_ok()
                        {
                            claimed = Some(task);
                            moved += 1;
                        }
                        // Stale entries fail the CAS and drop silently.
                    } else {
                        self.deques[id].push(task);
                        moved += 1;
                        extras += 1;
                    }
                }
            }
        }
        if extras > 0 {
            PENDING_WAKES.with(|p| p.set(p.get() + extras));
        }
        if moved > 0 {
            self.m.stolen_tasks.add(moved);
        }
        (claimed, false)
    }

    /// One full dequeue attempt: (tick) injector, local, injector, then
    /// steal round-robin (see module docs for the ordering rationale).
    fn scan(&self, id: usize, tick: usize) -> Scan {
        match self.queues {
            QueueMode::Shared => match self.claim_from(&self.injector) {
                Some(t) => {
                    self.m.injector_hits.inc();
                    Scan::Task(t)
                }
                None => Scan::Empty,
            },
            QueueMode::Stealing => {
                if tick % INJECTOR_TICK == 0 {
                    if let Some(t) = self.claim_from(&self.injector) {
                        self.m.injector_hits.inc();
                        return Scan::Task(t);
                    }
                }
                if let Some(t) = self.claim_from(&self.locals[id]) {
                    self.m.local_hits.inc();
                    return Scan::Task(t);
                }
                if let Some(t) = self.claim_from(&self.injector) {
                    self.m.injector_hits.inc();
                    return Scan::Task(t);
                }
                for off in 1..self.workers {
                    if let Some(t) = self.claim_from(&self.locals[(id + off) % self.workers]) {
                        self.m.steals.inc();
                        return Scan::Task(t);
                    }
                }
                Scan::Empty
            }
            QueueMode::ChaseLev => {
                if tick % INJECTOR_TICK == 0 {
                    if let Some(t) = self.drain_injector(id) {
                        self.m.injector_hits.inc();
                        return Scan::Task(t);
                    }
                }
                if let Some(t) = self.pop_own(id) {
                    self.m.local_hits.inc();
                    return Scan::Task(t);
                }
                if let Some(t) = self.drain_injector(id) {
                    self.m.injector_hits.inc();
                    return Scan::Task(t);
                }
                let mut lost_cas = false;
                for off in 1..self.workers {
                    let (t, lost) = self.steal_batch(id, (id + off) % self.workers);
                    lost_cas |= lost;
                    if let Some(t) = t {
                        self.m.steals.inc();
                        return Scan::Task(t);
                    }
                }
                if lost_cas {
                    Scan::Retry
                } else {
                    Scan::Empty
                }
            }
        }
    }

    /// Block until a task is claimable. The pre-sleep re-scan runs under
    /// the sleep lock: an enqueue landing between a failed scan and
    /// `idle += 1` would find no idle worker to signal, so the re-scan
    /// (which observes every push completed before it) closes that
    /// lost-wakeup window. Lock order is sleep -> queue here; producers
    /// take queue and sleep sequentially, never nested — no deadlock.
    /// A `Retry` scan (lost steal CAS) loops back instead of sleeping:
    /// the victim may still hold work whose wake signal was already
    /// consumed.
    fn next_task(&self, id: usize, tick: &mut usize) -> Arc<Task> {
        loop {
            *tick = tick.wrapping_add(1);
            match self.scan(id, *tick) {
                Scan::Task(t) => return t,
                Scan::Retry => {
                    std::hint::spin_loop();
                    continue;
                }
                Scan::Empty => {}
            }
            let mut s = self.sleep.lock().unwrap();
            match self.scan(id, *tick) {
                Scan::Task(t) => return t,
                Scan::Retry => continue, // drop the lock, rescan
                Scan::Empty => {}
            }
            s.idle += 1;
            while s.signals == 0 {
                s = self.cv.wait(s).unwrap();
            }
            s.signals -= 1;
            s.idle -= 1;
            drop(s);
        }
    }

    fn worker_loop(self: Arc<Self>, id: usize) {
        WORKER.with(|w| w.set((Arc::as_ptr(&self) as usize, id)));
        let mut tick = 0usize;
        loop {
            let task = self.next_task(id, &mut tick);
            // The claim CAS in next_task already moved QUEUED -> RUNNING.
            let outcome = {
                let mut guard = task.run.lock().unwrap_or_else(|p| p.into_inner());
                match guard.as_mut() {
                    Some(run) => {
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            run.step(&self.m)
                        })) {
                            Ok(o) => o,
                            Err(_) => {
                                run.abort("element panicked");
                                StepOutcome::Done
                            }
                        }
                    }
                    None => StepOutcome::Done,
                }
            };
            match outcome {
                StepOutcome::Yield => {
                    task.state.store(QUEUED, Ordering::SeqCst);
                    self.enqueue(task);
                }
                StepOutcome::Parked => {
                    if task
                        .state
                        .compare_exchange(RUNNING, PARKED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        self.m.parks.inc();
                    } else {
                        // A waker fired mid-step (NOTIFIED): run again.
                        task.state.store(QUEUED, Ordering::SeqCst);
                        self.enqueue(task);
                    }
                }
                StepOutcome::Done => {
                    task.state.store(DONE, Ordering::SeqCst);
                    // Drop element + ctx promptly (sockets, channels).
                    *task.run.lock().unwrap_or_else(|p| p.into_inner()) = None;
                }
            }
            // One sleep-lock pass covers every wake this turn issued —
            // a multi-buffer burst or an EOS fan-out signals idle
            // workers once, not once per enqueued task.
            self.flush_wakes();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_group_counts_down() {
        let g = TaskGroup::new(2);
        let g2 = g.clone();
        let h = std::thread::spawn(move || g2.wait());
        g.finish();
        assert!(!h.is_finished());
        g.finish();
        h.join().unwrap();
    }

    #[test]
    fn workers_from_env_default_positive() {
        assert!(workers_from_env() >= 1);
    }

    #[test]
    fn workload_defaults_to_compute() {
        assert_eq!(Workload::default(), Workload::Compute);
    }

    #[test]
    fn queue_mode_defaults_to_chaselev() {
        assert_eq!(QueueMode::default(), QueueMode::ChaseLev);
    }

    #[test]
    fn detached_pools_report_their_shape() {
        let s = Scheduler::start_detached(2, QueueMode::Shared);
        assert_eq!(s.workers(), 2);
        assert_eq!(s.queue_mode(), QueueMode::Shared);
        let s2 = Scheduler::start_detached(2, QueueMode::ChaseLev);
        assert_eq!(s2.queue_mode(), QueueMode::ChaseLev);
        // Zero workers is clamped, not accepted.
        let s1 = Scheduler::start_detached(0, QueueMode::Stealing);
        assert_eq!(s1.workers(), 1);
    }

    #[test]
    fn notify_banks_signals_for_idle_workers_only() {
        let s = Scheduler::start_detached(1, QueueMode::Stealing);
        // No worker can be idle-registered AND signalled without consuming:
        // the grant never exceeds registered idles.
        s.notify(1000);
        let sl = s.sleep.lock().unwrap();
        assert!(sl.signals <= sl.idle);
    }

    // -----------------------------------------------------------------------
    // Chase-Lev deque unit + stress suite. Payload `Arc<usize>` keeps
    // element identity checkable without scheduler machinery.
    // -----------------------------------------------------------------------

    #[test]
    fn deque_empty_pop_and_empty_steal() {
        let d: ChaseLev<usize> = ChaseLev::new();
        assert!(d.pop().is_none());
        assert!(matches!(d.steal(), Steal::Empty));
        assert_eq!(d.len(), 0);
        d.push(Arc::new(7));
        assert_eq!(d.len(), 1);
        assert_eq!(*d.pop().unwrap(), 7);
        // Back to empty: both ends agree again.
        assert!(d.pop().is_none());
        assert!(matches!(d.steal(), Steal::Empty));
    }

    #[test]
    fn deque_owner_pops_lifo_thief_steals_fifo() {
        let d: ChaseLev<usize> = ChaseLev::new();
        for i in 0..10 {
            d.push(Arc::new(i));
        }
        // Thief takes the OLDEST entries...
        for want in 0..3 {
            match d.steal() {
                Steal::Taken(v) => assert_eq!(*v, want),
                _ => panic!("steal failed with no contention"),
            }
        }
        // ...the owner the NEWEST.
        for want in (3..10).rev() {
            assert_eq!(*d.pop().unwrap(), want);
        }
        assert!(d.pop().is_none());
    }

    #[test]
    fn deque_grows_past_min_cap_without_losing_elements() {
        let d: ChaseLev<usize> = ChaseLev::new();
        let n = MIN_DEQUE_CAP * 8 + 3; // several grow generations
        for i in 0..n {
            d.push(Arc::new(i));
        }
        assert_eq!(d.len(), n);
        // Old generations are retired, not freed.
        assert!(!d.retired.lock().unwrap().is_empty());
        for want in (0..n).rev() {
            assert_eq!(*d.pop().unwrap(), want);
        }
        assert!(d.pop().is_none());
    }

    #[test]
    fn deque_grow_interleaved_with_steals() {
        let d: ChaseLev<usize> = ChaseLev::new();
        // Advance top first so grown rings start mid-index.
        for i in 0..MIN_DEQUE_CAP {
            d.push(Arc::new(i));
        }
        for want in 0..MIN_DEQUE_CAP / 2 {
            match d.steal() {
                Steal::Taken(v) => assert_eq!(*v, want),
                _ => panic!("uncontended steal failed"),
            }
        }
        for i in MIN_DEQUE_CAP..MIN_DEQUE_CAP * 4 {
            d.push(Arc::new(i));
        }
        let mut got: Vec<usize> = Vec::new();
        while let Some(v) = d.pop() {
            got.push(*v);
        }
        let want: Vec<usize> = (MIN_DEQUE_CAP / 2..MIN_DEQUE_CAP * 4).rev().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn deque_one_element_owner_thief_race_hands_out_exactly_once() {
        let d: Arc<ChaseLev<usize>> = Arc::new(ChaseLev::new());
        for round in 0..300usize {
            d.push(Arc::new(round));
            let thief = {
                let d = d.clone();
                std::thread::spawn(move || loop {
                    match d.steal() {
                        Steal::Taken(v) => return Some(*v),
                        Steal::Empty => return None,
                        Steal::Retry => std::hint::spin_loop(),
                    }
                })
            };
            let mine = d.pop().map(|v| *v);
            let theirs = thief.join().unwrap();
            // Exactly one side gets the element (the thief may also see
            // Empty after the owner's pop — never a duplicate).
            match (mine, theirs) {
                (Some(v), None) | (None, Some(v)) => assert_eq!(v, round),
                other => panic!("round {round}: element duplicated or lost: {other:?}"),
            }
            assert!(d.pop().is_none());
        }
    }

    /// Deterministic xorshift for the stress mix (no external crates).
    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    #[test]
    fn deque_multi_thief_stress_conserves_every_element() {
        const N: usize = 20_000;
        const THIEVES: usize = 3;
        let d: Arc<ChaseLev<usize>> = Arc::new(ChaseLev::new());
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut thieves = Vec::new();
        for _ in 0..THIEVES {
            let d = d.clone();
            let done = done.clone();
            thieves.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match d.steal() {
                        Steal::Taken(v) => got.push(*v),
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => {
                            if done.load(Ordering::SeqCst) {
                                return got;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            }));
        }
        // Owner: randomized push/pop mix, then drain.
        let mut owned = Vec::new();
        let mut rng = 0x9e3779b97f4a7c15u64;
        for i in 0..N {
            d.push(Arc::new(i));
            if xorshift(&mut rng) % 4 == 0 {
                if let Some(v) = d.pop() {
                    owned.push(*v);
                }
            }
        }
        while let Some(v) = d.pop() {
            owned.push(*v);
        }
        // The deque is empty from the owner's side; thieves may still be
        // completing in-flight CASes, but Steal::Empty after `done` means
        // they saw the final state.
        done.store(true, Ordering::SeqCst);
        let mut all = owned;
        for t in thieves {
            all.extend(t.join().unwrap());
        }
        // Conservation: every element exactly once — none lost to a
        // steal/pop race, none duplicated by a stale-buffer read.
        assert_eq!(all.len(), N, "lost or duplicated elements");
        all.sort_unstable();
        for (i, v) in all.iter().enumerate() {
            assert_eq!(*v, i, "element {i} missing or duplicated");
        }
    }

    #[test]
    fn deque_drop_releases_leftover_payloads() {
        let payload = Arc::new(41usize);
        let d: ChaseLev<usize> = ChaseLev::new();
        for _ in 0..MIN_DEQUE_CAP * 2 {
            d.push(payload.clone());
        }
        assert!(Arc::strong_count(&payload) > MIN_DEQUE_CAP);
        drop(d);
        assert_eq!(Arc::strong_count(&payload), 1, "Drop leaked deque payloads");
    }
}
