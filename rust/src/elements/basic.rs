//! Core plumbing elements: identity, fakesink, capsfilter, queue, tee,
//! appsrc/appsink (programmatic + named-channel endpoints).

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::element::{Ctx, Element, Item, Leaky, QueueCfg, Workload};
use crate::metrics;
use crate::util::{Error, Result};

/// Pass-through element.
pub struct Identity;

impl Element for Identity {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if !matches!(item, Item::Eos) {
            ctx.push(0, item)?;
        }
        Ok(())
    }
}

/// Swallow everything; count buffers into the global metrics registry
/// under `fakesink.<name>`.
pub struct FakeSink;

impl Element for FakeSink {
    fn n_src_pads(&self) -> usize {
        0
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if let Item::Buffer(b) = item {
            metrics::global().counter(&format!("fakesink.{}", ctx.name)).add_bytes(b.len() as u64);
        }
        Ok(())
    }
}

/// Enforce stream caps: intersects incoming caps with the configured ones,
/// errors on incompatibility (launch-time type verification, §3).
pub struct CapsFilter {
    caps: Caps,
}

impl CapsFilter {
    pub fn new(caps: Caps) -> Self {
        Self { caps }
    }
}

impl Element for CapsFilter {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let merged = self.caps.intersect(&c).map_err(|e| {
                    Error::element(&ctx.name, format!("incompatible caps: {e}"))
                })?;
                ctx.push_caps(merged)
            }
            Item::Buffer(b) => ctx.push_buffer(b),
            Item::Eos => Ok(()),
        }
    }
}

/// Decoupling queue with configurable size + leak policy (`queue leaky=2`).
pub struct Queue {
    cfg: QueueCfg,
}

impl Queue {
    pub fn new(capacity: usize, leaky: Leaky) -> Self {
        Self { cfg: QueueCfg { capacity, leaky } }
    }
}

impl Element for Queue {
    fn sink_queue_cfg(&self, _pad: usize) -> QueueCfg {
        self.cfg
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if !matches!(item, Item::Eos) {
            ctx.push(0, item)?;
        }
        Ok(())
    }
}

/// Explicit tee (fan-out also happens implicitly on any multi-linked src
/// pad; the element exists for description compatibility).
pub struct Tee;

impl Element for Tee {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if !matches!(item, Item::Eos) {
            ctx.push(0, item)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// appsrc / appsink: named in-process channels so parsed descriptions can
// exchange data with application code (NNStreamer app API analog).
// ---------------------------------------------------------------------------

type SrcReg = Mutex<HashMap<String, Receiver<(Option<Caps>, Buffer)>>>;
type SinkReg = Mutex<HashMap<String, Receiver<Buffer>>>;

fn src_registry() -> &'static SrcReg {
    static R: OnceLock<SrcReg> = OnceLock::new();
    R.get_or_init(|| Mutex::new(HashMap::new()))
}

fn sink_registry() -> &'static SinkReg {
    static R: OnceLock<SinkReg> = OnceLock::new();
    R.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Handle for pushing buffers into an `appsrc channel=<key>` element.
#[derive(Clone)]
pub struct AppSrcHandle {
    tx: SyncSender<(Option<Caps>, Buffer)>,
}

impl AppSrcHandle {
    pub fn push(&self, buf: Buffer) -> Result<()> {
        self.tx
            .send((None, buf))
            .map_err(|_| Error::Pipeline("appsrc: pipeline gone".into()))
    }

    pub fn push_with_caps(&self, caps: Caps, buf: Buffer) -> Result<()> {
        self.tx
            .send((Some(caps), buf))
            .map_err(|_| Error::Pipeline("appsrc: pipeline gone".into()))
    }
}

/// Create the app side of an `appsrc channel=<key>`; call BEFORE parsing.
/// Dropping the handle ends the stream (EOS).
pub fn appsrc_channel(key: &str, depth: usize) -> AppSrcHandle {
    let (tx, rx) = sync_channel(depth);
    src_registry().lock().unwrap().insert(key.to_string(), rx);
    AppSrcHandle { tx }
}

/// Take the app side of an `appsink channel=<key>`; call AFTER parsing.
pub fn appsink_channel(key: &str) -> Option<Receiver<Buffer>> {
    sink_registry().lock().unwrap().remove(key)
}

/// Source fed by an [`AppSrcHandle`].
pub struct AppSrc {
    rx: Option<Receiver<(Option<Caps>, Buffer)>>,
    caps_sent: bool,
    initial_caps: Option<Caps>,
}

impl AppSrc {
    pub fn from_channel(key: &str, caps: Option<Caps>) -> Result<Self> {
        let rx = src_registry()
            .lock()
            .unwrap()
            .remove(key)
            .ok_or_else(|| Error::Parse(format!("appsrc channel `{key}` not registered")))?;
        Ok(AppSrc { rx: Some(rx), caps_sent: false, initial_caps: caps })
    }

    /// Programmatic constructor.
    pub fn new(depth: usize, caps: Option<Caps>) -> (Self, AppSrcHandle) {
        let (tx, rx) = sync_channel(depth);
        (AppSrc { rx: Some(rx), caps_sent: false, initial_caps: caps }, AppSrcHandle { tx })
    }
}

impl Element for AppSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    /// Blocks on the app channel (`recv_timeout`): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!("appsrc has no sink pads")
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        let Some(rx) = &self.rx else { return Ok(false) };
        if !self.caps_sent {
            if let Some(c) = self.initial_caps.take() {
                ctx.push_caps(c)?;
            }
            self.caps_sent = true;
        }
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok((caps, buf)) => {
                if let Some(c) = caps {
                    ctx.push_caps(c)?;
                }
                ctx.push_buffer(buf)?;
                Ok(true)
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(!ctx.stopped()),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(false),
        }
    }
}

/// Sink delivering buffers to an app channel (or counting if unclaimed).
pub struct AppSink {
    tx: Option<SyncSender<Buffer>>,
}

impl AppSink {
    pub fn to_channel(key: &str, depth: usize) -> Self {
        let (tx, rx) = sync_channel(depth);
        sink_registry().lock().unwrap().insert(key.to_string(), rx);
        AppSink { tx: Some(tx) }
    }

    /// Programmatic constructor.
    pub fn new(depth: usize) -> (Self, Receiver<Buffer>) {
        let (tx, rx) = sync_channel(depth);
        (AppSink { tx: Some(tx) }, rx)
    }

    /// Channel-less appsink (counts like fakesink).
    pub fn detached() -> Self {
        AppSink { tx: None }
    }
}

impl Element for AppSink {
    fn n_src_pads(&self) -> usize {
        0
    }

    /// Blocks on the app channel (intended backpressure): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if let Item::Buffer(b) = item {
            metrics::global().counter(&format!("appsink.{}", ctx.name)).add_bytes(b.len() as u64);
            if let Some(tx) = &self.tx {
                // Block: the app is the consumer; backpressure is intended.
                if tx.send(b).is_err() {
                    self.tx = None; // app hung up; keep draining
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{Pipeline, WaitOutcome};

    #[test]
    fn appsrc_appsink_roundtrip_programmatic() {
        let mut p = Pipeline::new();
        let (src, handle) = AppSrc::new(8, Some(Caps::video(2, 2, 30)));
        let (sink, rx) = AppSink::new(8);
        let s = p.add("src", Box::new(src)).unwrap();
        let i = p.add("id", Box::new(Identity)).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, i).unwrap();
        p.link(i, k).unwrap();
        let running = p.start().unwrap();
        handle.push(Buffer::new(vec![1, 2, 3]).with_pts(7)).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(&got.data[..], &[1, 2, 3]);
        assert_eq!(got.pts, Some(7));
        drop(handle); // EOS
        assert_eq!(running.wait_eos(Duration::from_secs(5)), WaitOutcome::Eos);
    }

    #[test]
    fn named_channels_roundtrip() {
        let h = appsrc_channel("t-in", 4);
        let mut p = Pipeline::new();
        let s = p.add("src", Box::new(AppSrc::from_channel("t-in", None).unwrap())).unwrap();
        let k = p.add("sink", Box::new(AppSink::to_channel("t-out", 4))).unwrap();
        p.link(s, k).unwrap();
        let rx = appsink_channel("t-out").unwrap();
        let running = p.start().unwrap();
        h.push(Buffer::new(vec![9])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[9]);
        drop(h);
        assert_eq!(running.wait_eos(Duration::from_secs(5)), WaitOutcome::Eos);
    }

    #[test]
    fn capsfilter_rejects_mismatch() {
        let mut p = Pipeline::new();
        let (src, handle) = AppSrc::new(4, Some(Caps::video(4, 4, 30)));
        let s = p.add("src", Box::new(src)).unwrap();
        let f = p
            .add("caps", Box::new(CapsFilter::new(Caps::parse("video/x-raw,width=999").unwrap())))
            .unwrap();
        let k = p.add("sink", Box::new(FakeSink)).unwrap();
        p.link(s, f).unwrap();
        p.link(f, k).unwrap();
        let mut running = p.start().unwrap();
        handle.push(Buffer::new(vec![0])).unwrap();
        match running.wait(Duration::from_secs(5)) {
            WaitOutcome::Error { element, .. } => assert_eq!(element, "caps"),
            other => panic!("expected caps error, got {other:?}"),
        }
    }

    #[test]
    fn capsfilter_passes_compatible() {
        let mut p = Pipeline::new();
        let (src, handle) = AppSrc::new(4, Some(Caps::video(4, 4, 30)));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("src", Box::new(src)).unwrap();
        let f = p
            .add("caps", Box::new(CapsFilter::new(Caps::parse("video/x-raw,width=4").unwrap())))
            .unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, f).unwrap();
        p.link(f, k).unwrap();
        let _running = p.start().unwrap();
        handle.push(Buffer::new(vec![5])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[5]);
    }

    #[test]
    fn unclaimed_appsrc_channel_errors() {
        assert!(AppSrc::from_channel("never-registered", None).is_err());
    }
}
