//! Tensor conversion elements: `tensor_converter` (media → tensors),
//! `tensor_transform` (arithmetic/typecast), `tensor_decoder` (tensors →
//! media / flexbuf) — the NNStreamer `tensor_*` filter family (§4.1).
//! All pure compute (`Workload::Compute` default): schedulable on the
//! worker pool, no dedicated threads.

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::element::{Ctx, Element, Item};
use crate::serial;
use crate::tensor::{self, DType, Format, TensorInfo, TensorsInfo};
use crate::util::{Error, Result};

// ---------------------------------------------------------------------------
// tensor_converter
// ---------------------------------------------------------------------------

/// Convert media streams into `other/tensors`:
/// - `video/x-raw` RGB WxH  → static u8 tensor `3:W:H:1` (NNStreamer order)
/// - `other/flexbuf`        → static tensors (schema from each frame;
///                            re-negotiates on schema change)
/// - `other/tensors,format=flexible` → static (strip per-frame headers)
pub struct TensorConverter {
    mode: ConvMode,
    out_info: Option<TensorsInfo>,
}

enum ConvMode {
    Unknown,
    Video,
    Flexbuf,
    FlexTensors,
    PassThrough,
}

impl Default for TensorConverter {
    fn default() -> Self {
        Self::new()
    }
}

impl TensorConverter {
    pub fn new() -> Self {
        Self { mode: ConvMode::Unknown, out_info: None }
    }

    fn negotiate(&mut self, info: TensorsInfo, ctx: &mut Ctx) -> Result<()> {
        if self.out_info.as_ref() != Some(&info) {
            ctx.push_caps(Caps::tensors(&info))?;
            self.out_info = Some(info);
        }
        Ok(())
    }
}

impl Element for TensorConverter {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                if c.is_video() {
                    let (w, h, _fps) = c.video_geometry().map_err(|e| Error::element(&ctx.name, e))?;
                    self.mode = ConvMode::Video;
                    let info = TensorsInfo::one(
                        TensorInfo::new(DType::U8, &[3, w, h]).map_err(|e| Error::element(&ctx.name, e))?,
                    );
                    self.negotiate(info, ctx)
                } else if c.media == crate::caps::MEDIA_FLEXBUF {
                    self.mode = ConvMode::Flexbuf;
                    Ok(()) // schema discovered per frame
                } else if c.is_tensors() {
                    match c.tensor_format().map_err(|e| Error::element(&ctx.name, e))? {
                        Format::Flexible => {
                            self.mode = ConvMode::FlexTensors;
                            Ok(())
                        }
                        Format::Static => {
                            self.mode = ConvMode::PassThrough;
                            ctx.push_caps(c)
                        }
                        Format::Sparse => Err(Error::element(
                            &ctx.name,
                            "sparse input needs tensor_sparse_dec first",
                        )),
                    }
                } else {
                    Err(Error::element(&ctx.name, format!("cannot convert caps `{c}`")))
                }
            }
            Item::Buffer(b) => match self.mode {
                ConvMode::Unknown => Err(Error::element(&ctx.name, "buffer before caps")),
                ConvMode::Video | ConvMode::PassThrough => ctx.push_buffer(b),
                ConvMode::Flexbuf => {
                    let (info, payload) = serial::flexbuf_to_tensors(&b.data)
                        .map_err(|e| Error::element(&ctx.name, e))?;
                    self.negotiate(info, ctx)?;
                    ctx.push_buffer(b.map_payload(payload))
                }
                ConvMode::FlexTensors => {
                    // Zero copy: the static payload is a slice view into
                    // the flexible frame's shared allocation.
                    let (info, payload) = tensor::flexible_to_static_shared(&b.data)
                        .map_err(|e| Error::element(&ctx.name, e))?;
                    self.negotiate(info, ctx)?;
                    ctx.push_buffer(b.map_payload(payload))
                }
            },
            Item::Eos => Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// tensor_transform
// ---------------------------------------------------------------------------

/// One arithmetic op of a `tensor_transform mode=arithmetic` chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArithOp {
    /// Cast to a dtype (only u8→f32 and f32→u8 used by the models).
    TypecastF32,
    TypecastU8,
    Add(f32),
    Mul(f32),
    Div(f32),
}

/// `tensor_transform` with
/// `option=typecast:float32,add:-127.5,div:127.5` syntax (Listing 1).
pub struct TensorTransform {
    ops: Vec<ArithOp>,
    in_info: Option<TensorsInfo>,
}

impl TensorTransform {
    pub fn new(ops: Vec<ArithOp>) -> Self {
        Self { ops, in_info: None }
    }

    /// Parse the NNStreamer option string.
    pub fn parse_option(opt: &str) -> Result<Vec<ArithOp>> {
        let mut ops = Vec::new();
        for part in opt.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (op, arg) = part
                .split_once(':')
                .ok_or_else(|| Error::Parse(format!("bad transform op `{part}`")))?;
            match op {
                "typecast" => match arg {
                    "float32" => ops.push(ArithOp::TypecastF32),
                    "uint8" => ops.push(ArithOp::TypecastU8),
                    other => return Err(Error::Parse(format!("unsupported typecast `{other}`"))),
                },
                "add" => ops.push(ArithOp::Add(
                    arg.parse().map_err(|_| Error::Parse(format!("bad add `{arg}`")))?,
                )),
                "mul" => ops.push(ArithOp::Mul(
                    arg.parse().map_err(|_| Error::Parse(format!("bad mul `{arg}`")))?,
                )),
                "div" => ops.push(ArithOp::Div(
                    arg.parse().map_err(|_| Error::Parse(format!("bad div `{arg}`")))?,
                )),
                other => return Err(Error::Parse(format!("unknown transform op `{other}`"))),
            }
        }
        if ops.is_empty() {
            return Err(Error::Parse("empty transform option".into()));
        }
        Ok(ops)
    }

    fn out_dtype(&self, mut dt: DType) -> DType {
        for op in &self.ops {
            match op {
                ArithOp::TypecastF32 => dt = DType::F32,
                ArithOp::TypecastU8 => dt = DType::U8,
                _ => {}
            }
        }
        dt
    }

    fn apply(&self, info: &TensorsInfo, payload: &[u8]) -> Result<(TensorsInfo, Vec<u8>)> {
        // Decode per input dtype to f32 workspace, run ops, encode out.
        let mut out_info = TensorsInfo::default();
        let mut out = Vec::new();
        let mut off = 0usize;
        for t in &info.tensors {
            let n = t.count();
            let in_bytes = &payload[off..off + t.size()];
            off += t.size();
            let mut vals: Vec<f32> = match t.dtype {
                DType::U8 => in_bytes.iter().map(|&b| b as f32).collect(),
                DType::F32 => tensor::bytes_to_f32(in_bytes)?,
                other => {
                    return Err(Error::Tensor(format!("transform: unsupported input {other}")))
                }
            };
            let mut dt = t.dtype;
            for op in &self.ops {
                match op {
                    ArithOp::TypecastF32 => dt = DType::F32,
                    ArithOp::TypecastU8 => dt = DType::U8,
                    ArithOp::Add(a) => vals.iter_mut().for_each(|v| *v += a),
                    ArithOp::Mul(m) => vals.iter_mut().for_each(|v| *v *= m),
                    ArithOp::Div(d) => vals.iter_mut().for_each(|v| *v /= d),
                }
            }
            match dt {
                DType::F32 => out.extend(vals.iter().flat_map(|v| v.to_le_bytes())),
                DType::U8 => out.extend(vals.iter().map(|v| v.round().clamp(0.0, 255.0) as u8)),
                _ => unreachable!(),
            }
            let dims: Vec<u32> = t.dims.to_vec();
            out_info.push(TensorInfo::new(dt, &dims)?)?;
            debug_assert_eq!(out_info.tensors.last().unwrap().count(), n);
        }
        Ok((out_info, out))
    }
}

impl Element for TensorTransform {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                if !c.is_tensors() {
                    return Err(Error::element(&ctx.name, format!("need tensors caps, got `{c}`")));
                }
                let info = c.tensors_info().map_err(|e| Error::element(&ctx.name, e))?;
                let mut out = TensorsInfo::default();
                for t in &info.tensors {
                    let dims: Vec<u32> = t.dims.to_vec();
                    out.push(
                        TensorInfo::new(self.out_dtype(t.dtype), &dims)
                            .map_err(|e| Error::element(&ctx.name, e))?,
                    )
                    .map_err(|e| Error::element(&ctx.name, e))?;
                }
                self.in_info = Some(info);
                ctx.push_caps(Caps::tensors(&out))
            }
            Item::Buffer(b) => {
                let info = self
                    .in_info
                    .as_ref()
                    .ok_or_else(|| Error::element(&ctx.name, "buffer before caps"))?;
                if b.len() != info.frame_size() {
                    return Err(Error::element(
                        &ctx.name,
                        format!("frame {} bytes != caps size {}", b.len(), info.frame_size()),
                    ));
                }
                let (_info, payload) =
                    self.apply(info, &b.data).map_err(|e| Error::element(&ctx.name, e))?;
                ctx.push_buffer(b.map_payload(payload))
            }
            Item::Eos => Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// tensor_decoder
// ---------------------------------------------------------------------------

/// Decode tensors back into media / serialized form.
pub enum DecoderMode {
    /// SSD output (boxes,cls,score,count) → RGB frame with box outlines.
    BoundingBoxes { width: u32, height: u32 },
    /// Tensor `3:W:H:1` u8 → video/x-raw passthrough.
    DirectVideo,
    /// Tensors → `other/flexbuf` (schemaless publish, Listing 2).
    Flexbuf,
    /// Pose keypoints (17,3) → RGB frame with keypoint dots.
    Pose { width: u32, height: u32 },
}

pub struct TensorDecoder {
    mode: DecoderMode,
    in_info: Option<TensorsInfo>,
}

impl TensorDecoder {
    pub fn new(mode: DecoderMode) -> Self {
        Self { mode, in_info: None }
    }

    fn decode_boxes(&self, b: &Buffer, w: u32, h: u32, info: &TensorsInfo) -> Result<Vec<u8>> {
        // Expect 4 f32 tensors: boxes(4,K), cls(K), score(K), count(1)
        if info.len() != 4 {
            return Err(Error::Tensor(format!("bounding_boxes: expected 4 tensors, got {}", info.len())));
        }
        let k = info.tensors[1].count();
        let vals = tensor::bytes_to_f32(&b.data)?;
        let boxes = &vals[..4 * k];
        let scores = &vals[4 * k + k..4 * k + 2 * k];
        let count = vals[4 * k + 2 * k] as usize;
        let (wu, hu) = (w as usize, h as usize);
        let mut canvas = vec![0u8; wu * hu * 3];
        for i in 0..count.min(k) {
            if scores[i] <= 0.0 {
                continue;
            }
            let x0 = (boxes[i * 4] * w as f32).clamp(0.0, (w - 1) as f32) as usize;
            let y0 = (boxes[i * 4 + 1] * h as f32).clamp(0.0, (h - 1) as f32) as usize;
            let x1 = (boxes[i * 4 + 2] * w as f32).clamp(0.0, (w - 1) as f32) as usize;
            let y1 = (boxes[i * 4 + 3] * h as f32).clamp(0.0, (h - 1) as f32) as usize;
            let color = [(40 + i * 37 % 200) as u8, 220, 60];
            for x in x0..=x1 {
                for y in [y0, y1] {
                    let px = (y * wu + x) * 3;
                    canvas[px..px + 3].copy_from_slice(&color);
                }
            }
            for y in y0..=y1 {
                for x in [x0, x1] {
                    let px = (y * wu + x) * 3;
                    canvas[px..px + 3].copy_from_slice(&color);
                }
            }
        }
        Ok(canvas)
    }

    fn decode_pose(&self, b: &Buffer, w: u32, h: u32) -> Result<Vec<u8>> {
        let vals = tensor::bytes_to_f32(&b.data)?;
        let (wu, hu) = (w as usize, h as usize);
        let mut canvas = vec![0u8; wu * hu * 3];
        for kp in vals.chunks_exact(3) {
            let x = (kp[0] * (w - 1) as f32).clamp(0.0, (w - 1) as f32) as usize;
            let y = (kp[1] * (h - 1) as f32).clamp(0.0, (h - 1) as f32) as usize;
            let c = (kp[2].clamp(0.0, 1.0) * 255.0) as u8;
            let px = (y * wu + x) * 3;
            canvas[px] = 255;
            canvas[px + 1] = c;
            canvas[px + 2] = 64;
        }
        Ok(canvas)
    }
}

impl Element for TensorDecoder {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                if !c.is_tensors() {
                    return Err(Error::element(&ctx.name, format!("need tensors caps, got `{c}`")));
                }
                let info = c.tensors_info().ok();
                self.in_info = info;
                match &self.mode {
                    DecoderMode::BoundingBoxes { width, height } | DecoderMode::Pose { width, height } => {
                        ctx.push_caps(Caps::video(*width, *height, 30))
                    }
                    DecoderMode::DirectVideo => {
                        let info = self
                            .in_info
                            .as_ref()
                            .ok_or_else(|| Error::element(&ctx.name, "direct_video needs static caps"))?;
                        let t = &info.tensors[0];
                        if t.dims[0] != 3 || t.dtype != DType::U8 {
                            return Err(Error::element(
                                &ctx.name,
                                format!("direct_video needs 3:W:H:1 uint8, got {}", t.dims_string()),
                            ));
                        }
                        ctx.push_caps(Caps::video(t.dims[1], t.dims[2], 30))
                    }
                    DecoderMode::Flexbuf => ctx.push_caps(Caps::new(crate::caps::MEDIA_FLEXBUF)),
                }
            }
            Item::Buffer(b) => match &self.mode {
                DecoderMode::BoundingBoxes { width, height } => {
                    let info = self
                        .in_info
                        .as_ref()
                        .ok_or_else(|| Error::element(&ctx.name, "buffer before caps"))?;
                    let frame = self
                        .decode_boxes(&b, *width, *height, info)
                        .map_err(|e| Error::element(&ctx.name, e))?;
                    ctx.push_buffer(b.map_payload(frame))
                }
                DecoderMode::Pose { width, height } => {
                    let frame =
                        self.decode_pose(&b, *width, *height).map_err(|e| Error::element(&ctx.name, e))?;
                    ctx.push_buffer(b.map_payload(frame))
                }
                DecoderMode::DirectVideo => ctx.push_buffer(b),
                DecoderMode::Flexbuf => {
                    let info = self
                        .in_info
                        .as_ref()
                        .ok_or_else(|| Error::element(&ctx.name, "buffer before caps"))?;
                    let enc = serial::tensors_to_flexbuf(info, &b.data)
                        .map_err(|e| Error::element(&ctx.name, e))?;
                    ctx.push_buffer(b.map_payload(enc))
                }
            },
            Item::Eos => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::pipeline::Pipeline;
    use std::time::Duration;

    fn run_one(el: Box<dyn Element>, caps: Caps, data: Vec<u8>) -> (Buffer, Option<Caps>) {
        let mut p = Pipeline::new();
        let (src, h) = AppSrc::new(4, Some(caps));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("src", Box::new(src)).unwrap();
        let e = p.add("el", el).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, e).unwrap();
        p.link(e, k).unwrap();
        let _r = p.start().unwrap();
        h.push(Buffer::new(data).with_pts(42)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        (out, None)
    }

    #[test]
    fn converter_video_to_tensors_keeps_payload() {
        let (out, _) = run_one(
            Box::new(TensorConverter::new()),
            Caps::video(4, 2, 30),
            vec![7u8; 4 * 2 * 3],
        );
        assert_eq!(out.len(), 24);
        assert_eq!(out.pts, Some(42));
    }

    #[test]
    fn converter_flexbuf_to_tensors() {
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::U8, &[4]).unwrap()).unwrap();
        let payload = vec![1, 2, 3, 4];
        let enc = serial::tensors_to_flexbuf(&info, &payload).unwrap();
        let (out, _) = run_one(
            Box::new(TensorConverter::new()),
            Caps::new(crate::caps::MEDIA_FLEXBUF),
            enc,
        );
        assert_eq!(&out.data[..], &[1, 2, 3, 4]);
    }

    #[test]
    fn converter_flexible_tensors_to_static() {
        let t = TensorInfo::new(DType::U8, &[3]).unwrap();
        let frame = tensor::encode_flexible(&[(t, &[9, 8, 7])]).unwrap();
        let (out, _) =
            run_one(Box::new(TensorConverter::new()), Caps::tensors_flexible(), frame);
        assert_eq!(&out.data[..], &[9, 8, 7]);
    }

    #[test]
    fn transform_parse_listing1_option() {
        let ops =
            TensorTransform::parse_option("typecast:float32,add:-127.5,div:127.5").unwrap();
        assert_eq!(
            ops,
            vec![ArithOp::TypecastF32, ArithOp::Add(-127.5), ArithOp::Div(127.5)]
        );
        assert!(TensorTransform::parse_option("bogus:1").is_err());
        assert!(TensorTransform::parse_option("").is_err());
    }

    #[test]
    fn transform_normalizes_u8_to_unit_f32() {
        let ops = TensorTransform::parse_option("typecast:float32,add:-127.5,div:127.5").unwrap();
        let tt = TensorTransform::new(ops);
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[4]).unwrap());
        let (out_info, payload) = tt.apply(&info, &[0, 127, 128, 255]).unwrap();
        assert_eq!(out_info.tensors[0].dtype, DType::F32);
        let vals = tensor::bytes_to_f32(&payload).unwrap();
        assert!((vals[0] + 1.0).abs() < 1e-3);
        assert!((vals[3] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn transform_roundtrip_u8_f32_u8() {
        let ops = vec![ArithOp::TypecastF32, ArithOp::TypecastU8];
        let tt = TensorTransform::new(ops);
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (out_info, payload) = tt.apply(&info, &[5, 250, 17]).unwrap();
        assert_eq!(out_info.tensors[0].dtype, DType::U8);
        assert_eq!(payload, vec![5, 250, 17]);
    }

    #[test]
    fn decoder_direct_video_reinterprets_caps() {
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::U8, &[3, 4, 2]).unwrap()).unwrap();
        let (out, _) = run_one(
            Box::new(TensorDecoder::new(DecoderMode::DirectVideo)),
            Caps::tensors(&info),
            vec![1u8; 24],
        );
        assert_eq!(out.len(), 24);
    }

    #[test]
    fn decoder_bounding_boxes_draws_something() {
        // 4 tensors: boxes(4,2), cls(2), score(2), count(1)
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::F32, &[4, 2]).unwrap()).unwrap();
        info.push(TensorInfo::new(DType::F32, &[2]).unwrap()).unwrap();
        info.push(TensorInfo::new(DType::F32, &[2]).unwrap()).unwrap();
        info.push(TensorInfo::new(DType::F32, &[1]).unwrap()).unwrap();
        let mut vals = vec![
            0.1, 0.1, 0.6, 0.6, // box 0
            0.2, 0.2, 0.4, 0.9, // box 1
            1.0, 2.0, // cls
            0.9, 0.8, // score
            2.0, // count
        ];
        let payload: Vec<u8> = vals.drain(..).flat_map(|v: f32| v.to_le_bytes()).collect();
        let (out, _) = run_one(
            Box::new(TensorDecoder::new(DecoderMode::BoundingBoxes { width: 32, height: 32 })),
            Caps::tensors(&info),
            payload,
        );
        assert_eq!(out.len(), 32 * 32 * 3);
        assert!(out.data.iter().any(|&b| b != 0), "expected drawn boxes");
    }

    #[test]
    fn decoder_flexbuf_roundtrips_with_converter() {
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::U8, &[5]).unwrap()).unwrap();
        let (out, _) = run_one(
            Box::new(TensorDecoder::new(DecoderMode::Flexbuf)),
            Caps::tensors(&info),
            vec![1, 2, 3, 4, 5],
        );
        let (info2, payload) = serial::flexbuf_to_tensors(&out.data).unwrap();
        assert_eq!(info2, info);
        assert_eq!(payload, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn decoder_pose_draws_keypoints() {
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::F32, &[3, 2]).unwrap()).unwrap();
        let vals: Vec<f32> = vec![0.5, 0.5, 1.0, 0.1, 0.9, 0.7];
        let payload: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let (out, _) = run_one(
            Box::new(TensorDecoder::new(DecoderMode::Pose { width: 16, height: 16 })),
            Caps::tensors(&info),
            payload,
        );
        assert!(out.data.iter().any(|&b| b == 255));
    }
}
