//! `tensor_filter` — neural-network inference inside a pipeline.
//!
//! `framework=pjrt model=<name>` loads an AOT HLO artifact and runs it via
//! the PJRT CPU client (the production path; Python never runs here).
//! `framework=passthrough` is the transport-isolation stand-in used by the
//! Fig 7 query benches; `framework=custom` wraps a closure (tests; also
//! the paper's custom-filter sub-plugin mechanism).
//!
//! Execution goes through the public batch-first [`InferenceBackend`]
//! trait. Two modes:
//!
//! - **Direct** (the default): one `infer_buffer` per frame on this
//!   element's own task, exactly the pre-PR 7 behaviour.
//! - **Batched** (`batch=B [batch-timeout-ms=T]`): ready frames are
//!   submitted to a per-model shared [`BatchCollector`] that coalesces
//!   frames from every pipeline running the same model into one
//!   `infer_batch` call (dispatch at B frames or T ms, whichever first)
//!   and demuxes results back in order. A pooled filter parks on its
//!   task waker while its frame is in flight ([`Element::pump`]); a
//!   thread-mode filter blocks inline on the frame's [`Slot`].

use std::sync::Arc;
use std::time::Instant;

use crate::buffer::Buffer;
use crate::element::{Async, Ctx, Element, Item, Workload};
use crate::metrics;
use crate::runtime::{BatchCollector, Model, Slot};
use crate::util::{Error, Result};

pub use crate::runtime::backend::{
    CustomBackend, CustomFn, InferenceBackend, PassthroughBackend, PjrtBackend,
};

/// One frame submitted to the collector and not yet delivered
/// downstream. At most one exists per filter (per-pipeline order).
struct Inflight {
    /// The original buffer: pts/duration/meta are rewrapped around the
    /// inference output on delivery.
    buf: Buffer,
    slot: Arc<Slot>,
    t0: Instant,
}

enum Exec {
    /// Per-frame inference on this element's own task.
    Direct(Box<dyn InferenceBackend>),
    /// Frames go through the shared per-model collector.
    Batched { collector: Arc<BatchCollector>, inflight: Option<Inflight>, registered: bool },
}

pub struct TensorFilter {
    exec: Exec,
    caps_ok: bool,
}

impl TensorFilter {
    /// Direct (unbatched) filter over any [`InferenceBackend`].
    pub fn new(backend: Box<dyn InferenceBackend>) -> Self {
        Self { exec: Exec::Direct(backend), caps_ok: false }
    }

    /// Batched filter: frames route through the shared `collector`
    /// (obtain one from `runtime::models().collector(dir, name, cfg)`).
    pub fn batched(collector: Arc<BatchCollector>) -> Self {
        Self { exec: Exec::Batched { collector, inflight: None, registered: false }, caps_ok: false }
    }

    pub fn pjrt(model: Arc<Model>) -> Self {
        Self::new(Box::new(PjrtBackend::new(model)))
    }

    pub fn passthrough() -> Self {
        Self::new(Box::new(PassthroughBackend))
    }

    pub fn custom(f: CustomFn) -> Self {
        Self::new(Box::new(CustomBackend::new(f)))
    }

    fn observe_latency(ctx: &Ctx, t0: Instant) {
        metrics::global()
            .observe(&format!("filter.{}.latency_us", ctx.name), t0.elapsed().as_micros() as f64);
    }

    /// Deliver a completed in-flight frame downstream (batched mode).
    fn deliver(
        ctx: &mut Ctx,
        inflight: Inflight,
        result: Result<Vec<u8>>,
    ) -> Result<()> {
        let payload = result.map_err(|e| Error::element(&ctx.name, e))?;
        Self::observe_latency(ctx, inflight.t0);
        ctx.push_buffer(inflight.buf.map_payload(payload))
    }
}

impl Element for TensorFilter {
    /// Inference is CPU-bound, never socket-bound: explicitly schedulable
    /// on the worker pool (the density win this refactor exists for —
    /// many model-running pipelines share K threads).
    fn workload(&self) -> Workload {
        Workload::Compute
    }

    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        if let Exec::Batched { collector, registered, .. } = &mut self.exec {
            collector.register_member();
            *registered = true;
        }
        Ok(())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let out = match &mut self.exec {
                    Exec::Direct(backend) => backend.negotiate(&c),
                    Exec::Batched { collector, .. } => collector.negotiate(&c),
                }
                .map_err(|e| Error::element(&ctx.name, e))?;
                self.caps_ok = true;
                ctx.push_caps(out)
            }
            Item::Buffer(b) => {
                if !self.caps_ok {
                    return Err(Error::element(&ctx.name, "buffer before caps"));
                }
                match &mut self.exec {
                    Exec::Direct(backend) => {
                        let t0 = Instant::now();
                        let out =
                            backend.infer_buffer(&b).map_err(|e| Error::element(&ctx.name, e))?;
                        Self::observe_latency(ctx, t0);
                        ctx.push_buffer(out)
                    }
                    Exec::Batched { collector, inflight, .. } => {
                        debug_assert!(inflight.is_none(), "runner pops no input while inflight");
                        let t0 = Instant::now();
                        let waker = ctx.task_waker();
                        let thread_mode = waker.is_none();
                        let slot = collector.submit(b.data.clone(), waker);
                        if thread_mode {
                            // Dedicated thread: block right here.
                            let payload = slot
                                .wait(collector)
                                .map_err(|e| Error::element(&ctx.name, e))?;
                            Self::observe_latency(ctx, t0);
                            return ctx.push_buffer(b.map_payload(payload));
                        }
                        if let Some(r) = slot.take() {
                            // Our submit completed the batch: the dispatch
                            // ran inline and the result is already here.
                            return Self::deliver(ctx, Inflight { buf: b, slot, t0 }, r);
                        }
                        *inflight = Some(Inflight { buf: b, slot, t0 });
                        Ok(())
                    }
                }
            }
            Item::Eos => Ok(()),
        }
    }

    /// Batched mode: poll the in-flight frame. The pooled runner calls
    /// this before popping input each turn, so the frame's output goes
    /// downstream (in order) the turn after the collector completes it.
    fn pump(&mut self, ctx: &mut Ctx) -> Result<Async> {
        let Exec::Batched { collector, inflight, .. } = &mut self.exec else {
            return Ok(Async::Idle);
        };
        if inflight.is_none() {
            return Ok(Async::Idle);
        }
        // The timer daemon may have woken us for an expired budget:
        // drive the flush from this task.
        collector.poll_due();
        match inflight.as_ref().and_then(|i| i.slot.take()) {
            None => Ok(Async::Pending),
            Some(r) => {
                let inf = inflight.take().expect("checked non-empty above");
                Self::deliver(ctx, inf, r)?;
                Ok(Async::Delivered)
            }
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        if let Exec::Batched { collector, registered, .. } = &mut self.exec {
            if *registered {
                collector.deregister_member();
                *registered = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use crate::caps::Caps;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo, TensorsInfo};
    use std::time::Duration;

    #[test]
    fn passthrough_forwards() {
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("src", Box::new(src)).unwrap();
        let f = p.add("f", Box::new(TensorFilter::passthrough())).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, f).unwrap();
        p.link(f, k).unwrap();
        let _r = p.start().unwrap();
        h.push(Buffer::new(vec![1, 2, 3])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[1, 2, 3]);
    }

    #[test]
    fn custom_filter_transforms() {
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x * 2).collect())
        }));
        let s = p.add("src", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let _r = p.start().unwrap();
        h.push(Buffer::new(vec![1, 2, 3])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[2, 4, 6]);
    }

    #[test]
    fn batched_filter_single_stream_roundtrip() {
        use crate::runtime::{BatchCfg, BatchCollector};
        let collector = BatchCollector::new(
            "filter_rt",
            Box::new(PassthroughBackend),
            BatchCfg { max_batch: 8, timeout: Duration::from_millis(2) },
        );
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("src", Box::new(src)).unwrap();
        let f = p.add("f", Box::new(TensorFilter::batched(collector))).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, f).unwrap();
        p.link(f, k).unwrap();
        let _r = p.start().unwrap();
        for i in 0..5u8 {
            h.push(Buffer::new(vec![i, i, i])).unwrap();
        }
        for i in 0..5u8 {
            let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(&got.data[..], &[i, i, i], "order preserved through the collector");
        }
    }

    // PJRT-backed end-to-end filter tests live in rust/tests/ (they need
    // built artifacts).
}
