//! `tensor_filter` — neural-network inference inside a pipeline.
//!
//! `framework=pjrt model=<name>` loads an AOT HLO artifact and runs it via
//! the PJRT CPU client (the production path; Python never runs here).
//! `framework=passthrough` is the transport-isolation stand-in used by the
//! Fig 7 query benches; `framework=custom` wraps a closure (tests; also
//! the paper's custom-filter sub-plugin mechanism).

use std::sync::Arc;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::element::{Ctx, Element, Item, Workload};
use crate::metrics;
use crate::runtime::Model;
use crate::tensor::Format;
use crate::util::{Error, Result};

type CustomFn = Box<dyn FnMut(&Buffer) -> Result<Vec<u8>> + Send>;

enum Backend {
    Pjrt(Arc<Model>),
    Passthrough,
    Custom(CustomFn),
}

pub struct TensorFilter {
    backend: Backend,
    caps_ok: bool,
}

impl TensorFilter {
    pub fn pjrt(model: Arc<Model>) -> Self {
        Self { backend: Backend::Pjrt(model), caps_ok: false }
    }

    pub fn passthrough() -> Self {
        Self { backend: Backend::Passthrough, caps_ok: false }
    }

    pub fn custom(f: CustomFn) -> Self {
        Self { backend: Backend::Custom(f), caps_ok: false }
    }
}

impl Element for TensorFilter {
    /// Inference is CPU-bound, never socket-bound: explicitly schedulable
    /// on the worker pool (the density win this refactor exists for —
    /// many model-running pipelines share K threads).
    fn workload(&self) -> Workload {
        Workload::Compute
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                match &self.backend {
                    Backend::Pjrt(model) => {
                        if !c.is_tensors() {
                            return Err(Error::element(
                                &ctx.name,
                                format!("tensor_filter needs tensors caps, got `{c}`"),
                            ));
                        }
                        if c.tensor_format().map_err(|e| Error::element(&ctx.name, e))?
                            != Format::Static
                        {
                            return Err(Error::element(&ctx.name, "needs static tensors"));
                        }
                        let want = model.input_info().map_err(|e| Error::element(&ctx.name, e))?;
                        if let Ok(got) = c.tensors_info() {
                            if got != want {
                                return Err(Error::element(
                                    &ctx.name,
                                    format!(
                                        "model `{}` expects {} got {}",
                                        model.manifest.name,
                                        want.dimensions_string(),
                                        got.dimensions_string()
                                    ),
                                ));
                            }
                        }
                        let out = model.output_info().map_err(|e| Error::element(&ctx.name, e))?;
                        self.caps_ok = true;
                        ctx.push_caps(Caps::tensors(&out))
                    }
                    Backend::Passthrough => {
                        self.caps_ok = true;
                        ctx.push_caps(c)
                    }
                    Backend::Custom(_) => {
                        self.caps_ok = true;
                        ctx.push_caps(c)
                    }
                }
            }
            Item::Buffer(b) => {
                if !self.caps_ok {
                    return Err(Error::element(&ctx.name, "buffer before caps"));
                }
                let t0 = std::time::Instant::now();
                let out = match &mut self.backend {
                    Backend::Pjrt(model) => {
                        let payload =
                            model.infer_bytes(&b.data).map_err(|e| Error::element(&ctx.name, e))?;
                        b.map_payload(payload)
                    }
                    Backend::Passthrough => b,
                    Backend::Custom(f) => {
                        let payload = f(&b)?;
                        b.map_payload(payload)
                    }
                };
                metrics::global()
                    .observe(&format!("filter.{}.latency_us", ctx.name), t0.elapsed().as_micros() as f64);
                ctx.push_buffer(out)
            }
            Item::Eos => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo, TensorsInfo};
    use std::time::Duration;

    #[test]
    fn passthrough_forwards() {
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("src", Box::new(src)).unwrap();
        let f = p.add("f", Box::new(TensorFilter::passthrough())).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, f).unwrap();
        p.link(f, k).unwrap();
        let _r = p.start().unwrap();
        h.push(Buffer::new(vec![1, 2, 3])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[1, 2, 3]);
    }

    #[test]
    fn custom_filter_transforms() {
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x * 2).collect())
        }));
        let s = p.add("src", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let _r = p.start().unwrap();
        h.push(Buffer::new(vec![1, 2, 3])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[2, 4, 6]);
    }

    // PJRT-backed end-to-end filter tests live in rust/tests/ (they need
    // built artifacts).
}
