//! Built-in element library + registry wiring for pipeline descriptions.
//!
//! Property names follow the paper's listings (GStreamer/NNStreamer
//! spellings) wherever they appear there: `leaky=2`, `operation=`,
//! `sub-topic=`, `pub-topic=`, `mode=arithmetic option=...`,
//! `framework=... model=...`, `is-live=false`, `pattern=ball`, etc.
//!
//! Each element declares a `Workload` scheduling class: socket-bound and
//! app-channel elements are `Blocking` (dedicated thread), everything
//! else is `Compute` and runs on the shared worker pool (see
//! `element/sched.rs` and the README's classification table).

pub mod basic;
pub mod convert;
pub mod filter;
pub mod muxdemux;
pub mod mqttel;
pub mod query;
pub mod sparsel;
pub mod video;
pub mod zmqel;

pub use basic::{appsink_channel, appsrc_channel, AppSink, AppSrc, AppSrcHandle, CapsFilter, FakeSink, Identity, Queue, Tee};
pub use convert::{ArithOp, DecoderMode, TensorConverter, TensorDecoder, TensorTransform};
pub use filter::{
    CustomBackend, CustomFn, InferenceBackend, PassthroughBackend, PjrtBackend, TensorFilter,
};
pub use muxdemux::{IfOp, TensorDemux, TensorIf, TensorMux};
pub use mqttel::{MqttSink, MqttSrc};
pub use query::{QueryClient, QueryProtocol, QueryServerSink, QueryServerSrc, ResilienceConfig};
pub use sparsel::{SparseDec, SparseEnc};
pub use video::{Compositor, PadCfg, Pattern, VideoConvert, VideoScale, VideoTestSrc};
pub use zmqel::{ZmqSink, ZmqSrc};

use crate::caps::Caps;
use crate::element::registry::{prop_bool, prop_f64, prop_str, prop_u32, prop_u64, require_str, Props, Registry};
use crate::element::Element as _;
use crate::element::Leaky;
use crate::serial::Codec;
use crate::util::{Error, Result};

/// Default broker address used when a description omits `broker=`.
pub fn default_broker() -> String {
    std::env::var("EDGEPIPE_BROKER").unwrap_or_else(|_| "127.0.0.1:1883".to_string())
}

/// Parse the link-codec properties shared by every transport sink:
/// `compress=none|zlib|auto|delta|sparse` plus the optional
/// `keyframe-interval=` (frames per delta keyframe). Nonsense is rejected
/// at parse time, not at runtime: the interval requires a codec that can
/// actually emit delta chains (`delta` or `auto`).
fn codec_props(p: &Props, kind: &str) -> Result<(Codec, Option<u64>)> {
    let codec = Codec::parse(prop_str(p, "compress", "none"))?;
    let interval = match p.get("keyframe-interval") {
        None => None,
        Some(v) => {
            let n: u64 = v.parse().map_err(|_| {
                Error::Parse(format!("{kind}: bad keyframe-interval=`{v}` (want integer >= 1)"))
            })?;
            if n == 0 {
                return Err(Error::Parse(format!("{kind}: bad keyframe-interval=0 (want >= 1)")));
            }
            if !matches!(codec, Codec::Delta | Codec::Auto) {
                return Err(Error::Parse(format!(
                    "{kind}: keyframe-interval= needs compress=delta|auto (got compress={})",
                    codec.name()
                )));
            }
            Some(n)
        }
    };
    Ok((codec, interval))
}

fn compositor_from_props(props: &Props) -> Compositor {
    let mut c = Compositor::new(1);
    // Pad properties: sink_<n>::xpos / ypos / zorder
    let mut max_pad = 0usize;
    for k in props.keys() {
        if let Some(rest) = k.strip_prefix("sink_") {
            if let Some((n, _)) = rest.split_once("::") {
                if let Ok(n) = n.parse::<usize>() {
                    max_pad = max_pad.max(n);
                }
            }
        }
    }
    c.ensure_sink_pads(max_pad + 1);
    for pad in 0..=max_pad {
        let get = |f: &str| {
            props.get(&format!("sink_{pad}::{f}")).and_then(|v| v.parse::<u32>().ok()).unwrap_or(0)
        };
        c.set_pad(pad, PadCfg { xpos: get("xpos"), ypos: get("ypos"), zorder: get("zorder") });
    }
    c
}

/// Register every built-in element kind.
pub fn register_all(r: &mut Registry) {
    r.register("identity", |_p, _e| Ok(Box::new(Identity)));
    r.register("fakesink", |_p, _e| Ok(Box::new(FakeSink)));
    r.register("tee", |_p, _e| Ok(Box::new(Tee)));
    r.register("videoconvert", |_p, _e| Ok(Box::new(VideoConvert)));

    r.register("queue", |p, _e| {
        let leaky = Leaky::parse(prop_str(p, "leaky", "no"))?;
        let cap = prop_u32(p, "max-size-buffers", 16)? as usize;
        Ok(Box::new(Queue::new(cap, leaky)))
    });
    // Listing 2 uses `queue2` for latency injection; accept it as a big
    // non-leaky queue with an optional artificial `min-threshold-time`
    // delay handled by the runner-level property below.
    r.register("queue2", |p, _e| {
        let cap = prop_u32(p, "max-size-buffers", 64)? as usize;
        Ok(Box::new(Queue::new(cap, Leaky::No)))
    });

    r.register("capsfilter", |p, _e| {
        let spec = require_str(p, "caps", "capsfilter")?;
        Ok(Box::new(CapsFilter::new(Caps::parse(spec)?)))
    });

    r.register("videotestsrc", |p, _e| {
        let w = prop_u32(p, "width", 320)?;
        let h = prop_u32(p, "height", 240)?;
        let fps = prop_u32(p, "framerate", prop_u32(p, "fps", 30)?)?;
        let mut src = VideoTestSrc::new(w, h, fps)
            .with_pattern(Pattern::parse(prop_str(p, "pattern", "smpte"))?)
            .with_num_buffers(prop_u64(p, "num-buffers", 0)?)
            .live(prop_bool(p, "is-live", true)?);
        let _ = &mut src;
        Ok(Box::new(src))
    });
    // Listing 1/2 use v4l2src (USB camera); our synthetic camera stands in
    // (see DESIGN.md substitutions).
    r.register("v4l2src", |p, _e| {
        let w = prop_u32(p, "width", 640)?;
        let h = prop_u32(p, "height", 480)?;
        let fps = prop_u32(p, "framerate", 30)?;
        Ok(Box::new(
            VideoTestSrc::new(w, h, fps)
                .with_pattern(Pattern::Ball)
                .with_num_buffers(prop_u64(p, "num-buffers", 0)?),
        ))
    });

    r.register("videoscale", |p, _e| {
        let w = prop_u32(p, "width", 0)?;
        let h = prop_u32(p, "height", 0)?;
        if w == 0 || h == 0 {
            return Err(Error::Parse("videoscale needs width= and height=".into()));
        }
        Ok(Box::new(VideoScale::new(w, h)))
    });

    r.register("compositor", |p, _e| Ok(Box::new(compositor_from_props(p))));

    r.register("appsrc", |p, _e| {
        let key = require_str(p, "channel", "appsrc")?;
        Ok(Box::new(AppSrc::from_channel(key, None)?))
    });
    r.register("appsink", |p, _e| {
        match p.get("channel") {
            Some(key) => Ok(Box::new(AppSink::to_channel(key, prop_u32(p, "depth", 64)? as usize))),
            None => Ok(Box::new(AppSink::detached())),
        }
    });
    r.register("ximagesink", |_p, _e| Ok(Box::new(FakeSink))); // headless display

    r.register("tensor_converter", |_p, _e| Ok(Box::new(TensorConverter::new())));

    r.register("tensor_transform", |p, _e| {
        let mode = prop_str(p, "mode", "arithmetic");
        if mode != "arithmetic" {
            return Err(Error::Parse(format!("tensor_transform mode `{mode}` unsupported")));
        }
        let opt = require_str(p, "option", "tensor_transform")?;
        Ok(Box::new(TensorTransform::new(TensorTransform::parse_option(opt)?)))
    });

    r.register("tensor_decoder", |p, _e| {
        let mode = require_str(p, "mode", "tensor_decoder")?;
        let geom = |key: &str, def: (u32, u32)| -> Result<(u32, u32)> {
            match p.get(key) {
                None => Ok(def),
                Some(v) => {
                    let (w, h) = v
                        .split_once(':')
                        .ok_or_else(|| Error::Parse(format!("bad geometry `{v}`")))?;
                    Ok((
                        w.parse().map_err(|_| Error::Parse(format!("bad geometry `{v}`")))?,
                        h.parse().map_err(|_| Error::Parse(format!("bad geometry `{v}`")))?,
                    ))
                }
            }
        };
        let m = match mode {
            "bounding_boxes" => {
                // option4=WIDTH:HEIGHT in NNStreamer's decoder options.
                let (w, h) = geom("option4", (640, 480))?;
                DecoderMode::BoundingBoxes { width: w, height: h }
            }
            "direct_video" => DecoderMode::DirectVideo,
            "flexbuf" => DecoderMode::Flexbuf,
            "pose" => {
                let (w, h) = geom("option4", (192, 192))?;
                DecoderMode::Pose { width: w, height: h }
            }
            other => return Err(Error::Parse(format!("tensor_decoder mode `{other}` unsupported"))),
        };
        Ok(Box::new(TensorDecoder::new(m)))
    });

    r.register("tensor_filter", |p, e| {
        let fw = prop_str(p, "framework", "pjrt");
        // Batching knobs, validated BEFORE any model load so a bad value
        // surfaces as a parse error, never an artifacts error.
        let batch = match p.get("batch") {
            None => None,
            Some(v) => {
                let b: usize = v.parse().map_err(|_| {
                    Error::Parse(format!("bad batch={v} (want integer >= 1)"))
                })?;
                if b == 0 {
                    return Err(Error::Parse(
                        "bad batch=0 (want >= 1; batch=1 disables coalescing)".into(),
                    ));
                }
                Some(b)
            }
        };
        let timeout_ms = match p.get("batch-timeout-ms") {
            None => None,
            Some(v) => {
                let t: u64 = v.parse().map_err(|_| {
                    Error::Parse(format!("bad batch-timeout-ms={v} (want integer >= 1)"))
                })?;
                if t == 0 {
                    return Err(Error::Parse("bad batch-timeout-ms=0 (want >= 1)".into()));
                }
                Some(t)
            }
        };
        if batch.is_none() && timeout_ms.is_some() {
            return Err(Error::Parse(
                "batch-timeout-ms= without batch= (set batch=<B> to enable batching)".into(),
            ));
        }
        let cfg = batch.map(|b| {
            let mut c = crate::runtime::BatchCfg { max_batch: b, ..Default::default() };
            if let Some(t) = timeout_ms {
                c.timeout = std::time::Duration::from_millis(t);
            }
            c
        });
        match fw {
            "pjrt" | "tensorflow-lite" | "tensorflow" => {
                // Model path: accept a bare name or `/path/<name>.tflite`
                // (listing compatibility) and map to artifacts/<name>.
                let raw = require_str(p, "model", "tensor_filter")?;
                let name = raw
                    .rsplit('/')
                    .next()
                    .unwrap_or(raw)
                    .trim_end_matches(".tflite")
                    .trim_end_matches(".hlo.txt");
                // The process-wide registry is the one constructor path:
                // every pipeline naming the same model shares one
                // Arc<Model> (and one collector when batching).
                let models = crate::runtime::models();
                match cfg {
                    Some(cfg) => Ok(Box::new(TensorFilter::batched(models.collector(
                        &e.artifacts_dir,
                        name,
                        cfg,
                    )?))),
                    None => Ok(Box::new(TensorFilter::pjrt(models.get(&e.artifacts_dir, name)?))),
                }
            }
            "passthrough" => match cfg {
                // Per-instance collector: passthrough has no model key to
                // share under, and batching it only matters in tests.
                Some(cfg) => Ok(Box::new(TensorFilter::batched(
                    crate::runtime::BatchCollector::new(
                        "passthrough",
                        Box::new(PassthroughBackend),
                        cfg,
                    ),
                ))),
                None => Ok(Box::new(TensorFilter::passthrough())),
            },
            other => Err(Error::Parse(format!("tensor_filter framework `{other}` unsupported"))),
        }
    });

    r.register("tensor_mux", |p, _e| Ok(Box::new(TensorMux::new(prop_u32(p, "pads", 2)? as usize))));
    r.register("tensor_demux", |p, _e| Ok(Box::new(TensorDemux::new(prop_u32(p, "srcs", 1)? as usize))));

    r.register("tensor_if", |p, _e| {
        let idx = prop_u32(p, "compared-value", 0)? as usize;
        let op = IfOp::parse(prop_str(p, "operator", "gt"))?;
        let thr: f32 = prop_str(p, "threshold", "0.5")
            .parse()
            .map_err(|_| Error::Parse("bad threshold".into()))?;
        Ok(Box::new(TensorIf::new(idx, op, thr)))
    });

    r.register("tensor_sparse_enc", |_p, _e| Ok(Box::new(SparseEnc::new())));
    r.register("tensor_sparse_dec", |_p, _e| Ok(Box::new(SparseDec::new())));

    r.register("mqttsink", |p, _e| {
        let topic = require_str(p, "pub-topic", "mqttsink")?;
        let broker = prop_str(p, "broker", "");
        let broker = if broker.is_empty() { default_broker() } else { broker.to_string() };
        let (codec, interval) = codec_props(p, "mqttsink")?;
        let mut sink =
            MqttSink::new(&broker, topic).with_codec(codec).with_sync(prop_bool(p, "sync", true)?);
        if let Some(k) = interval {
            sink = sink.with_keyframe_interval(k);
        }
        Ok(Box::new(sink))
    });
    r.register("mqttsrc", |p, _e| {
        let topic = require_str(p, "sub-topic", "mqttsrc")?;
        let broker = prop_str(p, "broker", "");
        let broker = if broker.is_empty() { default_broker() } else { broker.to_string() };
        Ok(Box::new(MqttSrc::new(&broker, topic).with_sync(prop_bool(p, "sync", true)?)))
    });

    r.register("zmqsink", |p, _e| {
        let bind = require_str(p, "bind", "zmqsink")?;
        let topic = prop_str(p, "topic", "stream");
        let (codec, interval) = codec_props(p, "zmqsink")?;
        let mut sink = ZmqSink::new(bind, topic).with_codec(codec);
        if let Some(k) = interval {
            sink = sink.with_keyframe_interval(k);
        }
        Ok(Box::new(sink))
    });
    r.register("zmqsrc", |p, _e| {
        let connect = require_str(p, "connect", "zmqsrc")?;
        let topic = prop_str(p, "topic", "stream");
        Ok(Box::new(ZmqSrc::new(connect, topic)))
    });

    r.register("tensor_query_client", |p, _e| {
        use std::time::Duration;
        let op = require_str(p, "operation", "tensor_query_client")?;
        let proto = QueryProtocol::parse(prop_str(p, "protocol", "tcp"))?;
        let timeout = Duration::from_millis(prop_u64(p, "timeout-ms", 5000)?);
        // Resilience policy (see rust/src/README.md "Resilient elastic
        // offload"): defaults come from ResilienceConfig.
        let mut cfg = ResilienceConfig::default();
        cfg.retry = prop_u32(p, "retry", cfg.retry)?.max(1);
        cfg.backoff = Duration::from_millis(prop_u64(p, "backoff-ms", cfg.backoff.as_millis() as u64)?);
        cfg.backoff_max =
            Duration::from_millis(prop_u64(p, "backoff-max-ms", cfg.backoff_max.as_millis() as u64)?);
        let deadline = prop_u64(p, "deadline-ms", 0)?;
        cfg.deadline = (deadline > 0).then(|| Duration::from_millis(deadline));
        let hedge = prop_f64(p, "hedge-pct", 0.0)?;
        if !(0.0..=1.0).contains(&hedge) {
            return Err(Error::Parse(format!("bad hedge-pct={hedge} (want 0..=1)")));
        }
        cfg.hedge_pct = (hedge > 0.0).then_some(hedge);
        let (codec, interval) = codec_props(p, "tensor_query_client")?;
        if codec == Codec::Delta && cfg.hedge_pct.is_some() {
            // An explicit delta chain makes every non-keyframe request
            // undecodable by a second server, so hedging would silently
            // never fire mid-chain. `compress=auto` is fine: the client
            // only hedges frames the codec emitted as self-contained.
            return Err(Error::Parse(
                "tensor_query_client: hedge-pct= cannot combine with compress=delta \
                 (mid-chain requests are not hedgeable; use compress=auto)"
                    .into(),
            ));
        }
        let reroute = prop_f64(p, "reroute-load", cfg.reroute_load)?;
        if !(0.0..=1.0).contains(&reroute) {
            return Err(Error::Parse(format!("bad reroute-load={reroute} (want 0..=1)")));
        }
        cfg.reroute_load = reroute;
        cfg.breaker.failure_threshold =
            prop_u32(p, "breaker-threshold", cfg.breaker.failure_threshold)?.max(1);
        let open_ms = prop_u64(p, "breaker-open-ms", cfg.breaker.open_base.as_millis() as u64)?;
        if open_ms == 0 {
            // A zero open interval means the breaker re-closes instantly,
            // i.e. it never actually sheds load from a failing peer.
            return Err(Error::Parse("bad breaker-open-ms=0 (want >= 1)".into()));
        }
        cfg.breaker.open_base = Duration::from_millis(open_ms);
        let mut client = match proto {
            QueryProtocol::TcpRaw => {
                let server = require_str(p, "server", "tensor_query_client")?;
                QueryClient::tcp(op, server)
            }
            QueryProtocol::MqttHybrid => {
                let broker = prop_str(p, "broker", "");
                let broker = if broker.is_empty() { default_broker() } else { broker.to_string() };
                QueryClient::hybrid(op, &broker)?
            }
        };
        client = client.with_timeout(timeout).with_resilience(cfg).with_codec(codec);
        if let Some(k) = interval {
            client = client.with_keyframe_interval(k);
        }
        Ok(Box::new(client))
    });
    r.register("tensor_query_serversrc", |p, _e| {
        let op = require_str(p, "operation", "tensor_query_serversrc")?;
        let mut src = QueryServerSrc::new(op)
            .with_pair_id(prop_str(p, "pair-id", op))
            .with_bind(&format!("127.0.0.1:{}", prop_u32(p, "port", 0)?))
            .with_model_label(prop_str(p, "model-label", "model"))
            .with_advertised_load(prop_f64(p, "load", 0.0)?);
        if let Some(id) = p.get("server-id") {
            src = src.with_server_id(id);
        }
        if QueryProtocol::parse(prop_str(p, "protocol", "tcp"))? == QueryProtocol::MqttHybrid {
            let broker = prop_str(p, "broker", "");
            let broker = if broker.is_empty() { default_broker() } else { broker.to_string() };
            src = src.with_hybrid(&broker);
        }
        Ok(Box::new(src))
    });
    r.register("tensor_query_serversink", |p, _e| {
        let op = require_str(p, "operation", "tensor_query_serversink")?;
        let (codec, interval) = codec_props(p, "tensor_query_serversink")?;
        let mut sink = QueryServerSink::new(prop_str(p, "pair-id", op)).with_codec(codec);
        if let Some(k) = interval {
            sink = sink.with_keyframe_interval(k);
        }
        Ok(Box::new(sink))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::registry::PipelineEnv;

    fn registry() -> Registry {
        Registry::with_builtins()
    }

    #[test]
    fn all_paper_elements_registered() {
        let r = registry();
        for kind in [
            "videotestsrc",
            "v4l2src",
            "videoconvert",
            "videoscale",
            "compositor",
            "queue",
            "queue2",
            "tee",
            "capsfilter",
            "appsink",
            "ximagesink",
            "tensor_converter",
            "tensor_transform",
            "tensor_decoder",
            "tensor_filter",
            "tensor_mux",
            "tensor_demux",
            "tensor_if",
            "tensor_sparse_enc",
            "tensor_sparse_dec",
            "mqttsink",
            "mqttsrc",
            "zmqsink",
            "zmqsrc",
            "tensor_query_client",
            "tensor_query_serversrc",
            "tensor_query_serversink",
        ] {
            assert!(r.contains(kind), "missing element `{kind}`");
        }
    }

    #[test]
    fn queue_props_parsed() {
        let r = registry();
        let env = PipelineEnv::default();
        let mut p = Props::new();
        p.insert("leaky".into(), "2".into());
        p.insert("max-size-buffers".into(), "4".into());
        let el = r.make("queue", &p, &env).unwrap();
        let cfg = el.sink_queue_cfg(0);
        assert_eq!(cfg.capacity, 4);
        assert_eq!(cfg.leaky, Leaky::Downstream);
    }

    #[test]
    fn query_client_resilience_props_parsed() {
        let r = registry();
        let env = PipelineEnv::default();
        let mut p = Props::new();
        p.insert("operation".into(), "obj".into());
        p.insert("server".into(), "127.0.0.1:9000".into());
        p.insert("retry".into(), "5".into());
        p.insert("backoff-ms".into(), "20".into());
        p.insert("deadline-ms".into(), "250".into());
        p.insert("hedge-pct".into(), "0.95".into());
        p.insert("reroute-load".into(), "0.8".into());
        p.insert("breaker-threshold".into(), "2".into());
        p.insert("breaker-open-ms".into(), "100".into());
        assert!(r.make("tensor_query_client", &p, &env).is_ok());
        p.insert("hedge-pct".into(), "1.5".into());
        assert!(r.make("tensor_query_client", &p, &env).is_err());
        p.insert("hedge-pct".into(), "0.95".into());
        p.insert("reroute-load".into(), "-0.1".into());
        assert!(r.make("tensor_query_client", &p, &env).is_err(), "negative reroute-load");
        p.insert("reroute-load".into(), "0.8".into());
        p.insert("breaker-open-ms".into(), "0".into());
        assert!(r.make("tensor_query_client", &p, &env).is_err(), "zero breaker-open-ms");
    }

    #[test]
    fn transport_codec_props_validated() {
        let r = registry();
        let env = PipelineEnv::default();
        // mqttsink: every codec arm parses; interval needs delta|auto.
        let mut p = Props::new();
        p.insert("pub-topic".into(), "t".into());
        for codec in ["none", "zlib", "auto", "delta", "sparse"] {
            p.insert("compress".into(), codec.into());
            assert!(r.make("mqttsink", &p, &env).is_ok(), "compress={codec}");
        }
        p.insert("compress".into(), "lzma".into());
        assert!(r.make("mqttsink", &p, &env).is_err(), "unknown codec");
        p.insert("compress".into(), "delta".into());
        p.insert("keyframe-interval".into(), "8".into());
        assert!(r.make("mqttsink", &p, &env).is_ok());
        p.insert("keyframe-interval".into(), "0".into());
        assert!(r.make("mqttsink", &p, &env).is_err(), "zero interval");
        p.insert("keyframe-interval".into(), "often".into());
        assert!(r.make("mqttsink", &p, &env).is_err(), "non-numeric interval");
        p.insert("keyframe-interval".into(), "8".into());
        p.insert("compress".into(), "zlib".into());
        assert!(r.make("mqttsink", &p, &env).is_err(), "interval without delta|auto");
        p.insert("compress".into(), "auto".into());
        assert!(r.make("mqttsink", &p, &env).is_ok(), "interval with auto");

        // zmqsink shares the same helper.
        let mut z = Props::new();
        z.insert("bind".into(), "127.0.0.1:0".into());
        z.insert("compress".into(), "delta".into());
        z.insert("keyframe-interval".into(), "4".into());
        assert!(r.make("zmqsink", &z, &env).is_ok());
        z.insert("compress".into(), "sparse".into());
        assert!(r.make("zmqsink", &z, &env).is_err(), "interval with sparse");

        // Server response hop.
        let mut s = Props::new();
        s.insert("operation".into(), "obj".into());
        s.insert("compress".into(), "delta".into());
        s.insert("keyframe-interval".into(), "16".into());
        assert!(r.make("tensor_query_serversink", &s, &env).is_ok());

        // Query client: delta chains and hedging are mutually exclusive.
        let mut q = Props::new();
        q.insert("operation".into(), "obj".into());
        q.insert("server".into(), "127.0.0.1:9000".into());
        q.insert("compress".into(), "delta".into());
        assert!(r.make("tensor_query_client", &q, &env).is_ok());
        q.insert("hedge-pct".into(), "0.9".into());
        assert!(r.make("tensor_query_client", &q, &env).is_err(), "hedge + delta");
        q.insert("compress".into(), "auto".into());
        assert!(r.make("tensor_query_client", &q, &env).is_ok(), "hedge + auto ok");
    }

    #[test]
    fn missing_required_props_error() {
        let r = registry();
        let env = PipelineEnv::default();
        assert!(r.make("mqttsink", &Props::new(), &env).is_err());
        assert!(r.make("tensor_query_client", &Props::new(), &env).is_err());
        assert!(r.make("videoscale", &Props::new(), &env).is_err());
        assert!(r.make("capsfilter", &Props::new(), &env).is_err());
    }

    #[test]
    fn compositor_pad_props() {
        let mut p = Props::new();
        p.insert("sink_1::xpos".into(), "100".into());
        p.insert("sink_1::zorder".into(), "2".into());
        let c = compositor_from_props(&p);
        assert_eq!(c.n_sink_pads(), 2);
    }

    #[test]
    fn tensor_filter_batch_props_validated() {
        let r = registry();
        let env = PipelineEnv::default();
        let mut p = Props::new();
        p.insert("framework".into(), "passthrough".into());
        p.insert("batch".into(), "8".into());
        p.insert("batch-timeout-ms".into(), "3".into());
        assert!(r.make("tensor_filter", &p, &env).is_ok());
        p.insert("batch".into(), "0".into());
        assert!(r.make("tensor_filter", &p, &env).is_err(), "batch=0");
        p.insert("batch".into(), "eight".into());
        assert!(r.make("tensor_filter", &p, &env).is_err(), "non-numeric batch");
        p.insert("batch".into(), "8".into());
        p.insert("batch-timeout-ms".into(), "0".into());
        assert!(r.make("tensor_filter", &p, &env).is_err(), "batch-timeout-ms=0");
        p.insert("batch-timeout-ms".into(), "soon".into());
        assert!(r.make("tensor_filter", &p, &env).is_err(), "non-numeric timeout");
        let mut lone = Props::new();
        lone.insert("framework".into(), "passthrough".into());
        lone.insert("batch-timeout-ms".into(), "3".into());
        assert!(r.make("tensor_filter", &lone, &env).is_err(), "timeout without batch");
    }

    #[test]
    fn tensor_filter_model_name_mapping() {
        // `/PATH/ssd_mobilenet_v2_coco.tflite` maps to artifact name
        // `ssd_mobilenet_v2_coco` (which won't exist -> error mentions it).
        let r = registry();
        let env = PipelineEnv { artifacts_dir: "/nonexistent".into() };
        let mut p = Props::new();
        p.insert("model".into(), "/PATH/detector.tflite".into());
        let err = match r.make("tensor_filter", &p, &env) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("detector") || err.contains("nonexistent"), "{err}");
    }
}
