//! `mqttsink` / `mqttsrc` — stream pub/sub over MQTT (§4.2.1, Fig 3).
//!
//! The sink publishes EdgeFrames (payload + caps string + timestamps +
//! publisher base-time) on `pub-topic`; the source subscribes to
//! `sub-topic` (wildcards allowed) and reconstructs the stream,
//! re-negotiating caps in-band and correcting timestamps against the
//! local pipeline clock (§4.2.3) using an NTP offset when a sync server
//! is advertised on `<topic>/__sync`.

use std::sync::mpsc::Receiver;
use std::time::Duration;

use crate::caps::Caps;
use crate::element::{Ctx, Element, Item, Workload};
use crate::metrics;
use crate::mqtt::{ClientOptions, Message, MqttClient};
use crate::ntp::{NtpServer, SyncedClock};
use crate::serial::flexbuf::{self, Value};
use crate::serial::wire::{LinkCodec, LinkDecoder};
use crate::serial::Codec;
use crate::util::{Error, Result};
use crate::log_warn;

fn sync_topic(topic: &str) -> String {
    format!("{topic}/__sync")
}

/// Publish a pipeline stream to an MQTT topic.
pub struct MqttSink {
    pub broker: String,
    pub topic: String,
    /// Enable §4.2.3 timestamp sync: run an NTP responder and advertise it.
    pub enable_sync: bool,
    client: Option<MqttClient>,
    ntp: Option<NtpServer>,
    caps: Option<Caps>,
    link: LinkCodec,
}

impl MqttSink {
    pub fn new(broker: &str, topic: &str) -> Self {
        Self {
            broker: broker.to_string(),
            topic: topic.to_string(),
            enable_sync: true,
            client: None,
            ntp: None,
            caps: None,
            link: LinkCodec::new(Codec::None, ""),
        }
    }

    /// `Codec::Auto` gets a per-link adaptive state (keyed by topic) that
    /// samples compression ratios into `codec.auto.mqttsink.<topic>.*`;
    /// `Codec::Delta`/`Auto` additionally count keyframes/deltas into
    /// `codec.delta.mqttsink.<topic>.*`.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        let interval = self.link.keyframe_interval();
        self.link = LinkCodec::new(codec, &format!("mqttsink.{}", self.topic))
            .with_keyframe_interval(interval);
        self
    }

    /// Frames per delta-chain keyframe period (`Codec::Delta`/`Auto`).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.link.set_keyframe_interval(interval);
        self
    }

    /// The configured codec (`Auto` reports the policy, not the per-frame
    /// resolution).
    pub fn codec(&self) -> Codec {
        self.link.codec()
    }

    pub fn with_sync(mut self, enable: bool) -> Self {
        self.enable_sync = enable;
        self
    }
}

impl Element for MqttSink {
    fn n_src_pads(&self) -> usize {
        0
    }

    /// Socket-bound (broker connect + publish writes): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn start(&mut self, ctx: &mut Ctx) -> Result<()> {
        let client = MqttClient::connect(
            &self.broker,
            ClientOptions {
                client_id: format!("edgepipe-pub-{}-{}", ctx.name, std::process::id()),
                keep_alive_secs: 10,
                will: None,
                channel_depth: 64,
            },
        )?;
        if self.enable_sync {
            let ntp = NtpServer::start("0.0.0.0:0")?;
            let ad = flexbuf::encode(&flexbuf::map(vec![
                ("ntp_port", Value::UInt(ntp.addr().port() as u64)),
                ("base_universal", Value::UInt(ctx.clock.base_universal)),
            ]));
            client.publish(&sync_topic(&self.topic), &ad, true)?;
            self.ntp = Some(ntp);
        }
        self.client = Some(client);
        Ok(())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                self.caps = Some(c);
                Ok(())
            }
            Item::Buffer(mut b) => {
                let client =
                    self.client.as_ref().ok_or_else(|| Error::element(&ctx.name, "not started"))?;
                b.meta.remote_base_universal = Some(ctx.clock.base_universal);
                if let Some(pts) = b.pts {
                    b.meta.capture_universal = Some(ctx.clock.pts_to_universal(pts));
                }
                // Zero-copy hop: the EdgeFrame shares the buffer payload
                // (or deflates it in-place into a single-allocation frame)
                // and publish_frame emits it with one vectored write.
                let frame = self
                    .link
                    .encode(&b, self.caps.as_ref())
                    .map_err(|e| Error::element(&ctx.name, e))?;
                metrics::global()
                    .counter(&format!("mqttsink.{}", ctx.name))
                    .add_bytes(frame.len() as u64);
                client
                    .publish_frame(&self.topic, &frame, false)
                    .map_err(|e| Error::element(&ctx.name, e))
            }
            Item::Eos => Ok(()),
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        if let Some(c) = &self.client {
            if self.enable_sync {
                let _ = c.publish(&sync_topic(&self.topic), &[], true);
            }
            c.disconnect();
        }
    }
}

/// Subscribe to an MQTT topic and re-emit the stream locally.
pub struct MqttSrc {
    pub broker: String,
    pub topic: String,
    /// Apply NTP offset correction to incoming timestamps.
    pub enable_sync: bool,
    rx: Option<Receiver<Message>>,
    client: Option<MqttClient>,
    synced: SyncedClock,
    last_caps: Option<Caps>,
    sync_started: bool,
    decoder: LinkDecoder,
}

impl MqttSrc {
    pub fn new(broker: &str, topic: &str) -> Self {
        Self {
            broker: broker.to_string(),
            topic: topic.to_string(),
            enable_sync: true,
            rx: None,
            client: None,
            synced: SyncedClock::new(),
            last_caps: None,
            sync_started: false,
            decoder: LinkDecoder::new(&format!("mqttsrc.{topic}")),
        }
    }

    pub fn with_sync(mut self, enable: bool) -> Self {
        self.enable_sync = enable;
        self
    }
}

impl Element for MqttSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    /// Socket-bound (blocking subscribe receive): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }

    fn start(&mut self, ctx: &mut Ctx) -> Result<()> {
        let client = MqttClient::connect(
            &self.broker,
            ClientOptions {
                client_id: format!("edgepipe-sub-{}-{}", ctx.name, std::process::id()),
                keep_alive_secs: 10,
                will: None,
                channel_depth: 32,
            },
        )?;
        let rx = client.subscribe(&self.topic)?;
        if self.enable_sync {
            // Watch for the publisher's sync advertisement.
            let synced = self.synced.clone();
            client.subscribe_cb(&sync_topic(&self.topic), move |msg| {
                if msg.payload.is_empty() {
                    return;
                }
                if let Ok(v) = flexbuf::decode(&msg.payload) {
                    if let Ok(port) = v.field("ntp_port").and_then(|p| p.as_u64()) {
                        let server = format!("127.0.0.1:{port}");
                        if let Err(e) = synced.sync_once(&server, 4) {
                            log_warn!("mqttsrc", "ntp sync to {server} failed: {e}");
                        }
                    }
                }
            })?;
        }
        self.rx = Some(rx);
        self.client = Some(client);
        Ok(())
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        let Some(rx) = &self.rx else { return Ok(false) };
        if !self.sync_started {
            self.sync_started = true;
        }
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(msg) => {
                // msg.payload is the socket read's single allocation; the
                // decoded buffer is a slice view into it (zero copy). The
                // LinkDecoder tracks this subscription's delta chain; a
                // mid-chain delta after loss decodes to None (dropped,
                // never corrupt) until the publisher's next keyframe.
                let decoded =
                    self.decoder.decode(&msg.payload).map_err(|e| Error::element(&ctx.name, e))?;
                metrics::global()
                    .counter(&format!("mqttsrc.{}", ctx.name))
                    .add_bytes(msg.payload.len() as u64);
                let Some((mut buf, caps)) = decoded else { return Ok(true) };
                if let Some(c) = caps {
                    if self.last_caps.as_ref() != Some(&c) {
                        ctx.push_caps(c.clone())?;
                        self.last_caps = Some(c);
                    }
                }
                // §4.2.3: re-base the publisher's timestamps on our clock.
                // With sync disabled the raw remote running-time passes
                // through (the broken pre-sync behaviour the paper fixes).
                if self.enable_sync {
                    if let (Some(remote_base), Some(pts)) = (buf.meta.remote_base_universal, buf.pts)
                    {
                        buf.pts =
                            Some(ctx.clock.remote_pts_to_local(remote_base, pts, self.synced.offset_ns()));
                    }
                }
                ctx.push_buffer(buf)?;
                Ok(true)
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(!ctx.stopped()),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(false),
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        if let Some(c) = &self.client {
            c.disconnect();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::mqtt::Broker;
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo, TensorsInfo};

    fn pubsub_pair(broker: &str, topic: &str, codec: Codec) -> (crate::pipeline::Running, crate::pipeline::Running, crate::elements::basic::AppSrcHandle, Receiver<Buffer>) {
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[4]).unwrap());
        // Publisher pipeline: appsrc -> mqttsink
        let mut pp = Pipeline::new();
        let (src, h) = AppSrc::new(8, Some(Caps::tensors(&info)));
        let s = pp.add("src", Box::new(src)).unwrap();
        let m = pp
            .add("pub", Box::new(MqttSink::new(broker, topic).with_codec(codec)))
            .unwrap();
        pp.link(s, m).unwrap();
        // Subscriber pipeline: mqttsrc -> appsink
        let mut sp = Pipeline::new();
        let (sink, rx) = AppSink::new(8);
        let ms = sp.add("sub", Box::new(MqttSrc::new(broker, topic))).unwrap();
        let k = sp.add("sink", Box::new(sink)).unwrap();
        sp.link(ms, k).unwrap();
        let sub_running = sp.start().unwrap();
        std::thread::sleep(Duration::from_millis(200)); // subscription lands
        let pub_running = pp.start().unwrap();
        (pub_running, sub_running, h, rx)
    }

    #[test]
    fn pubsub_delivers_buffers_and_caps() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let (pr, sr, h, rx) = pubsub_pair(&broker.addr().to_string(), "t/pubsub", Codec::None);
        h.push(Buffer::new(vec![1, 2, 3, 4]).with_pts(1000)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(3)).unwrap();
        assert_eq!(&out.data[..], &[1, 2, 3, 4]);
        assert!(out.pts.is_some());
        drop(h);
        let _ = pr.stop(Duration::from_secs(5));
        let _ = sr.stop(Duration::from_secs(5));
    }

    #[test]
    fn pubsub_with_zlib_compression() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let (pr, sr, h, rx) = pubsub_pair(&broker.addr().to_string(), "t/gz", Codec::Zlib);
        h.push(Buffer::new(vec![7, 7, 7, 7])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(3)).unwrap();
        assert_eq!(&out.data[..], &[7, 7, 7, 7]);
        drop(h);
        let _ = pr.stop(Duration::from_secs(5));
        let _ = sr.stop(Duration::from_secs(5));
    }

    #[test]
    fn pubsub_with_auto_codec() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let (pr, sr, h, rx) = pubsub_pair(&broker.addr().to_string(), "t/auto", Codec::Auto);
        // Tiny incompressible-ish and larger compressible payloads both
        // arrive intact regardless of which codec Auto picked per frame.
        h.push(Buffer::new(vec![1, 2, 3, 4])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(3)).unwrap();
        assert_eq!(&out.data[..], &[1, 2, 3, 4]);
        drop(h);
        let _ = pr.stop(Duration::from_secs(5));
        let _ = sr.stop(Duration::from_secs(5));
    }

    #[test]
    fn pubsub_with_delta_codec() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let (pr, sr, h, rx) = pubsub_pair(&broker.addr().to_string(), "t/delta", Codec::Delta);
        // A correlated sequence: keyframe, then deltas; each must arrive
        // byte-exact through the stateful decode path.
        let mut payload = vec![9u8; 4096];
        for i in 0..5u8 {
            payload[i as usize * 700] = i;
            h.push(Buffer::new(payload.clone()).with_pts(i as u64 * 1000)).unwrap();
            let out = rx.recv_timeout(Duration::from_secs(3)).unwrap();
            assert_eq!(&out.data[..], &payload[..], "frame {i}");
        }
        drop(h);
        let _ = pr.stop(Duration::from_secs(5));
        let _ = sr.stop(Duration::from_secs(5));
    }

    #[test]
    fn timestamps_rebased_to_subscriber_clock() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let (pr, sr, h, rx) = pubsub_pair(&broker.addr().to_string(), "t/sync", Codec::None);
        std::thread::sleep(Duration::from_millis(300)); // let NTP ad land
        h.push(Buffer::new(vec![0, 0, 0, 0]).with_pts(0)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(3)).unwrap();
        // Publisher PTS 0 was stamped at publisher base-time; on the
        // subscriber clock that instant is >= 0 and close to "now".
        let pts = out.pts.unwrap();
        assert!(pts < 30 * crate::clock::SECOND, "pts {pts}");
        drop(h);
        let _ = pr.stop(Duration::from_secs(5));
        let _ = sr.stop(Duration::from_secs(5));
    }
}
