//! Stream topology elements: `tensor_mux` (N pads → one combined frame,
//! with the timestamp-delta accounting the §4.2.3 sync experiment
//! measures), `tensor_demux` (split tensors to pads), and `tensor_if`
//! (condition-gated routing — the Fig 5 activation gate).

use std::collections::VecDeque;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::element::{Ctx, Element, Item};
use crate::metrics;
use crate::tensor::TensorsInfo;
use crate::util::{Error, Result};

/// Combine one frame from each sink pad into a single multi-tensor frame.
/// Output pts = pad 0's pts. Records `|max(pts)-min(pts)|` per merged set
/// into the global histogram `mux.<name>.delta_ms` (experiment E3).
pub struct TensorMux {
    n_pads: usize,
    caps: Vec<Option<TensorsInfo>>,
    queues: Vec<VecDeque<Buffer>>,
    caps_sent: bool,
}

impl TensorMux {
    pub fn new(n_pads: usize) -> Self {
        let n = n_pads.max(2);
        Self { n_pads: n, caps: vec![None; n], queues: vec![VecDeque::new(); n], caps_sent: false }
    }

    fn try_emit(&mut self, ctx: &mut Ctx) -> Result<()> {
        while self.queues.iter().all(|q| !q.is_empty()) {
            if !self.caps_sent {
                if self.caps.iter().any(|c| c.is_none()) {
                    return Ok(()); // all buffers there but caps missing
                }
                let mut merged = TensorsInfo::default();
                for c in self.caps.iter().flatten() {
                    for t in &c.tensors {
                        merged.push(t.clone()).map_err(|e| Error::element(&ctx.name, e))?;
                    }
                }
                ctx.push_caps(Caps::tensors(&merged))?;
                self.caps_sent = true;
            }
            // Timestamp-aligned pairing (sync_mode=basepad analog): if all
            // heads carry PTS, drop stale frames from lagging queues until
            // every head is within `slack` of the newest head. Corrected
            // timestamps (§4.2.3) make this align frames captured at the
            // same real instant even when publishers started at different
            // times.
            if self.queues.iter().all(|q| q.front().is_some_and(|b| b.pts.is_some())) {
                let newest = self.queues.iter().map(|q| q.front().unwrap().pts.unwrap()).max().unwrap();
                let slack = self
                    .queues
                    .iter()
                    .filter_map(|q| q.front().unwrap().duration)
                    .max()
                    .unwrap_or(33_000_000); // default one 30fps frame period
                let mut dropped_stale = false;
                for q in self.queues.iter_mut() {
                    while q.len() > 1 && q.front().unwrap().pts.unwrap() + slack < newest {
                        q.pop_front();
                        dropped_stale = true;
                    }
                }
                if dropped_stale && self.queues.iter().any(|q| q.is_empty()) {
                    return Ok(()); // wait for fresher frames on the lagging pad
                }
                if self.queues.iter().any(|q| {
                    q.len() == 1 && q.front().unwrap().pts.unwrap() + slack < newest
                }) {
                    // Lagging pad has only a stale frame; merge anyway (the
                    // delta metric will show the residual skew).
                }
            }
            let parts: Vec<Buffer> =
                self.queues.iter_mut().map(|q| q.pop_front().unwrap()).collect();
            // E3 metric: true capture-time skew when ground truth is
            // available (transport sinks stamp capture_universal), else the
            // corrected-PTS skew.
            let caps_t: Vec<u64> = parts.iter().filter_map(|b| b.meta.capture_universal).collect();
            let ptss: Vec<u64> = parts.iter().filter_map(|b| b.pts).collect();
            let basis = if caps_t.len() == parts.len() { &caps_t } else { &ptss };
            if basis.len() == parts.len() && !basis.is_empty() {
                let delta = (*basis.iter().max().unwrap() - *basis.iter().min().unwrap()) as f64;
                metrics::global().observe(&format!("mux.{}.delta_ms", ctx.name), delta / 1e6);
            }
            let total: usize = parts.iter().map(|b| b.len()).sum();
            let mut payload = Vec::with_capacity(total);
            for p in &parts {
                payload.extend_from_slice(&p.data);
            }
            let mut out = Buffer::new(payload);
            out.pts = parts[0].pts;
            out.duration = parts[0].duration;
            ctx.push_buffer(out)?;
        }
        Ok(())
    }
}

impl Element for TensorMux {
    // Workload::Compute (default): pure aggregation, pool-schedulable.

    fn n_sink_pads(&self) -> usize {
        self.n_pads
    }

    fn ensure_sink_pads(&mut self, n: usize) -> bool {
        while self.n_pads < n {
            self.n_pads += 1;
            self.caps.push(None);
            self.queues.push(VecDeque::new());
        }
        true
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let info = c.tensors_info().map_err(|e| Error::element(&ctx.name, e))?;
                self.caps[pad] = Some(info);
                self.try_emit(ctx)
            }
            Item::Buffer(b) => {
                self.queues[pad].push_back(b);
                // Bound memory if one input stalls: keep the freshest 32.
                if self.queues[pad].len() > 32 {
                    self.queues[pad].pop_front();
                    metrics::global().counter(&format!("mux.{}.dropped", ctx.name)).inc();
                }
                self.try_emit(ctx)
            }
            Item::Eos => Ok(()),
        }
    }
}

/// Split a static multi-tensor frame: tensor i → src pad i.
pub struct TensorDemux {
    n_src: usize,
    info: Option<TensorsInfo>,
}

impl TensorDemux {
    pub fn new(n_src: usize) -> Self {
        Self { n_src: n_src.max(1), info: None }
    }
}

impl Element for TensorDemux {
    fn n_src_pads(&self) -> usize {
        self.n_src
    }

    fn ensure_src_pads(&mut self, n: usize) -> bool {
        self.n_src = self.n_src.max(n);
        true
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let info = c.tensors_info().map_err(|e| Error::element(&ctx.name, e))?;
                for (i, t) in info.tensors.iter().enumerate().take(self.n_src) {
                    ctx.push(i, Item::Caps(Caps::tensors(&TensorsInfo::one(t.clone()))))?;
                }
                self.info = Some(info);
                Ok(())
            }
            Item::Buffer(b) => {
                let info = self
                    .info
                    .as_ref()
                    .ok_or_else(|| Error::element(&ctx.name, "buffer before caps"))?;
                if b.len() != info.frame_size() {
                    return Err(Error::element(
                        &ctx.name,
                        format!("frame {} != caps size {}", b.len(), info.frame_size()),
                    ));
                }
                let mut off = 0;
                for (i, t) in info.tensors.iter().enumerate() {
                    // Slice views into the combined frame — demux fan-out
                    // shares the parent allocation, no per-tensor copy.
                    let part = b.data.slice(off..off + t.size());
                    off += t.size();
                    if i < self.n_src {
                        ctx.push(i, Item::Buffer(b.map_payload(part)))?;
                    }
                }
                Ok(())
            }
            Item::Eos => Ok(()),
        }
    }
}

/// Comparison operator of `tensor_if`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IfOp {
    Gt,
    Lt,
    Ge,
    Le,
    Eq,
}

impl IfOp {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gt" | ">" => IfOp::Gt,
            "lt" | "<" => IfOp::Lt,
            "ge" | ">=" => IfOp::Ge,
            "le" | "<=" => IfOp::Le,
            "eq" | "==" => IfOp::Eq,
            other => return Err(Error::Parse(format!("unknown operator `{other}`"))),
        })
    }

    fn eval(self, v: f32, threshold: f32) -> bool {
        match self {
            IfOp::Gt => v > threshold,
            IfOp::Lt => v < threshold,
            IfOp::Ge => v >= threshold,
            IfOp::Le => v <= threshold,
            IfOp::Eq => (v - threshold).abs() < f32::EPSILON,
        }
    }
}

/// Route buffers by a scalar condition on one f32 element of the frame:
/// src pad 0 = condition true ("then"), src pad 1 = false ("else";
/// dropped when unlinked). The Fig 5 DETECT gate.
pub struct TensorIf {
    pub value_index: usize,
    pub op: IfOp,
    pub threshold: f32,
}

impl TensorIf {
    pub fn new(value_index: usize, op: IfOp, threshold: f32) -> Self {
        Self { value_index, op, threshold }
    }
}

impl Element for TensorIf {
    fn n_src_pads(&self) -> usize {
        2
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                ctx.push(0, Item::Caps(c.clone()))?;
                ctx.push(1, Item::Caps(c))?;
                Ok(())
            }
            Item::Buffer(b) => {
                let off = self.value_index * 4;
                if b.len() < off + 4 {
                    return Err(Error::element(
                        &ctx.name,
                        format!("frame {} bytes, need f32 at {off}", b.len()),
                    ));
                }
                let v = f32::from_le_bytes([b.data[off], b.data[off + 1], b.data[off + 2], b.data[off + 3]]);
                let pad = if self.op.eval(v, self.threshold) { 0 } else { 1 };
                metrics::global()
                    .counter(&format!("tensor_if.{}.{}", ctx.name, if pad == 0 { "then" } else { "else" }))
                    .inc();
                ctx.push(pad, Item::Buffer(b))
            }
            Item::Eos => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo};
    use std::time::Duration;

    fn f32_buf(vals: &[f32]) -> Buffer {
        Buffer::new(crate::tensor::f32_to_bytes(vals))
    }

    #[test]
    fn mux_combines_two_streams() {
        let mut p = Pipeline::new();
        let ia = TensorsInfo::one(TensorInfo::new(DType::U8, &[2]).unwrap());
        let ib = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        let (sa, ha) = AppSrc::new(4, Some(Caps::tensors(&ia)));
        let (sb, hb) = AppSrc::new(4, Some(Caps::tensors(&ib)));
        let (sink, rx) = AppSink::new(4);
        let a = p.add("a", Box::new(sa)).unwrap();
        let b = p.add("b", Box::new(sb)).unwrap();
        let m = p.add("mux", Box::new(TensorMux::new(2))).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link_pads(a, 0, m, 0).unwrap();
        p.link_pads(b, 0, m, 1).unwrap();
        p.link(m, k).unwrap();
        let _r = p.start().unwrap();
        ha.push(Buffer::new(vec![1, 2]).with_pts(100)).unwrap();
        hb.push(Buffer::new(vec![3, 4, 5]).with_pts(200)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(&out.data[..], &[1, 2, 3, 4, 5]);
        assert_eq!(out.pts, Some(100)); // basepad pts
    }

    #[test]
    fn mux_records_timestamp_delta() {
        metrics::global().reset();
        let mut p = Pipeline::new();
        let ia = TensorsInfo::one(TensorInfo::new(DType::U8, &[1]).unwrap());
        let (sa, ha) = AppSrc::new(4, Some(Caps::tensors(&ia)));
        let (sb, hb) = AppSrc::new(4, Some(Caps::tensors(&ia)));
        let (sink, _rx) = AppSink::new(4);
        let a = p.add("a", Box::new(sa)).unwrap();
        let b = p.add("b", Box::new(sb)).unwrap();
        let m = p.add("m0", Box::new(TensorMux::new(2))).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link_pads(a, 0, m, 0).unwrap();
        p.link_pads(b, 0, m, 1).unwrap();
        p.link(m, k).unwrap();
        let _r = p.start().unwrap();
        ha.push(Buffer::new(vec![1]).with_pts(0)).unwrap();
        hb.push(Buffer::new(vec![2]).with_pts(5_000_000)).unwrap(); // +5ms
        std::thread::sleep(Duration::from_millis(200));
        let s = metrics::global().summary("mux.m0.delta_ms").unwrap();
        assert!((s.max - 5.0).abs() < 0.5, "delta {s:?}");
    }

    #[test]
    fn demux_splits_tensors() {
        let mut p = Pipeline::new();
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::U8, &[2]).unwrap()).unwrap();
        info.push(TensorInfo::new(DType::U8, &[3]).unwrap()).unwrap();
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (k0, r0) = AppSink::new(4);
        let (k1, r1) = AppSink::new(4);
        let s = p.add("s", Box::new(src)).unwrap();
        let d = p.add("d", Box::new(TensorDemux::new(2))).unwrap();
        let a = p.add("k0", Box::new(k0)).unwrap();
        let b = p.add("k1", Box::new(k1)).unwrap();
        p.link(s, d).unwrap();
        p.link_pads(d, 0, a, 0).unwrap();
        p.link_pads(d, 1, b, 0).unwrap();
        let _r = p.start().unwrap();
        h.push(Buffer::new(vec![1, 2, 3, 4, 5])).unwrap();
        assert_eq!(&r0.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[1, 2]);
        assert_eq!(&r1.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[3, 4, 5]);
    }

    #[test]
    fn tensor_if_routes_by_threshold() {
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::F32, &[1]).unwrap());
        let (src, h) = AppSrc::new(8, Some(Caps::tensors(&info)));
        let (kt, rt) = AppSink::new(8);
        let (ke, re) = AppSink::new(8);
        let s = p.add("s", Box::new(src)).unwrap();
        let i = p.add("if", Box::new(TensorIf::new(0, IfOp::Gt, 0.5))).unwrap();
        let a = p.add("then", Box::new(kt)).unwrap();
        let b = p.add("else", Box::new(ke)).unwrap();
        p.link(s, i).unwrap();
        p.link_pads(i, 0, a, 0).unwrap();
        p.link_pads(i, 1, b, 0).unwrap();
        let _r = p.start().unwrap();
        h.push(f32_buf(&[0.9])).unwrap();
        h.push(f32_buf(&[0.1])).unwrap();
        h.push(f32_buf(&[0.7])).unwrap();
        assert!(rt.recv_timeout(Duration::from_secs(2)).is_ok());
        assert!(re.recv_timeout(Duration::from_secs(2)).is_ok());
        assert!(rt.recv_timeout(Duration::from_secs(2)).is_ok());
    }

    #[test]
    fn if_op_eval_table() {
        assert!(IfOp::Gt.eval(1.0, 0.5));
        assert!(!IfOp::Gt.eval(0.5, 0.5));
        assert!(IfOp::Ge.eval(0.5, 0.5));
        assert!(IfOp::Lt.eval(0.1, 0.5));
        assert!(IfOp::Le.eval(0.5, 0.5));
        assert!(IfOp::Eq.eval(0.5, 0.5));
        assert!(IfOp::parse("gt").is_ok());
        assert!(IfOp::parse("!!").is_err());
    }
}
