//! Query elements — inference workload offloading (§4.2.2, Fig 2):
//! `tensor_query_client`, `tensor_query_serversrc`, `tensor_query_serversink`.
//!
//! In a client pipeline, `tensor_query_client` is a drop-in replacement
//! for `tensor_filter`: it ships each input frame to a server pipeline
//! and emits the inference result downstream. Two transports:
//!
//! - **tcp** (TCP-raw): direct `host:port`, no discovery (fast, rigid).
//! - **mqtt-hybrid**: discovery + liveness via the MQTT broker
//!   (`edge/query/<operation>/#` retained ads + last-will), DATA over a
//!   direct TCP connection — "rich features of MQTT without broker
//!   throughput overheads". Automatic failover to another compatible
//!   server on death (R4).
//!
//! Server side: `serversrc` accepts connections, tags each request buffer
//! with a client id; `serversink` routes responses back by that tag; the
//! two rendezvous in-process via the operation name (`pair-id` to
//! disambiguate multiple servers in one process).

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::coordinator::discovery::{self, AdWatcher, ServiceAd};
use crate::coordinator::health::{self, BreakerConfig, HealthMap};
use crate::element::{Ctx, Element, Item, Workload};
use crate::metrics;
use crate::mqtt::MqttClient;
use crate::serial::wire::{self, LinkCodec, LinkDecoder, WireFrame};
use crate::serial::Codec;
use crate::util::rng::XorShift64;
use crate::util::{write_all_vectored, Error, Result};
use crate::{log_debug, log_info, log_warn};

/// Shared table of live client connections (write halves), keyed by the
/// server-assigned client id.
#[derive(Default)]
pub struct ConnTable {
    conns: Mutex<HashMap<u64, TcpStream>>,
}

impl ConnTable {
    fn insert(&self, id: u64, stream: TcpStream) {
        self.conns.lock().unwrap().insert(id, stream);
    }

    fn remove(&self, id: u64) {
        self.conns.lock().unwrap().remove(&id);
    }

    fn write_frame(&self, id: u64, frame: &WireFrame) -> Result<()> {
        let mut conns = self.conns.lock().unwrap();
        let Some(stream) = conns.get_mut(&id) else {
            return Err(Error::Transport(format!("query client {id} is gone")));
        };
        // Length prefix + frame header + shared payload in one vectored
        // write — the response payload is never assembled or copied.
        let len = (frame.len() as u32).to_le_bytes();
        let r = write_all_vectored(
            stream,
            &[&len[..], frame.header.as_slice(), frame.payload.as_slice()],
        );
        if r.is_err() {
            conns.remove(&id);
        }
        r.map_err(|e| Error::Transport(format!("response to client {id}: {e}")))
    }

    pub fn len(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// Ids of the currently-connected clients (codec-state pruning).
    fn ids(&self) -> Vec<u64> {
        self.conns.lock().unwrap().keys().copied().collect()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn tables() -> &'static Mutex<HashMap<String, Arc<ConnTable>>> {
    static T: OnceLock<Mutex<HashMap<String, Arc<ConnTable>>>> = OnceLock::new();
    T.get_or_init(|| Mutex::new(HashMap::new()))
}

fn table_for(pair_id: &str) -> Arc<ConnTable> {
    tables().lock().unwrap().entry(pair_id.to_string()).or_default().clone()
}

/// Transport protocol of the query elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryProtocol {
    TcpRaw,
    MqttHybrid,
}

impl QueryProtocol {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "tcp" | "tcp-raw" => QueryProtocol::TcpRaw,
            "mqtt-hybrid" | "hybrid" | "mqtt" => QueryProtocol::MqttHybrid,
            other => return Err(Error::Parse(format!("unknown query protocol `{other}`"))),
        })
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Accepts query connections and feeds request buffers into the server
/// pipeline, tagged with the client id.
pub struct QueryServerSrc {
    pub operation: String,
    pub pair_id: String,
    pub bind: String,
    pub protocol: QueryProtocol,
    pub broker: String,
    pub server_id: String,
    pub model_label: String,
    pub advertised_load: f64,
    rx: Option<Receiver<(Option<Caps>, Buffer)>>,
    mqtt: Option<MqttClient>,
    ad: Option<ServiceAd>,
    port: u16,
    shutdown: Option<Arc<AtomicBool>>,
    last_caps: Option<Caps>,
}

impl QueryServerSrc {
    pub fn new(operation: &str) -> Self {
        Self {
            operation: operation.to_string(),
            pair_id: operation.to_string(),
            bind: "127.0.0.1:0".to_string(),
            protocol: QueryProtocol::TcpRaw,
            broker: String::new(),
            server_id: format!("srv-{}-{}", std::process::id(), next_server_seq()),
            model_label: "model".to_string(),
            advertised_load: 0.0,
            rx: None,
            mqtt: None,
            ad: None,
            port: 0,
            shutdown: None,
            last_caps: None,
        }
    }

    pub fn with_bind(mut self, bind: &str) -> Self {
        self.bind = bind.to_string();
        self
    }

    pub fn with_pair_id(mut self, id: &str) -> Self {
        self.pair_id = id.to_string();
        self
    }

    pub fn with_hybrid(mut self, broker: &str) -> Self {
        self.protocol = QueryProtocol::MqttHybrid;
        self.broker = broker.to_string();
        self
    }

    pub fn with_server_id(mut self, id: &str) -> Self {
        self.server_id = id.to_string();
        self
    }

    pub fn with_model_label(mut self, m: &str) -> Self {
        self.model_label = m.to_string();
        self
    }

    /// Load figure advertised in the discovery ad (`load=` property).
    /// Clients rank peers by it; useful for steering selection in tests
    /// and benches, and for operators that know a device is busy.
    pub fn with_advertised_load(mut self, load: f64) -> Self {
        self.advertised_load = load.clamp(0.0, 1.0);
        self
    }

    /// Port actually bound (after start).
    pub fn port(&self) -> u16 {
        self.port
    }
}

fn next_server_seq() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    SEQ.fetch_add(1, Ordering::Relaxed)
}

impl Element for QueryServerSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    /// Socket-bound (request channel receive, MQTT advertisement): keep
    /// a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }

    fn start(&mut self, ctx: &mut Ctx) -> Result<()> {
        discovery::validate_operation(&self.operation)?;
        let listener = TcpListener::bind(&self.bind)
            .map_err(|e| Error::Transport(format!("query bind {}: {e}", self.bind)))?;
        self.port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let table = table_for(&self.pair_id);
        let (tx, rx) = sync_channel::<(Option<Caps>, Buffer)>(64);
        self.rx = Some(rx);
        let shutdown = Arc::new(AtomicBool::new(false));
        self.shutdown = Some(shutdown.clone());

        let name = ctx.name.clone();
        let link = format!("queryserversrc.{}", self.pair_id);
        std::thread::Builder::new()
            .name(format!("query-accept-{}", self.operation))
            .spawn(move || {
                let next_client = AtomicU64::new(1);
                while !shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            stream.set_nodelay(true).ok();
                            let id = next_client.fetch_add(1, Ordering::Relaxed);
                            log_debug!("query", "{name}: client {id} from {peer}");
                            let Ok(wstream) = stream.try_clone() else { continue };
                            table.insert(id, wstream);
                            spawn_client_reader(id, link.clone(), stream, table.clone(), tx.clone());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(|e| Error::Transport(format!("spawn accept: {e}")))?;

        if self.protocol == QueryProtocol::MqttHybrid {
            let ad = ServiceAd {
                operation: self.operation.clone(),
                server_id: self.server_id.clone(),
                host: "127.0.0.1".to_string(),
                port: self.port,
                model: self.model_label.clone(),
                load: self.advertised_load,
            };
            let client =
                MqttClient::connect(&self.broker, discovery::server_client_options(&self.server_id, &ad))?;
            discovery::advertise(&client, &ad)?;
            log_info!("query", "{}: advertised `{}` on {}", ctx.name, ad.topic(), self.broker);
            self.mqtt = Some(client);
            self.ad = Some(ad);
        }
        Ok(())
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        let Some(rx) = &self.rx else { return Ok(false) };
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok((caps, buf)) => {
                if let Some(c) = caps {
                    if self.last_caps.as_ref() != Some(&c) {
                        ctx.push_caps(c.clone())?;
                        self.last_caps = Some(c);
                    }
                }
                metrics::global().counter(&format!("queryserver.{}", ctx.name)).add_bytes(buf.len() as u64);
                ctx.push_buffer(buf)?;
                Ok(true)
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(!ctx.stopped()),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(false),
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        if let Some(s) = &self.shutdown {
            s.store(true, Ordering::Relaxed);
        }
        if let (Some(client), Some(ad)) = (&self.mqtt, &self.ad) {
            let _ = discovery::clear_advertisement(client, ad);
            client.disconnect();
        }
    }
}

fn spawn_client_reader(
    id: u64,
    link: String,
    mut stream: TcpStream,
    table: Arc<ConnTable>,
    tx: SyncSender<(Option<Caps>, Buffer)>,
) {
    std::thread::Builder::new()
        .name(format!("query-client-{id}"))
        .spawn(move || {
            // Per-connection decode state: delta-coded request streams
            // re-key on reconnect (the client resets its chain), so a
            // fresh decoder per connection is exactly right.
            let mut decoder = LinkDecoder::new(&link);
            loop {
                let frame = match wire::read_frame(&mut stream) {
                    Ok(f) => f,
                    Err(_) => break,
                };
                // One allocation per request: the decoded buffer is a
                // slice view into the received frame. A mid-chain delta
                // after a broken chain decodes to None and is skipped.
                let decoded = match decoder.decode(&frame) {
                    Ok(d) => d,
                    Err(_) => break,
                };
                let Some((mut buf, caps)) = decoded else { continue };
                buf.meta.client_id = Some(id);
                if tx.send((caps, buf)).is_err() {
                    break;
                }
            }
            table.remove(id);
            log_debug!("query", "client {id} disconnected");
        })
        .expect("spawn query reader");
}

/// Routes response buffers back to the tagged client connection.
///
/// One sink serves every connected client, but the stateful codecs
/// (`Delta`, `Auto`) track per-receiver history — so the sink keeps one
/// [`LinkCodec`] per client id, created on first response and pruned
/// when the client's connection is gone.
pub struct QueryServerSink {
    pub pair_id: String,
    table: Option<Arc<ConnTable>>,
    caps: Option<Caps>,
    codec: Codec,
    keyframe_interval: u64,
    links: HashMap<u64, LinkCodec>,
}

impl QueryServerSink {
    pub fn new(pair_id: &str) -> Self {
        Self {
            pair_id: pair_id.to_string(),
            table: None,
            caps: None,
            codec: Codec::None,
            keyframe_interval: wire::DEFAULT_KEYFRAME_INTERVAL,
            links: HashMap::new(),
        }
    }

    /// Codec for response frames (`Codec::Auto` adapts per link, sampling
    /// into `codec.auto.queryserver.<pair_id>.*`; `Delta`/`Auto` count
    /// keyframes/deltas into `codec.delta.queryserver.<pair_id>.*`,
    /// aggregated across clients).
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self.links.clear();
        self
    }

    /// Frames per delta-chain keyframe period (`Codec::Delta`/`Auto`).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.keyframe_interval = interval.max(1);
        self
    }
}

impl Element for QueryServerSink {
    fn n_src_pads(&self) -> usize {
        0
    }

    /// Socket-bound (response writes to client connections): keep a
    /// thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        self.table = Some(table_for(&self.pair_id));
        Ok(())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                self.caps = Some(c);
                Ok(())
            }
            Item::Buffer(b) => {
                let table = self
                    .table
                    .clone()
                    .ok_or_else(|| Error::element(&ctx.name, "not started"))?;
                let Some(id) = b.meta.client_id else {
                    return Err(Error::element(&ctx.name, "response buffer without client id"));
                };
                let frame = {
                    let (codec, scope, interval) =
                        (self.codec, &self.pair_id, self.keyframe_interval);
                    let link = self.links.entry(id).or_insert_with(|| {
                        LinkCodec::new(codec, &format!("queryserver.{scope}"))
                            .with_keyframe_interval(interval)
                    });
                    link.encode(&b, self.caps.as_ref())
                        .map_err(|e| Error::element(&ctx.name, e))?
                };
                // A vanished client is not a pipeline error (R4: clients
                // come and go); drop the response and its codec state.
                if let Err(e) = table.write_frame(id, &frame) {
                    self.links.remove(&id);
                    log_debug!("query", "{}: {e}", ctx.name);
                }
                // Codec state for clients that disconnected without a
                // failed write must not accumulate.
                if self.links.len() > 2 * table.len().max(4) {
                    let live = table.ids();
                    self.links.retain(|cid, _| live.contains(cid));
                }
                Ok(())
            }
            Item::Eos => Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

enum Endpoint {
    Fixed(String),
    Discovered { watcher: AdWatcher, current: Option<ServiceAd> },
}

/// Resilience policy of a [`QueryClient`] (see the README's "Resilient
/// elastic offload" section; all knobs are parseable element properties).
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Attempts per frame (`retry=`, includes the first try; min 1).
    pub retry: u32,
    /// Base retry backoff (`backoff-ms=`); doubles per attempt with
    /// ±50% jitter, capped at `backoff_max`.
    pub backoff: Duration,
    pub backoff_max: Duration,
    /// Per-frame budget (`deadline-ms=`). When set, a frame whose budget
    /// is spent is DROPPED (leaky semantics — the pipeline keeps flowing);
    /// when unset, exhausted retries error the pipeline (strict).
    pub deadline: Option<Duration>,
    /// Hedge percentile as a 0..=1 fraction (`hedge-pct=`; 0.95 → p95):
    /// duplicate a request to the second-best peer once it has been
    /// outstanding longer than this percentile of the primary's observed
    /// RTTs; first answer wins. `None` disables hedging.
    pub hedge_pct: Option<f64>,
    /// Advertised-load threshold (`reroute-load=`) above which the client
    /// re-routes mid-stream to a meaningfully better peer.
    pub reroute_load: f64,
    /// Circuit-breaker knobs (shared per operation via
    /// [`health::shared`]).
    pub breaker: BreakerConfig,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            retry: 3,
            backoff: Duration::from_millis(50),
            backoff_max: Duration::from_secs(1),
            deadline: None,
            hedge_pct: None,
            reroute_load: 0.9,
            breaker: BreakerConfig::default(),
        }
    }
}

/// Minimum score improvement another peer must offer before a loaded
/// current peer is abandoned mid-stream (anti-flap margin).
const REROUTE_MARGIN: f64 = 0.1;

fn jitter_seed() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    (std::process::id() as u64) << 32 | SEQ.fetch_add(1, Ordering::Relaxed)
}

/// Drop-in `tensor_filter` replacement that offloads inference.
pub struct QueryClient {
    pub operation: String,
    pub timeout: Duration,
    endpoint: Endpoint,
    conn: Option<TcpStream>,
    in_caps: Option<Caps>,
    out_caps: Option<Caps>,
    seq: u64,
    link: LinkCodec,
    cfg: ResilienceConfig,
    /// Shared per-operation peer health; lazily created so builder order
    /// (`with_resilience` after construction) can't lose the config.
    health: Option<Arc<HealthMap>>,
    /// Peer we most recently failed on (demoted, not blacklisted).
    last_failed: Option<String>,
    /// Cached connection to the last hedge target, with its response
    /// decode state (delta chains are per-connection).
    hedge_conn: Option<(String, TcpStream, LinkDecoder)>,
    /// Response decode state for the primary connection; replaced on
    /// every (re)connect.
    resp_dec: LinkDecoder,
    rng: XorShift64,
}

impl QueryClient {
    /// TCP-raw transport to a fixed server address.
    pub fn tcp(operation: &str, server: &str) -> Self {
        Self {
            operation: operation.to_string(),
            timeout: Duration::from_secs(5),
            endpoint: Endpoint::Fixed(server.to_string()),
            conn: None,
            in_caps: None,
            out_caps: None,
            seq: 0,
            link: LinkCodec::new(Codec::None, ""),
            cfg: ResilienceConfig::default(),
            health: None,
            last_failed: None,
            hedge_conn: None,
            resp_dec: LinkDecoder::new(&format!("query.{operation}")),
            rng: XorShift64::new(jitter_seed()),
        }
    }

    /// MQTT-hybrid transport: discover servers for `operation` via broker.
    pub fn hybrid(operation: &str, broker: &str) -> Result<Self> {
        let watcher = AdWatcher::watch(broker, operation)?;
        Ok(Self {
            operation: operation.to_string(),
            timeout: Duration::from_secs(5),
            endpoint: Endpoint::Discovered { watcher, current: None },
            conn: None,
            in_caps: None,
            out_caps: None,
            seq: 0,
            link: LinkCodec::new(Codec::None, ""),
            cfg: ResilienceConfig::default(),
            health: None,
            last_failed: None,
            hedge_conn: None,
            resp_dec: LinkDecoder::new(&format!("query.{operation}")),
            rng: XorShift64::new(jitter_seed()),
        })
    }

    pub fn with_timeout(mut self, t: Duration) -> Self {
        self.timeout = t;
        self
    }

    /// Codec for request frames (`Codec::Auto` adapts per link, sampling
    /// into `codec.auto.query.<operation>.*`; `Delta`/`Auto` count
    /// keyframes/deltas into `codec.delta.query.<operation>.*`). The
    /// server decodes via the wire flag, so no server-side configuration
    /// is needed.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        let interval = self.link.keyframe_interval();
        self.link = LinkCodec::new(codec, &format!("query.{}", self.operation))
            .with_keyframe_interval(interval);
        self
    }

    /// Frames per delta-chain keyframe period (`Codec::Delta`/`Auto`).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.link.set_keyframe_interval(interval);
        self
    }

    /// Retry/backoff/deadline/hedge/breaker policy.
    pub fn with_resilience(mut self, cfg: ResilienceConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Inject a specific health map (tests/benches); by default the
    /// process-shared per-operation map is used.
    pub fn with_health(mut self, h: Arc<HealthMap>) -> Self {
        self.health = Some(h);
        self
    }

    fn health(&mut self) -> Arc<HealthMap> {
        if self.health.is_none() {
            self.health = Some(health::shared(&self.operation, self.cfg.breaker));
        }
        self.health.as_ref().unwrap().clone()
    }

    /// Health key of the currently-targeted peer: `server_id` for
    /// discovered peers, the address for fixed endpoints.
    fn peer_key(&self) -> String {
        match &self.endpoint {
            Endpoint::Fixed(a) => a.clone(),
            Endpoint::Discovered { current, .. } => {
                current.as_ref().map(|ad| ad.server_id.clone()).unwrap_or_default()
            }
        }
    }

    fn counter(name: &str, which: &str) -> Arc<metrics::Counter> {
        metrics::global().counter(&format!("query.{name}.{which}"))
    }

    /// Exponential backoff with ±50% jitter, capped.
    fn backoff_delay(&mut self, attempt: u32) -> Duration {
        let exp = attempt.saturating_sub(1).min(10);
        let base = self.cfg.backoff_max.min(self.cfg.backoff.saturating_mul(1u32 << exp));
        base.mul_f64(0.5 + self.rng.f32() as f64)
    }

    /// Remaining per-attempt read/connect budget: the configured timeout,
    /// clipped by what is left of the frame deadline.
    fn attempt_budget(&self, deadline: Option<Instant>) -> Result<Duration> {
        let mut budget = self.timeout;
        if let Some(dl) = deadline {
            let left = dl.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Transport("frame deadline exhausted".into()));
            }
            budget = budget.min(left);
        }
        Ok(budget.max(Duration::from_millis(1)))
    }

    /// Record a failure against the current peer, open-count the breaker
    /// metric, tear the connection down, and demote the peer for the next
    /// selection.
    fn fail_current(&mut self, name: &str) {
        let key = self.peer_key();
        self.conn = None;
        if key.is_empty() {
            return;
        }
        if self.health().record_failure(&key) {
            Self::counter(name, "breaker_open").inc();
            log_warn!("query", "{name}: breaker OPEN for `{key}`");
        }
        self.last_failed = Some(key.clone());
        if let Endpoint::Discovered { current, .. } = &mut self.endpoint {
            if let Some(ad) = current.take() {
                log_warn!("query", "{name}: server `{}` failed; failing over", ad.server_id);
            }
        }
    }

    /// Health-aware (re)connect. Discovered endpoints rank live ads by
    /// advertised load + observed health, gated by each peer's breaker;
    /// fixed endpoints respect their own breaker.
    fn connect(&mut self, deadline: Option<Instant>, name: &str) -> Result<()> {
        let budget = self.attempt_budget(deadline)?;
        let health = self.health();
        let addr = match &mut self.endpoint {
            Endpoint::Fixed(a) => {
                if !health.allow(a) {
                    return Err(Error::Transport(format!("breaker open for {a}")));
                }
                a.clone()
            }
            Endpoint::Discovered { watcher, current } => {
                let avoid = self.last_failed.clone();
                let wait_until = Instant::now()
                    + deadline
                        .map(|dl| dl.saturating_duration_since(Instant::now()))
                        .unwrap_or(Duration::from_secs(3))
                        .min(Duration::from_secs(3));
                let ad = loop {
                    if let Some(ad) = health.select(&watcher.entries(), avoid.as_deref()) {
                        break ad;
                    }
                    if Instant::now() >= wait_until {
                        return Err(Error::Transport(format!(
                            "no selectable servers for operation `{}`",
                            self.operation
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                };
                log_info!("query", "{name}: using server `{}` at {}", ad.server_id, ad.endpoint());
                let ep = ad.endpoint();
                *current = Some(ad);
                ep
            }
        };
        let stream = connect_within(&addr, budget).map_err(|e| {
            self.fail_current(name);
            Error::Transport(format!("query connect {addr}: {e}"))
        })?;
        stream.set_nodelay(true).ok();
        self.conn = Some(stream);
        // Fresh connection, fresh codec state on BOTH directions: the
        // server allocates a new per-connection decoder (so our next
        // delta-codec request must re-key) and a new per-client response
        // chain (so our response decoder must forget the old one).
        self.link.reset_chain();
        self.resp_dec = LinkDecoder::new(&format!("query.{}", self.operation));
        Ok(())
    }

    /// Mid-stream re-route check: abandon the current (healthy, connected)
    /// peer when its ad vanished, its breaker opened, or its advertised
    /// load crossed `reroute_load` while a meaningfully better peer is
    /// available.
    fn maybe_reroute(&mut self, name: &str) {
        if self.conn.is_none() {
            return;
        }
        let health = self.health();
        let reroute_load = self.cfg.reroute_load;
        let (reroute, why) = {
            let Endpoint::Discovered { watcher, current } = &self.endpoint else { return };
            let Some(cur) = current else { return };
            let entries = watcher.entries();
            health.note_ads(&entries);
            match entries.iter().find(|(ad, _)| ad.server_id == cur.server_id) {
                None => (true, "ad vanished"),
                Some((ad, _)) => {
                    if !health.would_allow(&ad.server_id) {
                        (true, "breaker open")
                    } else if ad.load >= reroute_load
                        && entries.iter().any(|(o, _)| {
                            o.server_id != ad.server_id
                                && health.would_allow(&o.server_id)
                                && health.score(o) + REROUTE_MARGIN < health.score(ad)
                        })
                    {
                        (true, "load threshold")
                    } else {
                        (false, "")
                    }
                }
            }
        };
        if reroute {
            if let Endpoint::Discovered { current, .. } = &mut self.endpoint {
                if let Some(ad) = current.take() {
                    log_info!("query", "{name}: re-routing away from `{}` ({why})", ad.server_id);
                }
            }
            if let Some(c) = self.conn.take() {
                let _ = c.shutdown(std::net::Shutdown::Both);
            }
            Self::counter(name, "reroutes").inc();
        }
    }

    /// Best allowed hedge target: ranked like selection, excluding the
    /// primary, without consuming a probe (the hedge send is speculative).
    fn hedge_target(&mut self, primary: &str) -> Option<ServiceAd> {
        let health = self.health();
        let Endpoint::Discovered { watcher, .. } = &self.endpoint else { return None };
        let entries = watcher.entries();
        let mut ranked: Vec<&ServiceAd> = entries
            .iter()
            .map(|(ad, _)| ad)
            .filter(|ad| ad.server_id != primary && health.would_allow(&ad.server_id))
            .collect();
        ranked.sort_by(|a, b| {
            health.score(a).total_cmp(&health.score(b)).then_with(|| a.server_id.cmp(&b.server_id))
        });
        ranked.first().map(|ad| (*ad).clone())
    }

    /// One attempt at one frame: reroute check, (re)connect, then a plain
    /// or hedged exchange within the attempt budget.
    fn attempt(
        &mut self,
        b: &Buffer,
        seq: u64,
        deadline: Option<Instant>,
        name: &str,
    ) -> Result<(Buffer, Option<Caps>)> {
        self.maybe_reroute(name);
        if self.conn.is_none() {
            self.connect(deadline, name)?;
        }
        let budget = self.attempt_budget(deadline)?;
        let mut req = b.clone();
        req.meta.seq = Some(seq);
        let frame = self.link.encode(&req, self.in_caps.as_ref())?;

        // A mid-chain delta request only makes sense to the connection
        // whose chain it extends; duplicating it to a second server
        // would just be dropped there. Keyframes (and every stateless
        // codec) hedge fine.
        let hedgeable = frame.header[6] != Codec::Delta as u8
            || frame.header[5] & wire::FLAG_KEYFRAME != 0;
        if let Some(pct) = self.cfg.hedge_pct {
            if hedgeable {
                let primary = self.peer_key();
                let hedge_after = self
                    .health()
                    .rtt_percentile(&primary, pct)
                    .map(|us| Duration::from_micros(us as u64).max(Duration::from_millis(1)));
                if let Some(delay) = hedge_after {
                    if delay < budget {
                        if let Some(target) = self.hedge_target(&primary) {
                            return self.exchange_hedged(&frame, seq, budget, delay, target, name);
                        }
                    }
                }
            }
        }
        self.exchange_plain(&frame, seq, budget, name)
    }

    /// Plain request/response on the current connection.
    fn exchange_plain(
        &mut self,
        frame: &WireFrame,
        seq: u64,
        budget: Duration,
        name: &str,
    ) -> Result<(Buffer, Option<Caps>)> {
        let key = self.peer_key();
        let health = self.health();
        let stream = self.conn.as_mut().unwrap();
        stream.set_read_timeout(Some(budget))?;
        let t0 = Instant::now();
        let r = wire::write_frame_vectored(stream, frame)
            .and_then(|_| read_response(stream, seq, &mut self.resp_dec));
        match r {
            Ok(rc) => {
                health.record_success(&key, t0.elapsed().as_micros() as f64);
                Ok(rc)
            }
            Err(e) => {
                self.fail_current(name);
                Err(e)
            }
        }
    }

    /// Hedged exchange: the primary request runs on its own thread; if no
    /// answer lands within `delay`, the same frame is duplicated to
    /// `target` (second-best peer) and the first answer wins. The loser's
    /// socket is shut down (cancellation) so a stale response can never be
    /// mistaken for a later frame's.
    fn exchange_hedged(
        &mut self,
        frame: &WireFrame,
        seq: u64,
        budget: Duration,
        delay: Duration,
        target: ServiceAd,
        name: &str,
    ) -> Result<(Buffer, Option<Caps>)> {
        type Verdict =
            (bool, Result<(Buffer, Option<Caps>)>, f64, Option<(TcpStream, LinkDecoder)>);
        let health = self.health();
        let primary_key = self.peer_key();
        let end = Instant::now() + budget;

        let mut pstream = self.conn.take().unwrap();
        pstream.set_read_timeout(Some(budget))?;
        let pcancel = pstream.try_clone().ok();
        // The racer owns the connection's decode state for the duration
        // and hands it back with the stream if it wins.
        let mut pdec = std::mem::replace(
            &mut self.resp_dec,
            LinkDecoder::new(&format!("query.{}", self.operation)),
        );
        let (tx, rx) = std::sync::mpsc::channel::<Verdict>();
        let ptx = tx.clone();
        let pframe = frame.clone();
        std::thread::Builder::new()
            .name("query-hedge-pri".into())
            .spawn(move || {
                let t0 = Instant::now();
                let r = wire::write_frame_vectored(&mut pstream, &pframe)
                    .and_then(|_| read_response(&mut pstream, seq, &mut pdec));
                let _ =
                    ptx.send((true, r, t0.elapsed().as_micros() as f64, Some((pstream, pdec))));
            })
            .map_err(|e| Error::Transport(format!("spawn hedge: {e}")))?;

        // Fast path: primary answers before the hedge trigger.
        match rx.recv_timeout(delay) {
            Ok((_, Ok(rc), rtt, conn)) => {
                if let Some((stream, dec)) = conn {
                    self.conn = Some(stream);
                    self.resp_dec = dec;
                }
                health.record_success(&primary_key, rtt);
                return Ok(rc);
            }
            Ok((_, Err(e), _, _)) => {
                // Primary failed outright before the hedge even fired;
                // let the outer retry loop handle re-selection.
                self.fail_current(name);
                return Err(e);
            }
            Err(_) => {} // still outstanding -> hedge
        }

        Self::counter(name, "hedges").inc();
        let hedge_budget = end.saturating_duration_since(Instant::now()).max(Duration::from_millis(1));
        let hkey = target.server_id.clone();
        let haddr = target.endpoint();
        // Reuse the cached hedge connection (and its response decode
        // state) when it points at the same peer; otherwise dial fresh
        // within the remaining budget.
        let cached = match self.hedge_conn.take() {
            Some((id, s, d)) if id == hkey => Some((s, d)),
            _ => None,
        };
        let hcancel: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
        let hc2 = hcancel.clone();
        let hcancelled = Arc::new(AtomicBool::new(false));
        let hcancelled2 = hcancelled.clone();
        let htx = tx;
        let hframe = frame.clone();
        std::thread::Builder::new()
            .name("query-hedge-alt".into())
            .spawn(move || {
                let t0 = Instant::now();
                let run = || -> Result<((Buffer, Option<Caps>), TcpStream, LinkDecoder)> {
                    let (mut s, mut dec) = match cached {
                        Some(sd) => sd,
                        None => {
                            let s = connect_within(&haddr, hedge_budget)
                                .map_err(|e| Error::Transport(format!("hedge connect {haddr}: {e}")))?;
                            s.set_nodelay(true).ok();
                            (s, LinkDecoder::new(""))
                        }
                    };
                    s.set_read_timeout(Some(hedge_budget))?;
                    *hc2.lock().unwrap() = s.try_clone().ok();
                    // Handshake with `cancel_hedge`: the canceller sets the
                    // flag BEFORE shutting down the registered handle, and we
                    // check it AFTER registering — so either we abort here
                    // before sending, or the cancel hits our live socket and
                    // errors the write/read. No window where a cancelled
                    // hedge still completes against the peer.
                    if hcancelled2.load(Ordering::SeqCst) {
                        return Err(Error::Transport("hedge cancelled before send".into()));
                    }
                    wire::write_frame_vectored(&mut s, &hframe)?;
                    let rc = read_response(&mut s, seq, &mut dec)?;
                    Ok((rc, s, dec))
                };
                match run() {
                    Ok((rc, s, dec)) => {
                        let _ = htx
                            .send((false, Ok(rc), t0.elapsed().as_micros() as f64, Some((s, dec))));
                    }
                    Err(e) => {
                        let _ = htx.send((false, Err(e), 0.0, None));
                    }
                }
            })
            .map_err(|e| Error::Transport(format!("spawn hedge: {e}")))?;

        let cancel = |s: &Option<TcpStream>| {
            if let Some(s) = s {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        };
        let cancel_hedge = || {
            hcancelled.store(true, Ordering::SeqCst);
            cancel(&hcancel.lock().unwrap());
        };
        let mut first_err: Option<Error> = None;
        loop {
            let left = end.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left.max(Duration::from_millis(1))) {
                Ok((from_primary, Ok(rc), rtt, conn)) => {
                    if from_primary {
                        // Primary won after all: cancel the hedge.
                        cancel_hedge();
                        if let Some((s, dec)) = conn {
                            self.conn = Some(s);
                            self.resp_dec = dec;
                        }
                        health.record_success(&primary_key, rtt);
                    } else {
                        // Hedge won: cancel the primary read — its late
                        // response must never alias a future frame's.
                        Self::counter(name, "hedge_wins").inc();
                        cancel(&pcancel);
                        self.conn = None;
                        if let Some((s, dec)) = conn {
                            self.hedge_conn = Some((hkey.clone(), s, dec));
                        }
                        health.record_success(&hkey, rtt);
                    }
                    return Ok(rc);
                }
                Ok((from_primary, Err(e), _, _)) => {
                    // One racer failed; keep waiting for the other.
                    let key = if from_primary { &primary_key } else { &hkey };
                    if health.record_failure(key) {
                        Self::counter(name, "breaker_open").inc();
                        log_warn!("query", "{name}: breaker OPEN for `{key}`");
                    }
                    if let Some(first) = first_err.take() {
                        // Both failed: tear down without re-recording.
                        self.conn = None;
                        self.last_failed = Some(primary_key.clone());
                        if let Endpoint::Discovered { current, .. } = &mut self.endpoint {
                            current.take();
                        }
                        return Err(first);
                    }
                    first_err = Some(e);
                }
                Err(_) => {
                    // Budget exhausted with both still outstanding.
                    cancel(&pcancel);
                    cancel_hedge();
                    self.fail_current(name);
                    return Err(Error::Transport("hedged query timed out".into()));
                }
            }
        }
    }
}

/// `TcpStream::connect` with a timeout when the address parses to a
/// socket address (it always does for discovery ads; a hostname falls
/// back to the blocking resolver path).
fn connect_within(addr: &str, budget: Duration) -> std::io::Result<TcpStream> {
    match addr.parse::<std::net::SocketAddr>() {
        Ok(sa) => TcpStream::connect_timeout(&sa, budget),
        Err(_) => TcpStream::connect(addr),
    }
}

/// Read response frames until the one matching `seq` arrives. Responses
/// echo the request seq (the server round-trips buffer meta), so an
/// earlier frame's late response on a reused connection is drained
/// instead of being delivered as the answer to the current request. A
/// response from the future (seq ahead) can only mean protocol
/// corruption. Servers that strip meta (seq `None`) skip the check.
///
/// `dec` is this connection's response decode state (delta-coded
/// response streams are per-connection chains); a mid-chain delta the
/// chain can't apply is skipped like a stale response.
fn read_response(
    stream: &mut TcpStream,
    seq: u64,
    dec: &mut LinkDecoder,
) -> Result<(Buffer, Option<Caps>)> {
    loop {
        let f = wire::read_frame(stream)?;
        let Some((buf, caps)) = dec.decode(&f)? else {
            log_debug!("query", "skipping mid-chain response frame (waiting for a keyframe)");
            continue;
        };
        match buf.meta.seq {
            Some(s) if s < seq => {
                log_debug!("query", "draining stale response seq {s} (waiting for {seq})");
                continue;
            }
            Some(s) if s > seq => {
                return Err(Error::Transport(format!("response seq {s} ahead of request {seq}")));
            }
            _ => return Ok((buf, caps)),
        }
    }
}

impl Element for QueryClient {
    /// Socket-bound (synchronous request/response round-trip, discovery
    /// waits): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                self.in_caps = Some(c);
                Ok(())
            }
            Item::Buffer(b) => {
                let t0 = Instant::now();
                let deadline = self.cfg.deadline.map(|d| t0 + d);
                // One seq per FRAME, reused verbatim on every retry of it,
                // so servers can dedup retransmissions (the old code
                // re-incremented on the failover retry).
                self.seq += 1;
                let seq = self.seq;
                let max_attempts = self.cfg.retry.max(1);
                let mut attempt = 0u32;
                let result = loop {
                    attempt += 1;
                    match self.attempt(&b, seq, deadline, &ctx.name) {
                        Ok(r) => break Ok(r),
                        Err(e) => {
                            if attempt >= max_attempts || ctx.stopped() {
                                break Err(e);
                            }
                            let delay = self.backoff_delay(attempt);
                            if let Some(dl) = deadline {
                                if Instant::now() + delay >= dl {
                                    break Err(e);
                                }
                            }
                            Self::counter(&ctx.name, "retries").inc();
                            log_debug!(
                                "query",
                                "{}: attempt {attempt} failed ({e}); retrying in {delay:?}",
                                ctx.name
                            );
                            std::thread::sleep(delay);
                        }
                    }
                };
                let (resp, caps) = match result {
                    Ok(r) => r,
                    Err(e) => {
                        if deadline.is_some() {
                            // Leaky semantics: the frame's budget is spent;
                            // drop it rather than stalling the pipeline.
                            Self::counter(&ctx.name, "frames_dropped").inc();
                            log_warn!(
                                "query",
                                "{}: dropping frame seq {seq} after {attempt} attempts: {e}",
                                ctx.name
                            );
                            return Ok(());
                        }
                        return Err(Error::element(
                            &ctx.name,
                            format!("query failed after {attempt} attempts: {e}"),
                        ));
                    }
                };
                metrics::global().observe(
                    &format!("query.{}.rtt_us", ctx.name),
                    t0.elapsed().as_micros() as f64,
                );
                if let Some(c) = caps {
                    if self.out_caps.as_ref() != Some(&c) {
                        ctx.push_caps(c.clone())?;
                        self.out_caps = Some(c);
                    }
                }
                let mut out = resp;
                out.pts = b.pts; // response inherits the request timestamp
                out.duration = b.duration;
                out.meta.client_id = None;
                out.meta.seq = None;
                ctx.push_buffer(out)?;
                Ok(())
            }
            Item::Eos => Ok(()),
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        if let Some(c) = self.conn.take() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        if let Some((_, c, _)) = self.hedge_conn.take() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::elements::filter::TensorFilter;
    use crate::mqtt::Broker;
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo, TensorsInfo};

    /// Server pipeline (serversrc -> x2 filter -> serversink) on a port.
    fn start_server_on(
        pair: &str,
        op: &str,
        port: u16,
        broker: Option<&str>,
    ) -> crate::pipeline::Running {
        let mut src = QueryServerSrc::new(op)
            .with_pair_id(pair)
            .with_server_id(pair)
            .with_bind(&format!("127.0.0.1:{port}"));
        if let Some(b) = broker {
            src = src.with_hybrid(b);
        }
        let mut p = Pipeline::new();
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x.wrapping_mul(2)).collect())
        }));
        let s = p.add("ssrc", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p.add("ssink", Box::new(QueryServerSink::new(pair))).unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        p.start().unwrap()
    }

    fn free_port() -> u16 {
        TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
    }

    fn client_pipeline(client: QueryClient) -> (crate::pipeline::Running, crate::elements::basic::AppSrcHandle, Receiver<Buffer>) {
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[4]).unwrap());
        let mut p = Pipeline::new();
        let (src, h) = AppSrc::new(8, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(8);
        let s = p.add("src", Box::new(src)).unwrap();
        let c = p.add("qc", Box::new(client)).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, c).unwrap();
        p.link(c, k).unwrap();
        (p.start().unwrap(), h, rx)
    }

    #[test]
    fn tcp_query_roundtrip() {
        let port = free_port();
        let server = start_server_on("tcp-rt", "op-tcp", port, None);
        std::thread::sleep(Duration::from_millis(200));
        let (cr, h, rx) = client_pipeline(QueryClient::tcp("op-tcp", &format!("127.0.0.1:{port}")));
        h.push(Buffer::new(vec![1, 2, 3, 4]).with_pts(99)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(&out.data[..], &[2, 4, 6, 8]);
        assert_eq!(out.pts, Some(99));
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn tcp_query_with_compressed_hops() {
        // Zlib on the request hop, zlib on the response hop; both sides
        // self-configure from the wire flag.
        let port = free_port();
        let mut p = Pipeline::new();
        let src = QueryServerSrc::new("op-gz")
            .with_pair_id("gz-rt")
            .with_bind(&format!("127.0.0.1:{port}"));
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x.wrapping_mul(2)).collect())
        }));
        let s = p.add("ssrc", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p
            .add("ssink", Box::new(QueryServerSink::new("gz-rt").with_codec(Codec::Zlib)))
            .unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let server = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(200));

        let client =
            QueryClient::tcp("op-gz", &format!("127.0.0.1:{port}")).with_codec(Codec::Zlib);
        let (cr, h, rx) = client_pipeline(client);
        h.push(Buffer::new(vec![1, 2, 3, 4]).with_pts(7)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(&out.data[..], &[2, 4, 6, 8]);
        assert_eq!(out.pts, Some(7));
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn tcp_query_with_delta_hops() {
        // Delta on the request hop AND per-client delta on the response
        // hop: chains survive a correlated frame sequence end to end.
        let port = free_port();
        let mut p = Pipeline::new();
        let src = QueryServerSrc::new("op-delta")
            .with_pair_id("delta-rt")
            .with_bind(&format!("127.0.0.1:{port}"));
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x.wrapping_mul(2)).collect())
        }));
        let s = p.add("ssrc", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p
            .add("ssink", Box::new(QueryServerSink::new("delta-rt").with_codec(Codec::Delta)))
            .unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let server = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(200));

        let client =
            QueryClient::tcp("op-delta", &format!("127.0.0.1:{port}")).with_codec(Codec::Delta);
        let (cr, h, rx) = client_pipeline(client);
        let mut payload = vec![5u8; 2048];
        for i in 0..6u8 {
            payload[i as usize * 300] = i;
            h.push(Buffer::new(payload.clone()).with_pts(i as u64)).unwrap();
            let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            let expect: Vec<u8> = payload.iter().map(|&x| x.wrapping_mul(2)).collect();
            assert_eq!(&out.data[..], &expect[..], "frame {i}");
            assert_eq!(out.pts, Some(i as u64));
        }
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn multiple_clients_one_server() {
        let port = free_port();
        let server = start_server_on("multi", "op-multi", port, None);
        std::thread::sleep(Duration::from_millis(200));
        let addr = format!("127.0.0.1:{port}");
        let (c1, h1, r1) = client_pipeline(QueryClient::tcp("op-multi", &addr));
        let (c2, h2, r2) = client_pipeline(QueryClient::tcp("op-multi", &addr));
        h1.push(Buffer::new(vec![1, 1, 1, 1])).unwrap();
        h2.push(Buffer::new(vec![3, 3, 3, 3])).unwrap();
        assert_eq!(&r1.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[2, 2, 2, 2]);
        assert_eq!(&r2.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[6, 6, 6, 6]);
        drop(h1);
        drop(h2);
        let _ = c1.stop(Duration::from_secs(5));
        let _ = c2.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn hybrid_discovery_and_query() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let port = free_port();
        let server = start_server_on("hy1", "op-hybrid", port, Some(&baddr));
        std::thread::sleep(Duration::from_millis(300));
        let client = QueryClient::hybrid("op-hybrid", &baddr).unwrap();
        let (cr, h, rx) = client_pipeline(client);
        h.push(Buffer::new(vec![5, 5, 5, 5])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(&out.data[..], &[10, 10, 10, 10]);
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn hybrid_failover_to_second_server() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let p1 = free_port();
        let p2 = free_port();
        let s1 = start_server_on("fo1", "op-fo", p1, Some(&baddr));
        let s2 = start_server_on("fo2", "op-fo", p2, Some(&baddr));
        std::thread::sleep(Duration::from_millis(400));
        let client = QueryClient::hybrid("op-fo", &baddr).unwrap().with_timeout(Duration::from_secs(1));
        let (cr, h, rx) = client_pipeline(client);
        h.push(Buffer::new(vec![1, 0, 0, 1])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[2, 0, 0, 2]);
        // Kill the first server pipeline entirely (unclean for its MQTT
        // session is hard to fake here; the TCP conn dying is enough for
        // the client to fail over on the next request).
        let _ = s1.stop(Duration::from_secs(5));
        std::thread::sleep(Duration::from_millis(300));
        h.push(Buffer::new(vec![2, 0, 0, 2])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(&out.data[..], &[4, 0, 0, 4]);
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = s2.stop(Duration::from_secs(5));
    }

    #[test]
    fn query_protocol_parse() {
        assert_eq!(QueryProtocol::parse("tcp").unwrap(), QueryProtocol::TcpRaw);
        assert_eq!(QueryProtocol::parse("mqtt-hybrid").unwrap(), QueryProtocol::MqttHybrid);
        assert!(QueryProtocol::parse("udp").is_err());
    }

    #[test]
    fn client_without_server_errors() {
        let (mut running, h, _rx) = {
            let client = QueryClient::tcp("none", "127.0.0.1:1").with_timeout(Duration::from_millis(300));
            let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[1]).unwrap());
            let mut p = Pipeline::new();
            let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
            let (sink, rx) = AppSink::new(4);
            let s = p.add("src", Box::new(src)).unwrap();
            let c = p.add("qc", Box::new(client)).unwrap();
            let k = p.add("sink", Box::new(sink)).unwrap();
            p.link(s, c).unwrap();
            p.link(c, k).unwrap();
            (p.start().unwrap(), h, rx)
        };
        h.push(Buffer::new(vec![0])).unwrap();
        match running.wait(Duration::from_secs(5)) {
            crate::pipeline::WaitOutcome::Error { element, .. } => assert_eq!(element, "qc"),
            other => panic!("expected error, got {other:?}"),
        }
    }
}
