//! Query elements — inference workload offloading (§4.2.2, Fig 2):
//! `tensor_query_client`, `tensor_query_serversrc`, `tensor_query_serversink`.
//!
//! In a client pipeline, `tensor_query_client` is a drop-in replacement
//! for `tensor_filter`: it ships each input frame to a server pipeline
//! and emits the inference result downstream. Two transports:
//!
//! - **tcp** (TCP-raw): direct `host:port`, no discovery (fast, rigid).
//! - **mqtt-hybrid**: discovery + liveness via the MQTT broker
//!   (`edge/query/<operation>/#` retained ads + last-will), DATA over a
//!   direct TCP connection — "rich features of MQTT without broker
//!   throughput overheads". Automatic failover to another compatible
//!   server on death (R4).
//!
//! Server side: `serversrc` accepts connections, tags each request buffer
//! with a client id; `serversink` routes responses back by that tag; the
//! two rendezvous in-process via the operation name (`pair-id` to
//! disambiguate multiple servers in one process).

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::coordinator::discovery::{self, AdWatcher, ServiceAd};
use crate::element::{Ctx, Element, Item, Workload};
use crate::metrics;
use crate::mqtt::MqttClient;
use crate::serial::wire::{self, LinkCodec, WireFrame};
use crate::serial::Codec;
use crate::util::{write_all_vectored, Error, Result};
use crate::{log_debug, log_info, log_warn};

/// Shared table of live client connections (write halves), keyed by the
/// server-assigned client id.
#[derive(Default)]
pub struct ConnTable {
    conns: Mutex<HashMap<u64, TcpStream>>,
}

impl ConnTable {
    fn insert(&self, id: u64, stream: TcpStream) {
        self.conns.lock().unwrap().insert(id, stream);
    }

    fn remove(&self, id: u64) {
        self.conns.lock().unwrap().remove(&id);
    }

    fn write_frame(&self, id: u64, frame: &WireFrame) -> Result<()> {
        let mut conns = self.conns.lock().unwrap();
        let Some(stream) = conns.get_mut(&id) else {
            return Err(Error::Transport(format!("query client {id} is gone")));
        };
        // Length prefix + frame header + shared payload in one vectored
        // write — the response payload is never assembled or copied.
        let len = (frame.len() as u32).to_le_bytes();
        let r = write_all_vectored(
            stream,
            &[&len[..], frame.header.as_slice(), frame.payload.as_slice()],
        );
        if r.is_err() {
            conns.remove(&id);
        }
        r.map_err(|e| Error::Transport(format!("response to client {id}: {e}")))
    }

    pub fn len(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn tables() -> &'static Mutex<HashMap<String, Arc<ConnTable>>> {
    static T: OnceLock<Mutex<HashMap<String, Arc<ConnTable>>>> = OnceLock::new();
    T.get_or_init(|| Mutex::new(HashMap::new()))
}

fn table_for(pair_id: &str) -> Arc<ConnTable> {
    tables().lock().unwrap().entry(pair_id.to_string()).or_default().clone()
}

/// Transport protocol of the query elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryProtocol {
    TcpRaw,
    MqttHybrid,
}

impl QueryProtocol {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "tcp" | "tcp-raw" => QueryProtocol::TcpRaw,
            "mqtt-hybrid" | "hybrid" | "mqtt" => QueryProtocol::MqttHybrid,
            other => return Err(Error::Parse(format!("unknown query protocol `{other}`"))),
        })
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Accepts query connections and feeds request buffers into the server
/// pipeline, tagged with the client id.
pub struct QueryServerSrc {
    pub operation: String,
    pub pair_id: String,
    pub bind: String,
    pub protocol: QueryProtocol,
    pub broker: String,
    pub server_id: String,
    pub model_label: String,
    rx: Option<Receiver<(Option<Caps>, Buffer)>>,
    mqtt: Option<MqttClient>,
    ad: Option<ServiceAd>,
    port: u16,
    shutdown: Option<Arc<AtomicBool>>,
    last_caps: Option<Caps>,
}

impl QueryServerSrc {
    pub fn new(operation: &str) -> Self {
        Self {
            operation: operation.to_string(),
            pair_id: operation.to_string(),
            bind: "127.0.0.1:0".to_string(),
            protocol: QueryProtocol::TcpRaw,
            broker: String::new(),
            server_id: format!("srv-{}-{}", std::process::id(), next_server_seq()),
            model_label: "model".to_string(),
            rx: None,
            mqtt: None,
            ad: None,
            port: 0,
            shutdown: None,
            last_caps: None,
        }
    }

    pub fn with_bind(mut self, bind: &str) -> Self {
        self.bind = bind.to_string();
        self
    }

    pub fn with_pair_id(mut self, id: &str) -> Self {
        self.pair_id = id.to_string();
        self
    }

    pub fn with_hybrid(mut self, broker: &str) -> Self {
        self.protocol = QueryProtocol::MqttHybrid;
        self.broker = broker.to_string();
        self
    }

    pub fn with_server_id(mut self, id: &str) -> Self {
        self.server_id = id.to_string();
        self
    }

    pub fn with_model_label(mut self, m: &str) -> Self {
        self.model_label = m.to_string();
        self
    }

    /// Port actually bound (after start).
    pub fn port(&self) -> u16 {
        self.port
    }
}

fn next_server_seq() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    SEQ.fetch_add(1, Ordering::Relaxed)
}

impl Element for QueryServerSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    /// Socket-bound (request channel receive, MQTT advertisement): keep
    /// a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }

    fn start(&mut self, ctx: &mut Ctx) -> Result<()> {
        discovery::validate_operation(&self.operation)?;
        let listener = TcpListener::bind(&self.bind)
            .map_err(|e| Error::Transport(format!("query bind {}: {e}", self.bind)))?;
        self.port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let table = table_for(&self.pair_id);
        let (tx, rx) = sync_channel::<(Option<Caps>, Buffer)>(64);
        self.rx = Some(rx);
        let shutdown = Arc::new(AtomicBool::new(false));
        self.shutdown = Some(shutdown.clone());

        let name = ctx.name.clone();
        std::thread::Builder::new()
            .name(format!("query-accept-{}", self.operation))
            .spawn(move || {
                let next_client = AtomicU64::new(1);
                while !shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            stream.set_nodelay(true).ok();
                            let id = next_client.fetch_add(1, Ordering::Relaxed);
                            log_debug!("query", "{name}: client {id} from {peer}");
                            let Ok(wstream) = stream.try_clone() else { continue };
                            table.insert(id, wstream);
                            spawn_client_reader(id, stream, table.clone(), tx.clone());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(|e| Error::Transport(format!("spawn accept: {e}")))?;

        if self.protocol == QueryProtocol::MqttHybrid {
            let ad = ServiceAd {
                operation: self.operation.clone(),
                server_id: self.server_id.clone(),
                host: "127.0.0.1".to_string(),
                port: self.port,
                model: self.model_label.clone(),
                load: 0.0,
            };
            let client =
                MqttClient::connect(&self.broker, discovery::server_client_options(&self.server_id, &ad))?;
            discovery::advertise(&client, &ad)?;
            log_info!("query", "{}: advertised `{}` on {}", ctx.name, ad.topic(), self.broker);
            self.mqtt = Some(client);
            self.ad = Some(ad);
        }
        Ok(())
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        let Some(rx) = &self.rx else { return Ok(false) };
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok((caps, buf)) => {
                if let Some(c) = caps {
                    if self.last_caps.as_ref() != Some(&c) {
                        ctx.push_caps(c.clone())?;
                        self.last_caps = Some(c);
                    }
                }
                metrics::global().counter(&format!("queryserver.{}", ctx.name)).add_bytes(buf.len() as u64);
                ctx.push_buffer(buf)?;
                Ok(true)
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(!ctx.stopped()),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(false),
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        if let Some(s) = &self.shutdown {
            s.store(true, Ordering::Relaxed);
        }
        if let (Some(client), Some(ad)) = (&self.mqtt, &self.ad) {
            let _ = discovery::clear_advertisement(client, ad);
            client.disconnect();
        }
    }
}

fn spawn_client_reader(
    id: u64,
    mut stream: TcpStream,
    table: Arc<ConnTable>,
    tx: SyncSender<(Option<Caps>, Buffer)>,
) {
    std::thread::Builder::new()
        .name(format!("query-client-{id}"))
        .spawn(move || {
            loop {
                let frame = match wire::read_frame(&mut stream) {
                    Ok(f) => f,
                    Err(_) => break,
                };
                // One allocation per request: the decoded buffer is a
                // slice view into the received frame.
                let Ok((mut buf, caps)) = wire::decode_shared(&frame) else { break };
                buf.meta.client_id = Some(id);
                if tx.send((caps, buf)).is_err() {
                    break;
                }
            }
            table.remove(id);
            log_debug!("query", "client {id} disconnected");
        })
        .expect("spawn query reader");
}

/// Routes response buffers back to the tagged client connection.
pub struct QueryServerSink {
    pub pair_id: String,
    table: Option<Arc<ConnTable>>,
    caps: Option<Caps>,
    link: LinkCodec,
}

impl QueryServerSink {
    pub fn new(pair_id: &str) -> Self {
        Self {
            pair_id: pair_id.to_string(),
            table: None,
            caps: None,
            link: LinkCodec::new(Codec::None, ""),
        }
    }

    /// Codec for response frames (`Codec::Auto` adapts per link, sampling
    /// into `codec.auto.queryserver.<pair_id>.*`).
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.link = LinkCodec::new(codec, &format!("queryserver.{}", self.pair_id));
        self
    }
}

impl Element for QueryServerSink {
    fn n_src_pads(&self) -> usize {
        0
    }

    /// Socket-bound (response writes to client connections): keep a
    /// thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        self.table = Some(table_for(&self.pair_id));
        Ok(())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                self.caps = Some(c);
                Ok(())
            }
            Item::Buffer(b) => {
                let table =
                    self.table.as_ref().ok_or_else(|| Error::element(&ctx.name, "not started"))?;
                let Some(id) = b.meta.client_id else {
                    return Err(Error::element(&ctx.name, "response buffer without client id"));
                };
                let frame = self
                    .link
                    .encode(&b, self.caps.as_ref())
                    .map_err(|e| Error::element(&ctx.name, e))?;
                // A vanished client is not a pipeline error (R4: clients
                // come and go); drop the response.
                if let Err(e) = table.write_frame(id, &frame) {
                    log_debug!("query", "{}: {e}", ctx.name);
                }
                Ok(())
            }
            Item::Eos => Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

enum Endpoint {
    Fixed(String),
    Discovered { watcher: AdWatcher, current: Option<ServiceAd>, failed: Vec<String> },
}

/// Drop-in `tensor_filter` replacement that offloads inference.
pub struct QueryClient {
    pub operation: String,
    pub timeout: Duration,
    endpoint: Endpoint,
    conn: Option<TcpStream>,
    in_caps: Option<Caps>,
    out_caps: Option<Caps>,
    seq: u64,
    link: LinkCodec,
}

impl QueryClient {
    /// TCP-raw transport to a fixed server address.
    pub fn tcp(operation: &str, server: &str) -> Self {
        Self {
            operation: operation.to_string(),
            timeout: Duration::from_secs(5),
            endpoint: Endpoint::Fixed(server.to_string()),
            conn: None,
            in_caps: None,
            out_caps: None,
            seq: 0,
            link: LinkCodec::new(Codec::None, ""),
        }
    }

    /// MQTT-hybrid transport: discover servers for `operation` via broker.
    pub fn hybrid(operation: &str, broker: &str) -> Result<Self> {
        let watcher = AdWatcher::watch(broker, operation)?;
        Ok(Self {
            operation: operation.to_string(),
            timeout: Duration::from_secs(5),
            endpoint: Endpoint::Discovered { watcher, current: None, failed: Vec::new() },
            conn: None,
            in_caps: None,
            out_caps: None,
            seq: 0,
            link: LinkCodec::new(Codec::None, ""),
        })
    }

    pub fn with_timeout(mut self, t: Duration) -> Self {
        self.timeout = t;
        self
    }

    /// Codec for request frames (`Codec::Auto` adapts per link, sampling
    /// into `codec.auto.query.<operation>.*`). The server decodes via the
    /// wire flag, so no server-side configuration is needed.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.link = LinkCodec::new(codec, &format!("query.{}", self.operation));
        self
    }

    fn connect(&mut self) -> Result<()> {
        let addr = match &mut self.endpoint {
            Endpoint::Fixed(a) => a.clone(),
            Endpoint::Discovered { watcher, current, failed } => {
                let ad = watcher
                    .pick(failed)
                    .or_else(|| watcher.wait_any(Duration::from_secs(3)))
                    .ok_or_else(|| {
                        Error::Transport(format!("no servers for operation `{}`", self.operation))
                    })?;
                log_info!("query", "client: using server `{}` at {}", ad.server_id, ad.endpoint());
                let ep = ad.endpoint();
                *current = Some(ad);
                ep
            }
        };
        let stream = TcpStream::connect(&addr)
            .map_err(|e| Error::Transport(format!("query connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(self.timeout))?;
        self.conn = Some(stream);
        Ok(())
    }

    fn mark_failed(&mut self) {
        self.conn = None;
        if let Endpoint::Discovered { current, failed, .. } = &mut self.endpoint {
            if let Some(ad) = current.take() {
                log_warn!("query", "client: server `{}` failed; failing over", ad.server_id);
                failed.push(ad.server_id);
            }
        }
    }

    /// One request/response exchange.
    fn exchange(&mut self, b: &Buffer) -> Result<(Buffer, Option<Caps>)> {
        if self.conn.is_none() {
            self.connect()?;
        }
        let mut req = b.clone();
        self.seq += 1;
        req.meta.seq = Some(self.seq);
        let frame = self.link.encode(&req, self.in_caps.as_ref())?;
        let stream = self.conn.as_mut().unwrap();
        let send = wire::write_frame_vectored(stream, &frame);
        let resp = send.and_then(|_| wire::read_frame(stream));
        match resp {
            Ok(f) => wire::decode_shared(&f),
            Err(e) => {
                self.mark_failed();
                Err(e)
            }
        }
    }
}

impl Element for QueryClient {
    /// Socket-bound (synchronous request/response round-trip, discovery
    /// waits): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                self.in_caps = Some(c);
                Ok(())
            }
            Item::Buffer(b) => {
                let t0 = std::time::Instant::now();
                // Try current server, then fail over once (R4).
                let (resp, caps) = match self.exchange(&b) {
                    Ok(r) => r,
                    Err(first) => match self.exchange(&b) {
                        Ok(r) => r,
                        Err(_second) => {
                            return Err(Error::element(
                                &ctx.name,
                                format!("query failed (no failover target): {first}"),
                            ))
                        }
                    },
                };
                metrics::global().observe(
                    &format!("query.{}.rtt_us", ctx.name),
                    t0.elapsed().as_micros() as f64,
                );
                if let Some(c) = caps {
                    if self.out_caps.as_ref() != Some(&c) {
                        ctx.push_caps(c.clone())?;
                        self.out_caps = Some(c);
                    }
                }
                let mut out = resp;
                out.pts = b.pts; // response inherits the request timestamp
                out.duration = b.duration;
                out.meta.client_id = None;
                ctx.push_buffer(out)?;
                Ok(())
            }
            Item::Eos => Ok(()),
        }
    }

    fn stop(&mut self, _ctx: &mut Ctx) {
        self.conn = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::elements::filter::TensorFilter;
    use crate::mqtt::Broker;
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo, TensorsInfo};

    /// Server pipeline (serversrc -> x2 filter -> serversink) on a port.
    fn start_server_on(
        pair: &str,
        op: &str,
        port: u16,
        broker: Option<&str>,
    ) -> crate::pipeline::Running {
        let mut src = QueryServerSrc::new(op)
            .with_pair_id(pair)
            .with_server_id(pair)
            .with_bind(&format!("127.0.0.1:{port}"));
        if let Some(b) = broker {
            src = src.with_hybrid(b);
        }
        let mut p = Pipeline::new();
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x.wrapping_mul(2)).collect())
        }));
        let s = p.add("ssrc", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p.add("ssink", Box::new(QueryServerSink::new(pair))).unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        p.start().unwrap()
    }

    fn free_port() -> u16 {
        TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
    }

    fn client_pipeline(client: QueryClient) -> (crate::pipeline::Running, crate::elements::basic::AppSrcHandle, Receiver<Buffer>) {
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[4]).unwrap());
        let mut p = Pipeline::new();
        let (src, h) = AppSrc::new(8, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(8);
        let s = p.add("src", Box::new(src)).unwrap();
        let c = p.add("qc", Box::new(client)).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, c).unwrap();
        p.link(c, k).unwrap();
        (p.start().unwrap(), h, rx)
    }

    #[test]
    fn tcp_query_roundtrip() {
        let port = free_port();
        let server = start_server_on("tcp-rt", "op-tcp", port, None);
        std::thread::sleep(Duration::from_millis(200));
        let (cr, h, rx) = client_pipeline(QueryClient::tcp("op-tcp", &format!("127.0.0.1:{port}")));
        h.push(Buffer::new(vec![1, 2, 3, 4]).with_pts(99)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(&out.data[..], &[2, 4, 6, 8]);
        assert_eq!(out.pts, Some(99));
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn tcp_query_with_compressed_hops() {
        // Zlib on the request hop, zlib on the response hop; both sides
        // self-configure from the wire flag.
        let port = free_port();
        let mut p = Pipeline::new();
        let src = QueryServerSrc::new("op-gz")
            .with_pair_id("gz-rt")
            .with_bind(&format!("127.0.0.1:{port}"));
        let f = TensorFilter::custom(Box::new(|b: &Buffer| {
            Ok(b.data.iter().map(|&x| x.wrapping_mul(2)).collect())
        }));
        let s = p.add("ssrc", Box::new(src)).unwrap();
        let fi = p.add("f", Box::new(f)).unwrap();
        let k = p
            .add("ssink", Box::new(QueryServerSink::new("gz-rt").with_codec(Codec::Zlib)))
            .unwrap();
        p.link(s, fi).unwrap();
        p.link(fi, k).unwrap();
        let server = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(200));

        let client =
            QueryClient::tcp("op-gz", &format!("127.0.0.1:{port}")).with_codec(Codec::Zlib);
        let (cr, h, rx) = client_pipeline(client);
        h.push(Buffer::new(vec![1, 2, 3, 4]).with_pts(7)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(&out.data[..], &[2, 4, 6, 8]);
        assert_eq!(out.pts, Some(7));
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn multiple_clients_one_server() {
        let port = free_port();
        let server = start_server_on("multi", "op-multi", port, None);
        std::thread::sleep(Duration::from_millis(200));
        let addr = format!("127.0.0.1:{port}");
        let (c1, h1, r1) = client_pipeline(QueryClient::tcp("op-multi", &addr));
        let (c2, h2, r2) = client_pipeline(QueryClient::tcp("op-multi", &addr));
        h1.push(Buffer::new(vec![1, 1, 1, 1])).unwrap();
        h2.push(Buffer::new(vec![3, 3, 3, 3])).unwrap();
        assert_eq!(&r1.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[2, 2, 2, 2]);
        assert_eq!(&r2.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[6, 6, 6, 6]);
        drop(h1);
        drop(h2);
        let _ = c1.stop(Duration::from_secs(5));
        let _ = c2.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn hybrid_discovery_and_query() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let port = free_port();
        let server = start_server_on("hy1", "op-hybrid", port, Some(&baddr));
        std::thread::sleep(Duration::from_millis(300));
        let client = QueryClient::hybrid("op-hybrid", &baddr).unwrap();
        let (cr, h, rx) = client_pipeline(client);
        h.push(Buffer::new(vec![5, 5, 5, 5])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(&out.data[..], &[10, 10, 10, 10]);
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = server.stop(Duration::from_secs(5));
    }

    #[test]
    fn hybrid_failover_to_second_server() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let baddr = broker.addr().to_string();
        let p1 = free_port();
        let p2 = free_port();
        let s1 = start_server_on("fo1", "op-fo", p1, Some(&baddr));
        let s2 = start_server_on("fo2", "op-fo", p2, Some(&baddr));
        std::thread::sleep(Duration::from_millis(400));
        let client = QueryClient::hybrid("op-fo", &baddr).unwrap().with_timeout(Duration::from_secs(1));
        let (cr, h, rx) = client_pipeline(client);
        h.push(Buffer::new(vec![1, 0, 0, 1])).unwrap();
        assert_eq!(&rx.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[2, 0, 0, 2]);
        // Kill the first server pipeline entirely (unclean for its MQTT
        // session is hard to fake here; the TCP conn dying is enough for
        // the client to fail over on the next request).
        let _ = s1.stop(Duration::from_secs(5));
        std::thread::sleep(Duration::from_millis(300));
        h.push(Buffer::new(vec![2, 0, 0, 2])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(&out.data[..], &[4, 0, 0, 4]);
        drop(h);
        let _ = cr.stop(Duration::from_secs(5));
        let _ = s2.stop(Duration::from_secs(5));
    }

    #[test]
    fn query_protocol_parse() {
        assert_eq!(QueryProtocol::parse("tcp").unwrap(), QueryProtocol::TcpRaw);
        assert_eq!(QueryProtocol::parse("mqtt-hybrid").unwrap(), QueryProtocol::MqttHybrid);
        assert!(QueryProtocol::parse("udp").is_err());
    }

    #[test]
    fn client_without_server_errors() {
        let (mut running, h, _rx) = {
            let client = QueryClient::tcp("none", "127.0.0.1:1").with_timeout(Duration::from_millis(300));
            let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[1]).unwrap());
            let mut p = Pipeline::new();
            let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
            let (sink, rx) = AppSink::new(4);
            let s = p.add("src", Box::new(src)).unwrap();
            let c = p.add("qc", Box::new(client)).unwrap();
            let k = p.add("sink", Box::new(sink)).unwrap();
            p.link(s, c).unwrap();
            p.link(c, k).unwrap();
            (p.start().unwrap(), h, rx)
        };
        h.push(Buffer::new(vec![0])).unwrap();
        match running.wait(Duration::from_secs(5)) {
            crate::pipeline::WaitOutcome::Error { element, .. } => assert_eq!(element, "qc"),
            other => panic!("expected error, got {other:?}"),
        }
    }
}
