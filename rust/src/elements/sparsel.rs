//! `tensor_sparse_enc` / `tensor_sparse_dec` — converting filters between
//! static and sparse (COO) tensor streams (§4.1: the binary representation
//! is incompatible with static/flexible, hence dedicated elements).
//! Both pure compute (`Workload::Compute` default): schedulable on the
//! worker pool, no dedicated threads.

use crate::caps::Caps;
use crate::element::{Ctx, Element, Item};
use crate::metrics;
use crate::tensor::{sparse, TensorsInfo};
use crate::util::{Error, Result};

/// static → sparse. Records the per-frame compression ratio into the
/// histogram `sparse.<name>.ratio` (encoded/dense).
pub struct SparseEnc {
    info: Option<TensorsInfo>,
}

impl Default for SparseEnc {
    fn default() -> Self {
        Self::new()
    }
}

impl SparseEnc {
    pub fn new() -> Self {
        Self { info: None }
    }
}

impl Element for SparseEnc {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let info = c.tensors_info().map_err(|e| Error::element(&ctx.name, e))?;
                self.info = Some(info.clone());
                // Sparse caps keep the logical shape for the decoder side.
                let caps = Caps::tensors_sparse()
                    .with("num_tensors", info.len())
                    .with("dimensions", info.dimensions_string())
                    .with("types", info.types_string());
                ctx.push_caps(caps)
            }
            Item::Buffer(b) => {
                let info = self
                    .info
                    .as_ref()
                    .ok_or_else(|| Error::element(&ctx.name, "buffer before caps"))?;
                if b.len() != info.frame_size() {
                    return Err(Error::element(
                        &ctx.name,
                        format!("frame {} != caps size {}", b.len(), info.frame_size()),
                    ));
                }
                let mut out = Vec::new();
                let mut off = 0;
                for t in &info.tensors {
                    let enc = sparse::encode(t, &b.data[off..off + t.size()])
                        .map_err(|e| Error::element(&ctx.name, e))?;
                    off += t.size();
                    out.extend_from_slice(&enc);
                }
                metrics::global().observe(
                    &format!("sparse.{}.ratio", ctx.name),
                    out.len() as f64 / b.len().max(1) as f64,
                );
                ctx.push_buffer(b.map_payload(out))
            }
            Item::Eos => Ok(()),
        }
    }
}

/// sparse → static.
pub struct SparseDec {
    info: Option<TensorsInfo>,
}

impl Default for SparseDec {
    fn default() -> Self {
        Self::new()
    }
}

impl SparseDec {
    pub fn new() -> Self {
        Self { info: None }
    }
}

impl Element for SparseDec {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let info = c.tensors_info().map_err(|e| Error::element(&ctx.name, e))?;
                self.info = Some(info.clone());
                ctx.push_caps(Caps::tensors(&info))
            }
            Item::Buffer(b) => {
                let info = self
                    .info
                    .as_ref()
                    .ok_or_else(|| Error::element(&ctx.name, "buffer before caps"))?;
                let mut payload = Vec::with_capacity(info.frame_size());
                let mut off = 0usize;
                for _ in 0..info.len() {
                    // Each chunk's length is derivable from its header.
                    let chunk = &b.data[off..];
                    let (t, dense) =
                        sparse::decode_prefix(chunk).map_err(|e| Error::element(&ctx.name, e))?;
                    off += sparse::encoded_len(chunk).map_err(|e| Error::element(&ctx.name, e))?;
                    let _ = t;
                    payload.extend_from_slice(&dense);
                }
                if off != b.len() {
                    return Err(Error::element(
                        &ctx.name,
                        format!("{} trailing bytes in sparse frame", b.len() - off),
                    ));
                }
                ctx.push_buffer(b.map_payload(payload))
            }
            Item::Eos => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::pipeline::Pipeline;
    use crate::tensor::{f32_to_bytes, DType, TensorInfo};
    use std::time::Duration;

    #[test]
    fn enc_dec_roundtrip_pipeline() {
        let mut p = Pipeline::new();
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::F32, &[8]).unwrap()).unwrap();
        info.push(TensorInfo::new(DType::F32, &[4]).unwrap()).unwrap();
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("s", Box::new(src)).unwrap();
        let e = p.add("enc", Box::new(SparseEnc::new())).unwrap();
        let d = p.add("dec", Box::new(SparseDec::new())).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, e).unwrap();
        p.link(e, d).unwrap();
        p.link(d, k).unwrap();
        let _r = p.start().unwrap();
        let mut vals = vec![0f32; 12];
        vals[1] = 3.5;
        vals[9] = -1.0;
        let payload = f32_to_bytes(&vals);
        h.push(Buffer::new(payload.clone()).with_pts(3)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(&out.data[..], payload.as_slice());
        assert_eq!(out.pts, Some(3));
    }

    #[test]
    fn sparse_frame_smaller_for_sparse_data() {
        let mut p = Pipeline::new();
        let info = TensorsInfo::one(TensorInfo::new(DType::F32, &[1000]).unwrap());
        let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
        let (sink, rx) = AppSink::new(4);
        let s = p.add("s", Box::new(src)).unwrap();
        let e = p.add("enc", Box::new(SparseEnc::new())).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link(s, e).unwrap();
        p.link(e, k).unwrap();
        let _r = p.start().unwrap();
        let mut vals = vec![0f32; 1000];
        vals[17] = 1.0;
        h.push(Buffer::new(f32_to_bytes(&vals))).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(out.len() < 100, "sparse frame {} bytes", out.len());
    }
}
