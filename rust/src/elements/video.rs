//! Video elements: the synthetic camera (`videotestsrc` — the workload
//! generator standing in for the paper's USB cameras), `videoconvert`,
//! `videoscale`, and a minimal `compositor`.
//!
//! Video format is fixed to packed RGB (3 bytes/pixel, row-major), which
//! is what the paper's pipelines negotiate before `tensor_converter`.

use std::sync::Arc;

use crate::buffer::Buffer;
use crate::caps::Caps;
use crate::clock::{sleep_until, Ns, SECOND};
use crate::element::{Ctx, Element, EosTracker, Item, Workload};
use crate::util::{Error, Result};
use crate::util::rng::XorShift64;

/// Test pattern of the synthetic camera.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Moving color bars (deterministic, compressible).
    Smpte,
    /// Per-frame deterministic noise (incompressible).
    Noise,
    /// A bright square moving across a dark field (object-like).
    Ball,
}

impl Pattern {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "smpte" | "0" => Pattern::Smpte,
            "noise" | "random" | "1" => Pattern::Noise,
            "ball" | "18" => Pattern::Ball,
            other => return Err(Error::Parse(format!("unknown pattern `{other}`"))),
        })
    }
}

/// Synthetic live camera: `width`x`height` RGB at `fps`.
pub struct VideoTestSrc {
    pub width: u32,
    pub height: u32,
    pub fps: u32,
    pub pattern: Pattern,
    /// Stop after this many frames (0 = unbounded / live).
    pub num_buffers: u64,
    /// Pace frames against the pipeline clock (live source).
    pub is_live: bool,
    frame: u64,
    caps_sent: bool,
    rng: XorShift64,
}

impl VideoTestSrc {
    pub fn new(width: u32, height: u32, fps: u32) -> Self {
        Self {
            width,
            height,
            fps,
            pattern: Pattern::Smpte,
            num_buffers: 0,
            is_live: true,
            frame: 0,
            caps_sent: false,
            rng: XorShift64::new(0xC0FFEE),
        }
    }

    pub fn with_pattern(mut self, p: Pattern) -> Self {
        self.pattern = p;
        self
    }

    pub fn with_num_buffers(mut self, n: u64) -> Self {
        self.num_buffers = n;
        self
    }

    pub fn live(mut self, live: bool) -> Self {
        self.is_live = live;
        self
    }

    fn render(&mut self) -> Vec<u8> {
        let (w, h) = (self.width as usize, self.height as usize);
        let mut data = vec![0u8; w * h * 3];
        match self.pattern {
            Pattern::Smpte => {
                const BARS: [[u8; 3]; 7] = [
                    [235, 235, 235],
                    [235, 235, 16],
                    [16, 235, 235],
                    [16, 235, 16],
                    [235, 16, 235],
                    [235, 16, 16],
                    [16, 16, 235],
                ];
                let shift = (self.frame as usize) % w.max(1);
                for y in 0..h {
                    for x in 0..w {
                        let bar = ((x + shift) * 7 / w.max(1)).min(6);
                        let px = (y * w + x) * 3;
                        data[px..px + 3].copy_from_slice(&BARS[bar]);
                    }
                }
            }
            Pattern::Noise => {
                self.rng.fill_bytes(&mut data);
            }
            Pattern::Ball => {
                let t = self.frame as usize;
                let cx = (t * 7) % w.max(1);
                let cy = (t * 3) % h.max(1);
                let r = (w.min(h) / 8).max(1);
                for y in 0..h {
                    for x in 0..w {
                        let px = (y * w + x) * 3;
                        let dx = x.abs_diff(cx);
                        let dy = y.abs_diff(cy);
                        if dx * dx + dy * dy <= r * r {
                            data[px] = 250;
                            data[px + 1] = 220;
                            data[px + 2] = 40;
                        } else {
                            data[px] = 24;
                            data[px + 1] = 28;
                            data[px + 2] = 32;
                        }
                    }
                }
            }
        }
        data
    }
}

impl Element for VideoTestSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    /// Live capture paces frames against the wall clock (`sleep_until`),
    /// which must not stall a pool worker; as-fast-as-possible rendering
    /// (`is-live=false`) is pure compute and schedulable.
    fn workload(&self) -> Workload {
        if self.is_live {
            Workload::Blocking
        } else {
            Workload::Compute
        }
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        if self.num_buffers > 0 && self.frame >= self.num_buffers {
            return Ok(false);
        }
        if !self.caps_sent {
            ctx.push_caps(Caps::video(self.width, self.height, self.fps))?;
            self.caps_sent = true;
        }
        let dur = SECOND / self.fps.max(1) as Ns;
        let pts = self.frame * dur;
        if self.is_live {
            // do-timestamp=true semantics: stamp at frame creation time.
            sleep_until(&ctx.clock, pts);
            if ctx.stopped() {
                return Ok(false);
            }
        }
        let data = self.render();
        let mut buf = Buffer::new(data).with_pts(pts).with_duration(dur);
        buf.meta.origin = Some(Arc::from(ctx.name.as_str()));
        ctx.push_buffer(buf)?;
        self.frame += 1;
        Ok(true)
    }
}

/// Color conversion. RGB is the only in-memory format, so this is an
/// identity that exists for pipeline-description compatibility.
pub struct VideoConvert;

impl Element for VideoConvert {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if !matches!(item, Item::Eos) {
            ctx.push(0, item)?;
        }
        Ok(())
    }
}

/// Nearest-neighbour scaler to a fixed target size.
pub struct VideoScale {
    pub out_w: u32,
    pub out_h: u32,
    in_w: u32,
    in_h: u32,
}

impl VideoScale {
    pub fn new(out_w: u32, out_h: u32) -> Self {
        Self { out_w, out_h, in_w: 0, in_h: 0 }
    }
}

impl Element for VideoScale {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let (w, h, fps) = c
                    .video_geometry()
                    .map_err(|e| Error::element(&ctx.name, e))?;
                self.in_w = w;
                self.in_h = h;
                ctx.push_caps(Caps::video(self.out_w, self.out_h, fps))
            }
            Item::Buffer(b) => {
                if self.in_w == 0 {
                    return Err(Error::element(&ctx.name, "buffer before caps"));
                }
                if self.in_w == self.out_w && self.in_h == self.out_h {
                    return ctx.push_buffer(b);
                }
                let (iw, ih) = (self.in_w as usize, self.in_h as usize);
                let (ow, oh) = (self.out_w as usize, self.out_h as usize);
                let expect = iw * ih * 3;
                if b.len() != expect {
                    return Err(Error::element(
                        &ctx.name,
                        format!("frame {} bytes != {expect} for {iw}x{ih}", b.len()),
                    ));
                }
                let mut out = vec![0u8; ow * oh * 3];
                for y in 0..oh {
                    let sy = y * ih / oh;
                    for x in 0..ow {
                        let sx = x * iw / ow;
                        let d = (y * ow + x) * 3;
                        let s = (sy * iw + sx) * 3;
                        out[d..d + 3].copy_from_slice(&b.data[s..s + 3]);
                    }
                }
                ctx.push_buffer(b.map_payload(out))
            }
            Item::Eos => Ok(()),
        }
    }
}

/// Minimal compositor: N video sink pads layered onto one canvas by
/// per-pad (xpos, ypos, zorder). One output frame per pad-0 frame, using
/// the latest frame from the other pads.
pub struct Compositor {
    pads: Vec<PadCfg>,
    latest: Vec<Option<Buffer>>,
    geoms: Vec<Option<(u32, u32)>>,
    out_w: u32,
    out_h: u32,
    caps_sent: bool,
    eos: EosTracker,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PadCfg {
    pub xpos: u32,
    pub ypos: u32,
    pub zorder: u32,
}

impl Compositor {
    pub fn new(n_pads: usize) -> Self {
        Self {
            pads: vec![PadCfg::default(); n_pads.max(1)],
            latest: vec![None; n_pads.max(1)],
            geoms: vec![None; n_pads.max(1)],
            out_w: 0,
            out_h: 0,
            caps_sent: false,
            eos: EosTracker::new(n_pads.max(1)),
        }
    }

    pub fn set_pad(&mut self, pad: usize, cfg: PadCfg) {
        if pad < self.pads.len() {
            self.pads[pad] = cfg;
        }
    }

    fn compose(&self) -> Option<Vec<u8>> {
        let (ow, oh) = (self.out_w as usize, self.out_h as usize);
        if ow == 0 {
            return None;
        }
        let mut canvas = vec![0u8; ow * oh * 3];
        // Paint in ascending zorder.
        let mut order: Vec<usize> = (0..self.pads.len()).collect();
        order.sort_by_key(|&i| self.pads[i].zorder);
        for i in order {
            let (Some(buf), Some((w, h))) = (&self.latest[i], self.geoms[i]) else { continue };
            let (w, h) = (w as usize, h as usize);
            let (x0, y0) = (self.pads[i].xpos as usize, self.pads[i].ypos as usize);
            for y in 0..h {
                let oy = y + y0;
                if oy >= oh {
                    break;
                }
                let copy_w = w.min(ow.saturating_sub(x0));
                if copy_w == 0 {
                    continue;
                }
                let src = (y * w) * 3;
                let dst = (oy * ow + x0) * 3;
                canvas[dst..dst + copy_w * 3].copy_from_slice(&buf.data[src..src + copy_w * 3]);
            }
        }
        Some(canvas)
    }
}

impl Element for Compositor {
    fn n_sink_pads(&self) -> usize {
        self.pads.len()
    }

    fn ensure_sink_pads(&mut self, n: usize) -> bool {
        while self.pads.len() < n {
            self.pads.push(PadCfg::default());
            self.latest.push(None);
            self.geoms.push(None);
        }
        self.eos = EosTracker::new(self.pads.len());
        true
    }

    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                let (w, h, fps) = c.video_geometry().map_err(|e| Error::element(&ctx.name, e))?;
                self.geoms[pad] = Some((w, h));
                // Canvas grows to cover every pad's extent.
                self.out_w = self.out_w.max(self.pads[pad].xpos + w);
                self.out_h = self.out_h.max(self.pads[pad].ypos + h);
                if !self.caps_sent {
                    ctx.push_caps(Caps::video(self.out_w, self.out_h, fps))?;
                    self.caps_sent = true;
                }
                Ok(())
            }
            Item::Buffer(b) => {
                let pts = b.pts;
                self.latest[pad] = Some(b);
                if pad == 0 {
                    if let Some(canvas) = self.compose() {
                        let mut out = Buffer::new(canvas);
                        out.pts = pts;
                        ctx.push_buffer(out)?;
                    }
                }
                Ok(())
            }
            Item::Eos => {
                self.eos.mark(pad);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::basic::AppSink;
    use crate::pipeline::{Pipeline, WaitOutcome};
    use std::time::Duration;

    #[test]
    fn testsrc_produces_declared_frames() {
        let mut p = Pipeline::new();
        let (sink, rx) = AppSink::new(64);
        let src = VideoTestSrc::new(8, 6, 30).with_num_buffers(10).live(false);
        let s = p.add("src", Box::new(src)).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, k).unwrap();
        let running = p.start().unwrap();
        let mut frames = Vec::new();
        while let Ok(b) = rx.recv_timeout(Duration::from_secs(2)) {
            frames.push(b);
        }
        assert_eq!(running.wait_eos(Duration::from_secs(5)), WaitOutcome::Eos);
        assert_eq!(frames.len(), 10);
        assert_eq!(frames[0].len(), 8 * 6 * 3);
        // PTS spaced by 1/fps.
        assert_eq!(frames[1].pts.unwrap() - frames[0].pts.unwrap(), SECOND / 30);
    }

    #[test]
    fn patterns_are_deterministic_per_frame() {
        let mut a = VideoTestSrc::new(16, 16, 30).with_pattern(Pattern::Ball);
        let mut b = VideoTestSrc::new(16, 16, 30).with_pattern(Pattern::Ball);
        assert_eq!(a.render(), b.render());
        a.frame = 5;
        b.frame = 5;
        assert_eq!(a.render(), b.render());
        a.frame = 6;
        assert_ne!(a.render(), b.render());
    }

    #[test]
    fn noise_pattern_differs_per_frame() {
        let mut s = VideoTestSrc::new(8, 8, 30).with_pattern(Pattern::Noise);
        let f1 = s.render();
        let f2 = s.render();
        assert_ne!(f1, f2);
    }

    #[test]
    fn pattern_parse() {
        assert_eq!(Pattern::parse("smpte").unwrap(), Pattern::Smpte);
        assert_eq!(Pattern::parse("noise").unwrap(), Pattern::Noise);
        assert_eq!(Pattern::parse("ball").unwrap(), Pattern::Ball);
        assert!(Pattern::parse("xyz").is_err());
    }

    #[test]
    fn videoscale_downscales() {
        let mut p = Pipeline::new();
        let (sink, rx) = AppSink::new(16);
        let src = VideoTestSrc::new(16, 16, 30).with_num_buffers(2).live(false);
        let s = p.add("src", Box::new(src)).unwrap();
        let v = p.add("scale", Box::new(VideoScale::new(4, 4))).unwrap();
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(s, v).unwrap();
        p.link(v, k).unwrap();
        let running = p.start().unwrap();
        let b = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(b.len(), 4 * 4 * 3);
        assert_eq!(running.wait_eos(Duration::from_secs(5)), WaitOutcome::Eos);
    }

    #[test]
    fn videoscale_passthrough_same_size() {
        let mut vs = VideoScale::new(8, 8);
        vs.in_w = 8;
        vs.in_h = 8;
        // passthrough path exercised through a pipeline would need caps;
        // unit-check the geometry logic instead.
        assert_eq!(vs.out_w, 8);
    }

    #[test]
    fn compositor_layers_by_zorder() {
        let mut c = Compositor::new(2);
        c.set_pad(0, PadCfg { xpos: 0, ypos: 0, zorder: 1 });
        c.set_pad(1, PadCfg { xpos: 0, ypos: 0, zorder: 0 });
        c.geoms[0] = Some((2, 2));
        c.geoms[1] = Some((2, 2));
        c.out_w = 2;
        c.out_h = 2;
        c.latest[0] = Some(Buffer::new(vec![255; 12]));
        c.latest[1] = Some(Buffer::new(vec![1; 12]));
        let canvas = c.compose().unwrap();
        // pad 0 has higher zorder -> painted last -> wins
        assert!(canvas.iter().all(|&b| b == 255));
    }

    #[test]
    fn compositor_side_by_side() {
        let mut c = Compositor::new(2);
        c.set_pad(0, PadCfg { xpos: 0, ypos: 0, zorder: 0 });
        c.set_pad(1, PadCfg { xpos: 2, ypos: 0, zorder: 0 });
        c.geoms[0] = Some((2, 1));
        c.geoms[1] = Some((2, 1));
        c.out_w = 4;
        c.out_h = 1;
        c.latest[0] = Some(Buffer::new(vec![10; 6]));
        c.latest[1] = Some(Buffer::new(vec![20; 6]));
        let canvas = c.compose().unwrap();
        assert_eq!(&canvas[..6], &[10; 6]);
        assert_eq!(&canvas[6..], &[20; 6]);
    }
}
