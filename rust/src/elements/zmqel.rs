//! `zmqsink` / `zmqsrc` — the brokerless baseline transport (ZeroMQ
//! analog) used as the Fig 7 normalization denominator.
//!
//! Same EdgeFrame envelope as the MQTT elements so the comparison
//! isolates the transport, not the serialization.

use std::sync::mpsc::Receiver;
use std::time::Duration;

use crate::caps::Caps;
use crate::element::{Ctx, Element, Item, Workload};
use crate::metrics;
use crate::serial::wire::{LinkCodec, LinkDecoder};
use crate::serial::Codec;
use crate::util::{Error, Result};
use crate::zmq::{PubSocket, SubSocket, ZmqMessage};

/// Publish a stream on a bound ZMQ-style PUB socket.
pub struct ZmqSink {
    pub bind: String,
    pub topic: String,
    socket: Option<PubSocket>,
    caps: Option<Caps>,
    link: LinkCodec,
}

impl ZmqSink {
    pub fn new(bind: &str, topic: &str) -> Self {
        Self {
            bind: bind.to_string(),
            topic: topic.to_string(),
            socket: None,
            caps: None,
            link: LinkCodec::new(Codec::None, ""),
        }
    }

    /// `Codec::Auto` gets a per-link adaptive state (keyed by topic) that
    /// samples compression ratios into `codec.auto.zmqsink.<topic>.*`;
    /// `Codec::Delta`/`Auto` additionally count keyframes/deltas into
    /// `codec.delta.zmqsink.<topic>.*`.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        let interval = self.link.keyframe_interval();
        self.link = LinkCodec::new(codec, &format!("zmqsink.{}", self.topic))
            .with_keyframe_interval(interval);
        self
    }

    /// Frames per delta-chain keyframe period (`Codec::Delta`/`Auto`).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.link.set_keyframe_interval(interval);
        self
    }

    /// The configured codec (`Auto` reports the policy, not the per-frame
    /// resolution).
    pub fn codec(&self) -> Codec {
        self.link.codec()
    }

    /// Bound address (after start).
    pub fn addr(&self) -> Option<std::net::SocketAddr> {
        self.socket.as_ref().map(|s| s.addr())
    }
}

impl Element for ZmqSink {
    fn n_src_pads(&self) -> usize {
        0
    }

    /// Socket-bound (bind + fan-out writes): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        self.socket = Some(PubSocket::bind(&self.bind)?);
        Ok(())
    }

    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Caps(c) => {
                self.caps = Some(c);
                Ok(())
            }
            Item::Buffer(mut b) => {
                let sock =
                    self.socket.as_ref().ok_or_else(|| Error::element(&ctx.name, "not started"))?;
                b.meta.remote_base_universal = Some(ctx.clock.base_universal);
                // Zero-copy hop: header + shared (possibly in-place
                // deflated) payload fan out to all subscribers without
                // assembling a contiguous frame.
                let frame = self
                    .link
                    .encode(&b, self.caps.as_ref())
                    .map_err(|e| Error::element(&ctx.name, e))?;
                metrics::global().counter(&format!("zmqsink.{}", ctx.name)).add_bytes(frame.len() as u64);
                sock.send_frame(self.topic.as_bytes(), &frame);
                Ok(())
            }
            Item::Eos => Ok(()),
        }
    }
}

/// Subscribe to a ZMQ-style PUB socket.
pub struct ZmqSrc {
    pub connect: String,
    pub topic: String,
    rx: Option<Receiver<ZmqMessage>>,
    last_caps: Option<Caps>,
    decoder: LinkDecoder,
}

impl ZmqSrc {
    pub fn new(connect: &str, topic: &str) -> Self {
        Self {
            connect: connect.to_string(),
            topic: topic.to_string(),
            rx: None,
            last_caps: None,
            decoder: LinkDecoder::new(&format!("zmqsrc.{topic}")),
        }
    }
}

impl Element for ZmqSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }

    /// Socket-bound (connect retry loop + blocking receive): keep a thread.
    fn workload(&self) -> Workload {
        Workload::Blocking
    }

    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }

    fn start(&mut self, _ctx: &mut Ctx) -> Result<()> {
        // The publisher may not have bound yet (pipelines start in any
        // order); retry for a couple of seconds like zmq's reconnect.
        let deadline = std::time::Instant::now() + Duration::from_secs(3);
        let mut sock = loop {
            match SubSocket::connect(&self.connect) {
                Ok(s) => break s,
                Err(e) if std::time::Instant::now() < deadline => {
                    let _ = e;
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => return Err(e),
            }
        };
        sock.subscribe(self.topic.as_bytes())?;
        self.rx = Some(sock.into_channel(32));
        Ok(())
    }

    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        let Some(rx) = &self.rx else { return Ok(false) };
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok((_topic, payload)) => {
                // payload is the socket read's single allocation; decode
                // into a slice view of it (zero copy). Mid-chain delta
                // frames after loss decode to None and are dropped until
                // the publisher's next keyframe.
                let decoded =
                    self.decoder.decode(&payload).map_err(|e| Error::element(&ctx.name, e))?;
                metrics::global().counter(&format!("zmqsrc.{}", ctx.name)).add_bytes(payload.len() as u64);
                let Some((mut buf, caps)) = decoded else { return Ok(true) };
                if let Some(c) = caps {
                    if self.last_caps.as_ref() != Some(&c) {
                        ctx.push_caps(c.clone())?;
                        self.last_caps = Some(c);
                    }
                }
                if let (Some(remote_base), Some(pts)) = (buf.meta.remote_base_universal, buf.pts) {
                    buf.pts = Some(ctx.clock.remote_pts_to_local(remote_base, pts, 0));
                }
                ctx.push_buffer(buf)?;
                Ok(true)
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(!ctx.stopped()),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use crate::elements::basic::{AppSink, AppSrc};
    use crate::pipeline::Pipeline;
    use crate::tensor::{DType, TensorInfo, TensorsInfo};

    #[test]
    fn zmq_pubsub_pipeline_roundtrip() {
        let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[3]).unwrap());
        // Grab a free port (std listener closes its fd synchronously on
        // drop, unlike PubSocket whose accept thread lingers a few ms).
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };

        let mut pp = Pipeline::new();
        let (src, h) = AppSrc::new(8, Some(Caps::tensors(&info)));
        let s = pp.add("src", Box::new(src)).unwrap();
        let z = pp.add("pub", Box::new(ZmqSink::new(&addr, "t"))).unwrap();
        pp.link(s, z).unwrap();

        let mut sp = Pipeline::new();
        let (sink, rx) = AppSink::new(8);
        let zs = sp.add("sub", Box::new(ZmqSrc::new(&addr, "t"))).unwrap();
        let k = sp.add("sink", Box::new(sink)).unwrap();
        sp.link(zs, k).unwrap();

        let pr = pp.start().unwrap();
        std::thread::sleep(Duration::from_millis(150));
        let sr = sp.start().unwrap();
        std::thread::sleep(Duration::from_millis(300)); // sub connects

        h.push(Buffer::new(vec![9, 8, 7]).with_pts(0)).unwrap();
        // The first frame may race the subscription; push a few more.
        for _ in 0..5 {
            h.push(Buffer::new(vec![9, 8, 7]).with_pts(0)).unwrap();
            if let Ok(out) = rx.recv_timeout(Duration::from_millis(400)) {
                assert_eq!(&out.data[..], &[9, 8, 7]);
                drop(h);
                let _ = pr.stop(Duration::from_secs(5));
                let _ = sr.stop(Duration::from_secs(5));
                return;
            }
        }
        panic!("no zmq delivery");
    }
}
