//! EdgePipe: among-device AI stream pipelines.
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod buffer;
pub mod caps;
pub mod mqtt;
pub mod coordinator;
pub mod edge;
pub mod element;
pub mod elements;
pub mod metrics;
pub mod ntp;
pub mod pipeline;
pub mod runtime;
pub mod zmq;
pub mod clock;
pub mod serial;
pub mod tensor;
pub mod testkit;
pub mod util;
