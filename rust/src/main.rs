//! EdgePipe CLI — the launcher (`gst-launch` analog plus service tools).
//!
//! ```text
//! edgepipe run "<pipeline description>" [--secs N] [--artifacts DIR]
//! edgepipe broker [--bind 127.0.0.1:1883]
//! edgepipe serve --operation NAME --model MODEL [--port P] [--broker B] [--protocol tcp|mqtt-hybrid]
//! edgepipe inspect [ELEMENT]
//! edgepipe loc "<pipeline description>"          # §5.2 LoC counter
//! ```

use std::time::Duration;

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::pipeline::{parser, WaitOutcome};
use edgepipe::util::args::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    let code = match cmd.as_str() {
        "run" => cmd_run(&args),
        "broker" => cmd_broker(&args),
        "serve" => cmd_serve(&args),
        "inspect" => cmd_inspect(&args),
        "loc" => cmd_loc(&args),
        "version" | "--version" => {
            println!("edgepipe 0.1.0");
            0
        }
        other => {
            eprintln!("unknown command `{other}`");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage:\n  edgepipe run \"<desc>\" [--secs N] [--artifacts DIR]\n  \
         edgepipe broker [--bind ADDR]\n  \
         edgepipe serve --operation OP --model NAME [--port P] [--broker B] [--protocol tcp|mqtt-hybrid]\n  \
         edgepipe inspect [ELEMENT]\n  \
         edgepipe loc \"<desc>\""
    );
}

fn env_from(args: &Args) -> PipelineEnv {
    let mut env = PipelineEnv::default();
    if let Some(d) = args.get("artifacts") {
        env.artifacts_dir = d.to_string();
    }
    env
}

fn cmd_run(args: &Args) -> i32 {
    let Some(desc) = args.positional.first() else {
        eprintln!("run: missing pipeline description");
        return 2;
    };
    let registry = Registry::with_builtins();
    let env = env_from(args);
    let pipeline = match parser::parse(desc, &registry, &env) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("parse error: {e}");
            return 2;
        }
    };
    let running = match pipeline.start() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("start error: {e}");
            return 1;
        }
    };
    let secs = args.get_u64("secs", 0);
    let outcome = if secs > 0 {
        running.run_for(Duration::from_secs(secs))
    } else {
        running.wait_eos(Duration::from_secs(args.get_u64("timeout", 86400)))
    };
    report_outcome(outcome)
}

fn report_outcome(outcome: WaitOutcome) -> i32 {
    match outcome {
        WaitOutcome::Eos => {
            eprintln!("pipeline finished (EOS)");
            0
        }
        WaitOutcome::Error { element, message } => {
            eprintln!("pipeline error in `{element}`: {message}");
            1
        }
        WaitOutcome::Timeout => {
            eprintln!("pipeline timed out");
            1
        }
    }
}

fn cmd_broker(args: &Args) -> i32 {
    let bind = args.get_or("bind", "127.0.0.1:1883");
    match edgepipe::mqtt::Broker::start(bind) {
        Ok(broker) => {
            println!("mqtt broker on {}", broker.addr());
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("broker: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(op) = args.get("operation") else {
        eprintln!("serve: --operation required");
        return 2;
    };
    let Some(model) = args.get("model") else {
        eprintln!("serve: --model required");
        return 2;
    };
    let port = args.get_u64("port", 0);
    let protocol = args.get_or("protocol", "mqtt-hybrid");
    let broker = args.get_or("broker", "127.0.0.1:1883");
    let env = env_from(args);
    let desc = format!(
        "tensor_query_serversrc operation={op} port={port} protocol={protocol} broker={broker} model-label={model} ! \
         tensor_filter framework=pjrt model={model} ! tensor_query_serversink operation={op}"
    );
    println!("serving `{op}` with model `{model}` ({protocol})");
    let registry = Registry::with_builtins();
    match parser::parse(&desc, &registry, &env).and_then(|p| p.start()) {
        Ok(running) => {
            report_outcome(running.wait_eos(Duration::from_secs(args.get_u64("secs", 86400))))
        }
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

fn cmd_inspect(args: &Args) -> i32 {
    let registry = Registry::with_builtins();
    match args.positional.first() {
        None => {
            println!("available elements:");
            for k in registry.kinds() {
                println!("  {k}");
            }
            0
        }
        Some(kind) => {
            if registry.contains(kind) {
                println!("{kind}: registered (see rust/src/elements/ docs)");
                0
            } else {
                eprintln!("unknown element `{kind}`");
                1
            }
        }
    }
}

fn cmd_loc(args: &Args) -> i32 {
    let Some(desc) = args.positional.first() else {
        eprintln!("loc: missing description");
        return 2;
    };
    println!("{} pipeline tokens", parser::segment_count(desc));
    0
}
