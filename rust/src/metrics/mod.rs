//! Lightweight metrics: counters, rate meters, histograms, and the
//! process-level CPU/RSS sampling the paper's evaluation reports
//! (throughput, CPU usage, peak memory — §5.4).
//!
//! ## Sharded hot counters
//!
//! A plain [`Counter`] is a pair of atomics; at K workers hammering the
//! same counter per frame (`sched.polls`, `bytes_copied`, wake counts)
//! the cache line holding those atomics ping-pongs between cores — the
//! classic false-sharing/contention tax on the hot path. Counters
//! upgraded via [`Registry::sharded_counter`] (or
//! [`Counter::ensure_sharded`]) split their increments across
//! cache-line-padded per-thread shards: each writer picks a stable
//! thread-local slot and only ever touches its own line. Reads
//! ([`Counter::count`]/[`Counter::bytes`]) sum the base atomics plus all
//! shards, so the API — and every `metrics::dump`/bench reader — is
//! unchanged. The sum is **monotonic but not a linearizable snapshot**:
//! concurrent increments may or may not be included, exactly like the
//! relaxed single-atomic read before it. Increments recorded before an
//! upgrade stay in the base atomics and remain part of the sum, so
//! upgrading late never loses counts.
//!
//! Well-known counter families registered elsewhere: `sched.*` from the
//! work-stealing element scheduler (`tasks`/`parks`/`polls`, the
//! `local_hits`/`injector_hits`/`steals` dequeue split plus
//! `stolen_tasks` batch-transfer totals, and `queue_locks`/`lock_waits`
//! ready-queue contention — see [`crate::element::sched`]; all sharded),
//! `inbox.wakes` consumer/producer waker firings from the link inboxes
//! (sharded — see [`crate::element::inbox::WakeBatch`]),
//! `codec.auto.<link>.*` from the adaptive
//! wire codec, `codec.delta.<link>.{keyframes,deltas,bytes_saved}` from
//! delta-coded link encoders plus `codec.delta.<link>.resyncs` from
//! their decoders (chain breaks observed after loss/reorder — see
//! [`crate::serial::wire::LinkDecoder`]), `appsink.<name>` delivery
//! counters,
//! `query.<name>.{retries,hedges,hedge_wins,reroutes,breaker_open,frames_dropped}`
//! plus the `query.<name>.rtt_us` histogram from the resilient offload
//! client ([`crate::elements::QueryClient`]), and
//! `batch.<model>.{flushes_full,flushes_timer}` counters plus the
//! `batch.<model>.{size,occupancy}` histograms from the cross-pipeline
//! inference batcher ([`crate::runtime::BatchCollector`]), and
//! `broker.shard<i>.{publishes,matches,lock_waits}` from the sharded
//! MQTT broker ([`crate::mqtt::broker::Router`]): per-shard PUBLISH
//! count, matched subscriber deliveries (post-dedup), and shard-mutex
//! acquisitions that had to wait — the contention topic-hash sharding
//! exists to eliminate.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Shard count of an upgraded [`Counter`] (power of two; the slot mask).
/// More shards than typical worker counts so K workers rarely collide.
pub(crate) const COUNTER_SHARDS: usize = 16;

/// One per-thread lane of a sharded counter, padded to its own pair of
/// cache lines (128 B covers adjacent-line prefetching on x86).
#[derive(Debug, Default)]
#[repr(align(128))]
struct CounterShard {
    n: AtomicU64,
    bytes: AtomicU64,
}

/// Stable per-thread shard slot: threads round-robin onto
/// `COUNTER_SHARDS` lanes at first use, so each worker keeps hitting the
/// same (exclusive in the common K <= shards case) cache line.
pub(crate) fn shard_slot() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: usize = NEXT.fetch_add(1, Ordering::Relaxed) & (COUNTER_SHARDS - 1);
    }
    SLOT.with(|s| *s)
}

/// Monotonic event counter.
///
/// Plain by default (one atomic pair); [`Counter::ensure_sharded`]
/// upgrades it in place to per-thread padded shards for hot-path use —
/// see the module docs. Reads always return base + Σ shards, so both
/// forms share one API.
#[derive(Debug, Default)]
pub struct Counter {
    n: AtomicU64,
    bytes: AtomicU64,
    shards: OnceLock<Box<[CounterShard]>>,
}

impl Counter {
    /// Upgrade to per-thread sharded increments (idempotent; safe while
    /// other threads hold the same `Arc<Counter>` — pre-upgrade counts
    /// stay in the base atomics and remain part of every sum).
    pub fn ensure_sharded(&self) {
        self.shards.get_or_init(|| (0..COUNTER_SHARDS).map(|_| CounterShard::default()).collect());
    }

    pub fn inc(&self) {
        match self.shards.get() {
            Some(s) => s[shard_slot()].n.fetch_add(1, Ordering::Relaxed),
            None => self.n.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Bump the event count by `n` (batched increment — one atomic op
    /// for a whole fan-out instead of one per subscriber).
    pub fn add(&self, n: u64) {
        match self.shards.get() {
            Some(s) => s[shard_slot()].n.fetch_add(n, Ordering::Relaxed),
            None => self.n.fetch_add(n, Ordering::Relaxed),
        };
    }

    pub fn add_bytes(&self, b: u64) {
        match self.shards.get() {
            Some(s) => {
                let sh = &s[shard_slot()];
                sh.n.fetch_add(1, Ordering::Relaxed);
                sh.bytes.fetch_add(b, Ordering::Relaxed);
            }
            None => {
                self.n.fetch_add(1, Ordering::Relaxed);
                self.bytes.fetch_add(b, Ordering::Relaxed);
            }
        }
    }

    /// Total events: base + every shard. Monotonic, not a linearizable
    /// snapshot (concurrent increments may land either side of the sum).
    pub fn count(&self) -> u64 {
        let base = self.n.load(Ordering::Relaxed);
        match self.shards.get() {
            Some(s) => base + s.iter().map(|sh| sh.n.load(Ordering::Relaxed)).sum::<u64>(),
            None => base,
        }
    }

    pub fn bytes(&self) -> u64 {
        let base = self.bytes.load(Ordering::Relaxed);
        match self.shards.get() {
            Some(s) => base + s.iter().map(|sh| sh.bytes.load(Ordering::Relaxed)).sum::<u64>(),
            None => base,
        }
    }
}

/// Value distribution (lock-guarded vec; fine for bench-scale volumes).
#[derive(Debug, Default)]
pub struct Histogram {
    values: Mutex<Vec<f64>>,
}

/// Summary statistics of a histogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        self.values.lock().unwrap().push(v);
    }

    pub fn summary(&self) -> Option<Summary> {
        let mut v = self.values.lock().unwrap().clone();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let q = |p: f64| v[((n - 1) as f64 * p).round() as usize];
        Some(Summary {
            count: n,
            mean: v.iter().sum::<f64>() / n as f64,
            min: v[0],
            p50: q(0.5),
            p95: q(0.95),
            max: v[n - 1],
        })
    }

    pub fn reset(&self) {
        self.values.lock().unwrap().clear();
    }
}

/// Global registry (elements record, benches read).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// [`Registry::counter`] upgraded for hot paths: per-thread padded
    /// shards, summed on read (see the module docs). Returns the SAME
    /// instance `counter(name)` returns — callers that grabbed the plain
    /// handle earlier observe the upgrade and keep every count.
    pub fn sharded_counter(&self, name: &str) -> Arc<Counter> {
        let c = self.counter(name);
        c.ensure_sharded();
        c
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn observe(&self, name: &str, v: f64) {
        self.histogram(name).observe(v);
    }

    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.histograms.lock().unwrap().get(name).and_then(|h| h.summary())
    }

    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.histograms.lock().unwrap().clear();
    }

    pub fn counter_names(&self) -> Vec<String> {
        self.counters.lock().unwrap().keys().cloned().collect()
    }
}

/// Process-wide registry.
pub fn global() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::default)
}

// ---------------------------------------------------------------------------
// /proc sampling (CPU %, peak RSS) — the paper's overhead metrics.
// ---------------------------------------------------------------------------

fn read_proc_stat_jiffies() -> Option<u64> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // utime is field 14, stime 15 (1-indexed), after the comm field which
    // may contain spaces — skip past the closing paren first.
    let rest = &stat[stat.rfind(')')? + 2..];
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some(utime + stime)
}

/// Peak resident set size in KiB (VmHWM).
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

/// Current resident set size in KiB (VmRSS).
pub fn current_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

/// Resident thread count of this process (`Threads:` in
/// /proc/self/status) — the density metric the worker-pool scheduler
/// optimises (threads should scale with K workers, not with
/// pipelines x elements).
pub fn thread_count() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("Threads:") {
            return rest.trim().parse().ok();
        }
    }
    None
}

/// CPU usage sampler: percentage of one core used between calls.
pub struct CpuSampler {
    last_jiffies: u64,
    last_at: Instant,
    hz: f64,
}

impl CpuSampler {
    pub fn start() -> Self {
        Self {
            last_jiffies: read_proc_stat_jiffies().unwrap_or(0),
            last_at: Instant::now(),
            hz: 100.0, // USER_HZ on Linux
        }
    }

    /// CPU% (of one core) since the previous call.
    pub fn sample(&mut self) -> f64 {
        let j = read_proc_stat_jiffies().unwrap_or(self.last_jiffies);
        let now = Instant::now();
        let dj = (j - self.last_jiffies) as f64 / self.hz;
        let dt = now.duration_since(self.last_at).as_secs_f64();
        self.last_jiffies = j;
        self.last_at = now;
        if dt <= 0.0 {
            0.0
        } else {
            100.0 * dj / dt
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add_bytes(100);
        assert_eq!(c.count(), 2);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn sharded_counter_sums_across_threads() {
        let c = Arc::new(Counter::default());
        c.ensure_sharded();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c2.inc();
                }
                c2.add(5);
                c2.add_bytes(7);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.count(), 8 * (1000 + 5 + 1));
        assert_eq!(c.bytes(), 8 * 7);
    }

    #[test]
    fn late_shard_upgrade_keeps_base_counts() {
        let r = Registry::default();
        let plain = r.counter("hot");
        plain.inc();
        plain.add_bytes(3);
        // Upgrade through the registry: same instance, counts preserved,
        // and the pre-upgrade handle routes new increments to shards.
        let sharded = r.sharded_counter("hot");
        assert!(Arc::ptr_eq(&plain, &sharded));
        plain.inc();
        sharded.add(2);
        assert_eq!(plain.count(), 2 + 2 + 1); // 2 pre-upgrade (inc+add_bytes), inc, add(2)
        assert_eq!(sharded.bytes(), 3);
        // Idempotent.
        r.sharded_counter("hot").inc();
        assert_eq!(plain.count(), 6);
    }

    #[test]
    fn shard_slot_is_stable_and_in_range() {
        let a = shard_slot();
        assert_eq!(a, shard_slot());
        assert!(a < COUNTER_SHARDS);
        let other = std::thread::spawn(shard_slot).join().unwrap();
        assert!(other < COUNTER_SHARDS);
    }

    #[test]
    fn histogram_summary() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        let s = h.summary().unwrap();
        assert_eq!(s.count, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
    }

    #[test]
    fn empty_histogram_no_summary() {
        assert!(Histogram::default().summary().is_none());
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").count(), 2);
        r.observe("h", 1.0);
        assert_eq!(r.summary("h").unwrap().count, 1);
        assert!(r.summary("missing").is_none());
    }

    #[test]
    fn proc_sampling_works_on_linux() {
        assert!(peak_rss_kb().unwrap() > 0);
        assert!(current_rss_kb().unwrap() > 0);
        assert!(thread_count().unwrap() >= 1);
        let mut s = CpuSampler::start();
        // burn a little CPU
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_add(i * i);
        }
        std::hint::black_box(x);
        let pct = s.sample();
        assert!(pct >= 0.0);
    }

    #[test]
    fn global_registry_is_singleton() {
        global().counter("g").inc();
        assert!(global().counter_names().contains(&"g".to_string()));
    }
}
