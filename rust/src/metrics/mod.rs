//! Lightweight metrics: counters, rate meters, histograms, and the
//! process-level CPU/RSS sampling the paper's evaluation reports
//! (throughput, CPU usage, peak memory — §5.4).
//!
//! Well-known counter families registered elsewhere: `sched.*` from the
//! work-stealing element scheduler (`tasks`/`parks`/`polls`, the
//! `local_hits`/`injector_hits`/`steals` dequeue split, and
//! `queue_locks`/`lock_waits` ready-queue contention — see
//! [`crate::element::sched`]), `codec.auto.<link>.*` from the adaptive
//! wire codec, `codec.delta.<link>.{keyframes,deltas,bytes_saved}` from
//! delta-coded link encoders plus `codec.delta.<link>.resyncs` from
//! their decoders (chain breaks observed after loss/reorder — see
//! [`crate::serial::wire::LinkDecoder`]), `appsink.<name>` delivery
//! counters,
//! `query.<name>.{retries,hedges,hedge_wins,reroutes,breaker_open,frames_dropped}`
//! plus the `query.<name>.rtt_us` histogram from the resilient offload
//! client ([`crate::elements::QueryClient`]), and
//! `batch.<model>.{flushes_full,flushes_timer}` counters plus the
//! `batch.<model>.{size,occupancy}` histograms from the cross-pipeline
//! inference batcher ([`crate::runtime::BatchCollector`]), and
//! `broker.shard<i>.{publishes,matches,lock_waits}` from the sharded
//! MQTT broker ([`crate::mqtt::broker::Router`]): per-shard PUBLISH
//! count, matched subscriber deliveries (post-dedup), and shard-mutex
//! acquisitions that had to wait — the contention topic-hash sharding
//! exists to eliminate.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    n: AtomicU64,
    bytes: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    /// Bump the event count by `n` (batched increment — one atomic op
    /// for a whole fan-out instead of one per subscriber).
    pub fn add(&self, n: u64) {
        self.n.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_bytes(&self, b: u64) {
        self.n.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(b, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Value distribution (lock-guarded vec; fine for bench-scale volumes).
#[derive(Debug, Default)]
pub struct Histogram {
    values: Mutex<Vec<f64>>,
}

/// Summary statistics of a histogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        self.values.lock().unwrap().push(v);
    }

    pub fn summary(&self) -> Option<Summary> {
        let mut v = self.values.lock().unwrap().clone();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let q = |p: f64| v[((n - 1) as f64 * p).round() as usize];
        Some(Summary {
            count: n,
            mean: v.iter().sum::<f64>() / n as f64,
            min: v[0],
            p50: q(0.5),
            p95: q(0.95),
            max: v[n - 1],
        })
    }

    pub fn reset(&self) {
        self.values.lock().unwrap().clear();
    }
}

/// Global registry (elements record, benches read).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn observe(&self, name: &str, v: f64) {
        self.histogram(name).observe(v);
    }

    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.histograms.lock().unwrap().get(name).and_then(|h| h.summary())
    }

    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.histograms.lock().unwrap().clear();
    }

    pub fn counter_names(&self) -> Vec<String> {
        self.counters.lock().unwrap().keys().cloned().collect()
    }
}

/// Process-wide registry.
pub fn global() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::default)
}

// ---------------------------------------------------------------------------
// /proc sampling (CPU %, peak RSS) — the paper's overhead metrics.
// ---------------------------------------------------------------------------

fn read_proc_stat_jiffies() -> Option<u64> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // utime is field 14, stime 15 (1-indexed), after the comm field which
    // may contain spaces — skip past the closing paren first.
    let rest = &stat[stat.rfind(')')? + 2..];
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some(utime + stime)
}

/// Peak resident set size in KiB (VmHWM).
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

/// Current resident set size in KiB (VmRSS).
pub fn current_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

/// Resident thread count of this process (`Threads:` in
/// /proc/self/status) — the density metric the worker-pool scheduler
/// optimises (threads should scale with K workers, not with
/// pipelines x elements).
pub fn thread_count() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("Threads:") {
            return rest.trim().parse().ok();
        }
    }
    None
}

/// CPU usage sampler: percentage of one core used between calls.
pub struct CpuSampler {
    last_jiffies: u64,
    last_at: Instant,
    hz: f64,
}

impl CpuSampler {
    pub fn start() -> Self {
        Self {
            last_jiffies: read_proc_stat_jiffies().unwrap_or(0),
            last_at: Instant::now(),
            hz: 100.0, // USER_HZ on Linux
        }
    }

    /// CPU% (of one core) since the previous call.
    pub fn sample(&mut self) -> f64 {
        let j = read_proc_stat_jiffies().unwrap_or(self.last_jiffies);
        let now = Instant::now();
        let dj = (j - self.last_jiffies) as f64 / self.hz;
        let dt = now.duration_since(self.last_at).as_secs_f64();
        self.last_jiffies = j;
        self.last_at = now;
        if dt <= 0.0 {
            0.0
        } else {
            100.0 * dj / dt
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add_bytes(100);
        assert_eq!(c.count(), 2);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn histogram_summary() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        let s = h.summary().unwrap();
        assert_eq!(s.count, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
    }

    #[test]
    fn empty_histogram_no_summary() {
        assert!(Histogram::default().summary().is_none());
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").count(), 2);
        r.observe("h", 1.0);
        assert_eq!(r.summary("h").unwrap().count, 1);
        assert!(r.summary("missing").is_none());
    }

    #[test]
    fn proc_sampling_works_on_linux() {
        assert!(peak_rss_kb().unwrap() > 0);
        assert!(current_rss_kb().unwrap() > 0);
        assert!(thread_count().unwrap() >= 1);
        let mut s = CpuSampler::start();
        // burn a little CPU
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_add(i * i);
        }
        std::hint::black_box(x);
        let pct = s.sample();
        assert!(pct >= 0.0);
    }

    #[test]
    fn global_registry_is_singleton() {
        global().counter("g").inc();
        assert!(global().counter_names().contains(&"g".to_string()));
    }
}
