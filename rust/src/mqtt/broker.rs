//! In-repo MQTT 3.1.1 broker — the discovery/relay substrate the paper
//! assumes ("users need to deploy an MQTT broker service", §3).
//!
//! Feature set sized to the among-device protocols: QoS 0/1 PUBLISH
//! (QoS 1 acknowledged to the publisher; delivery to subscribers is QoS 0),
//! retained messages (service advertisements), last-will (server-death
//! detection → R4 failover), topic wildcards, keep-alive enforcement.
//! `$`-prefixed topics follow §4.7.2: both the live fan-out ([`route`])
//! and retained delivery go through [`topic::matches`], which hides them
//! from filters that start with a wildcard — `#`/`+` subscribers never
//! see broker-internal namespaces like `$SYS`.
//!
//! One thread per connection + one writer thread per connection. A
//! published frame is encoded **once**: `route` builds the outbound
//! PUBLISH head a single time and every subscriber's writer emits
//! `head ++ payload` with a vectored write, where the payload is the
//! shared slice view produced by the connection's packet read — zero
//! broker-side payload copies regardless of subscriber count.
//!
//! Compression is end-to-end, never hop-by-hop here: a publisher using
//! `Codec::Zlib`/`Codec::Auto` deflates each frame exactly once, and the
//! broker fans the *compressed* body out as the same shared bytes — it
//! never inflates, re-deflates, or even parses the EdgeFrame payload
//! (asserted by `bench_wirepath`'s fan-out deflate-ops audit).

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::buffer::Bytes;
use crate::mqtt::packet::{self, LastWill, Packet, CONNACK_ACCEPTED};
use crate::mqtt::topic;
use crate::util::{write_all_vectored, Error, Result};
use crate::{log_debug, log_info, log_warn};

/// Message queued to a connection's writer thread.
enum OutMsg {
    Control(Packet),
    /// Fan-out publish: pre-encoded PUBLISH head + payload, both shared
    /// across every subscriber of the topic.
    Pub { head: Bytes, payload: Bytes },
    Close,
}

struct Session {
    #[allow(dead_code)]
    client_id: String,
    outbox: SyncSender<OutMsg>,
    subs: Vec<(String, u8)>,
    will: Option<LastWill>,
}

#[derive(Debug, Default, Clone)]
pub struct BrokerStats {
    pub connects: u64,
    pub disconnects: u64,
    pub published: u64,
    pub delivered: u64,
    pub dropped_slow: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

struct State {
    sessions: HashMap<u64, Session>,
    retained: HashMap<String, Bytes>,
    stats: BrokerStats,
}

/// Broker configuration.
#[derive(Debug, Clone)]
pub struct BrokerConfig {
    /// Per-connection outbound queue depth; overflow drops the message for
    /// that subscriber (slow-consumer policy).
    pub outbox_depth: usize,
    /// Fallback read timeout when a client requests keep_alive = 0.
    pub idle_timeout: Duration,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        Self { outbox_depth: 64, idle_timeout: Duration::from_secs(3600) }
    }
}

/// A running broker; dropping it stops the listener.
pub struct Broker {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    state: Arc<Mutex<State>>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Broker {
    /// Bind and start. Use port 0 for an ephemeral port.
    pub fn start(bind: &str) -> Result<Broker> {
        Broker::start_with(bind, BrokerConfig::default())
    }

    pub fn start_with(bind: &str, cfg: BrokerConfig) -> Result<Broker> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::Mqtt(format!("bind {bind}: {e}")))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let state = Arc::new(Mutex::new(State {
            sessions: HashMap::new(),
            retained: HashMap::new(),
            stats: BrokerStats::default(),
        }));
        let conn_seq = Arc::new(AtomicU64::new(1));

        let t_shutdown = shutdown.clone();
        let t_state = state.clone();
        let cfg = Arc::new(cfg);
        let accept_thread = std::thread::Builder::new()
            .name("mqtt-broker-accept".into())
            .spawn(move || {
                log_info!("mqtt.broker", "listening on {addr}");
                while !t_shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            let id = conn_seq.fetch_add(1, Ordering::Relaxed);
                            let st = t_state.clone();
                            let sd = t_shutdown.clone();
                            let c = cfg.clone();
                            let _ = std::thread::Builder::new()
                                .name(format!("mqtt-conn-{id}"))
                                .spawn(move || {
                                    if let Err(e) = serve_conn(id, stream, st, sd, &c) {
                                        log_debug!("mqtt.broker", "conn {id} ({peer}): {e}");
                                    }
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => {
                            log_warn!("mqtt.broker", "accept: {e}");
                            break;
                        }
                    }
                }
            })
            .expect("spawn broker accept thread");
        Ok(Broker { addr, shutdown, state, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> BrokerStats {
        self.state.lock().unwrap().stats.clone()
    }

    /// Number of live sessions (for tests).
    pub fn session_count(&self) -> usize {
        self.state.lock().unwrap().sessions.len()
    }

    /// Retained topics currently stored (for tests).
    pub fn retained_topics(&self) -> Vec<String> {
        let mut v: Vec<String> = self.state.lock().unwrap().retained.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Close all sessions so conn threads unblock.
        let sessions: Vec<SyncSender<OutMsg>> = {
            let st = self.state.lock().unwrap();
            st.sessions.values().map(|s| s.outbox.clone()).collect()
        };
        for s in sessions {
            let _ = s.try_send(OutMsg::Close);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Broker {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Build the shared outbound PUBLISH (head, payload) pair for a delivery.
fn pub_msg(topic_name: &str, payload: &Bytes, retain: bool) -> Option<OutMsg> {
    let head = packet::publish_head(topic_name, 0, retain, false, None, payload.len()).ok()?;
    Some(OutMsg::Pub { head: Bytes::from(head), payload: payload.clone() })
}

fn route(state: &Mutex<State>, topic_name: &str, payload: &Bytes, retain: bool) {
    let mut st = state.lock().unwrap();
    st.stats.published += 1;
    st.stats.bytes_in += payload.len() as u64;
    if retain {
        if payload.is_empty() {
            st.retained.remove(topic_name);
        } else {
            st.retained.insert(topic_name.to_string(), payload.clone());
        }
    }
    // Encode the outbound head ONCE; all subscribers share head + payload.
    let Some(OutMsg::Pub { head, payload: shared }) = pub_msg(topic_name, payload, false) else {
        return;
    };
    let mut delivered = 0u64;
    let mut dropped = 0u64;
    let mut bytes = 0u64;
    for sess in st.sessions.values() {
        if sess.subs.iter().any(|(f, _)| topic::matches(f, topic_name)) {
            match sess.outbox.try_send(OutMsg::Pub {
                head: head.clone(),
                payload: shared.clone(),
            }) {
                Ok(()) => {
                    delivered += 1;
                    bytes += shared.len() as u64;
                }
                Err(TrySendError::Full(_)) => dropped += 1,
                Err(TrySendError::Disconnected(_)) => {}
            }
        }
    }
    st.stats.delivered += delivered;
    st.stats.dropped_slow += dropped;
    st.stats.bytes_out += bytes;
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<OutMsg>) {
    for msg in rx {
        let ok = match msg {
            OutMsg::Close => break,
            OutMsg::Control(p) => match p.encode() {
                Ok(w) => {
                    use std::io::Write;
                    stream.write_all(&w).is_ok()
                }
                Err(_) => continue,
            },
            OutMsg::Pub { head, payload } => {
                write_all_vectored(&mut stream, &[head.as_slice(), payload.as_slice()]).is_ok()
            }
        };
        if !ok {
            break;
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

fn serve_conn(
    id: u64,
    mut stream: TcpStream,
    state: Arc<Mutex<State>>,
    shutdown: Arc<AtomicBool>,
    cfg: &BrokerConfig,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let connect = Packet::read(&mut stream)?;
    let (client_id, keep_alive, will) = match connect {
        Packet::Connect { client_id, keep_alive, will, .. } => (client_id, keep_alive, will),
        other => return Err(Error::Mqtt(format!("expected CONNECT, got {other:?}"))),
    };
    // Keep-alive enforcement: 1.5x grace per spec.
    let timeout = if keep_alive == 0 {
        cfg.idle_timeout
    } else {
        Duration::from_millis(keep_alive as u64 * 1500)
    };
    stream.set_read_timeout(Some(timeout))?;

    let (tx, rx) = sync_channel::<OutMsg>(cfg.outbox_depth);
    let wstream = stream.try_clone()?;
    let writer = std::thread::Builder::new()
        .name(format!("mqtt-wr-{id}"))
        .spawn(move || writer_loop(wstream, rx))
        .expect("spawn writer");

    {
        let mut st = state.lock().unwrap();
        st.stats.connects += 1;
        st.sessions.insert(
            id,
            Session { client_id: client_id.clone(), outbox: tx.clone(), subs: Vec::new(), will },
        );
    }
    let _ = tx.send(OutMsg::Control(Packet::ConnAck {
        session_present: false,
        code: CONNACK_ACCEPTED,
    }));
    log_debug!("mqtt.broker", "conn {id}: client `{client_id}` connected");

    let mut clean_disconnect = false;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let pkt = match Packet::read(&mut stream) {
            Ok(p) => p,
            Err(Error::Io(ref e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                log_debug!("mqtt.broker", "conn {id}: keep-alive timeout");
                break;
            }
            Err(_) => break,
        };
        match pkt {
            Packet::Publish { topic: t, payload, qos, retain, packet_id, .. } => {
                if topic::validate_name(&t).is_err() {
                    break;
                }
                // `payload` is a shared view into this connection's packet
                // read; route() fans it out without duplicating it.
                route(&state, &t, &payload, retain);
                if qos == 1 {
                    if let Some(pid) = packet_id {
                        let _ = tx.send(OutMsg::Control(Packet::PubAck { packet_id: pid }));
                    }
                }
            }
            Packet::Subscribe { packet_id, filters } => {
                let mut codes = Vec::with_capacity(filters.len());
                let mut retained_out: Vec<(String, Bytes)> = Vec::new();
                {
                    let mut st = state.lock().unwrap();
                    for (f, qos) in &filters {
                        if topic::validate_filter(f).is_err() {
                            codes.push(0x80);
                            continue;
                        }
                        codes.push((*qos).min(1));
                        for (rt, rp) in &st.retained {
                            if topic::matches(f, rt) {
                                retained_out.push((rt.clone(), rp.clone()));
                            }
                        }
                        if let Some(sess) = st.sessions.get_mut(&id) {
                            sess.subs.retain(|(ef, _)| ef != f);
                            sess.subs.push((f.clone(), (*qos).min(1)));
                        }
                    }
                }
                let _ = tx.send(OutMsg::Control(Packet::SubAck { packet_id, codes }));
                for (rt, rp) in retained_out {
                    if let Some(msg) = pub_msg(&rt, &rp, true) {
                        let _ = tx.send(msg);
                    }
                }
            }
            Packet::Unsubscribe { packet_id, filters } => {
                {
                    let mut st = state.lock().unwrap();
                    if let Some(sess) = st.sessions.get_mut(&id) {
                        sess.subs.retain(|(f, _)| !filters.contains(f));
                    }
                }
                let _ = tx.send(OutMsg::Control(Packet::UnsubAck { packet_id }));
            }
            Packet::PingReq => {
                let _ = tx.send(OutMsg::Control(Packet::PingResp));
            }
            Packet::Disconnect => {
                clean_disconnect = true;
                break;
            }
            Packet::PubAck { .. } => {}
            other => {
                log_warn!("mqtt.broker", "conn {id}: unexpected {other:?}");
                break;
            }
        }
    }

    // Teardown: remove session, fire will if unclean.
    let will = {
        let mut st = state.lock().unwrap();
        st.stats.disconnects += 1;
        st.sessions.remove(&id).and_then(|s| s.will)
    };
    if !clean_disconnect {
        if let Some(w) = will {
            log_debug!("mqtt.broker", "conn {id}: firing last-will on `{}`", w.topic);
            route(&state, &w.topic, &Bytes::from(w.payload), w.retain);
        }
    }
    let _ = tx.send(OutMsg::Close);
    drop(tx);
    let _ = writer.join();
    Ok(())
}
