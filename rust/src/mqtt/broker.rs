//! In-repo MQTT 3.1.1 broker — the discovery/relay substrate the paper
//! assumes ("users need to deploy an MQTT broker service", §3).
//!
//! Feature set sized to the among-device protocols: QoS 0/1 PUBLISH
//! (QoS 1 acknowledged to the publisher; delivery to subscribers is QoS 0),
//! retained messages (service advertisements), last-will (server-death
//! detection → R4 failover), topic wildcards, keep-alive enforcement.
//! `$`-prefixed topics follow §4.7.2: both the live fan-out and retained
//! delivery go through the [`trie`] walks, which hide them from filters
//! that start with a wildcard — `#`/`+` subscribers never see
//! broker-internal namespaces like `$SYS`.
//!
//! ## Sharded routing core
//!
//! All subscription and retained state lives in a [`Router`]: N shards
//! (`EDGEPIPE_BROKER_SHARDS`, default `min(available_parallelism, 8)`),
//! each holding a wildcard-aware subscription [`trie::SubTrie`] and a
//! retained-topic [`trie::RetainedTrie`] behind its own mutex. A topic's
//! shard is the hash of its FIRST level, so a PUBLISH locks exactly one
//! shard and matches in O(topic depth) — publishes to unrelated topic
//! namespaces never contend on a common lock, and per-publish cost stays
//! flat in the total number of subscriptions (the pre-trie broker walked
//! every session's filter list under one global mutex). Filters whose
//! first level is a literal live only in that level's shard; filters
//! starting with `+`/`#` are replicated into every shard at SUBSCRIBE
//! time (a per-subscription cost) so the publish path still consults a
//! single shard. Retained lookups for a new subscription walk the filter
//! down the owning shard's retained trie (all shards for a
//! wildcard-leading filter) instead of scanning every retained topic.
//!
//! Session metadata (client id, outbox, last-will, filter list) sits in a
//! separate control-plane map touched only by connect/subscribe/teardown,
//! never by PUBLISH. Per-shard counters land in the global metrics
//! registry as `broker.shard<i>.{publishes,matches,lock_waits}`.
//!
//! One thread per connection + one writer thread per connection. A
//! published frame is encoded **once**: [`Router::publish`] builds the
//! outbound PUBLISH head a single time and every subscriber's writer
//! emits `head ++ payload` with a vectored write, where the payload is
//! the shared slice view produced by the connection's packet read — zero
//! broker-side payload copies regardless of subscriber count.
//!
//! Compression is end-to-end, never hop-by-hop here: a publisher using
//! `Codec::Zlib`/`Codec::Auto` deflates each frame exactly once, and the
//! broker fans the *compressed* body out as the same shared bytes — it
//! never inflates, re-deflates, or even parses the EdgeFrame payload
//! (asserted by `bench_wirepath`'s fan-out deflate-ops audit, which runs
//! against a multi-shard broker).

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::buffer::Bytes;
use crate::metrics::{self, Counter};
use crate::mqtt::packet::{self, LastWill, Packet, CONNACK_ACCEPTED};
use crate::mqtt::topic;
use crate::mqtt::trie::{Retained, RetainedTrie, SubTrie};
use crate::util::{write_all_vectored, Error, Result};
use crate::{log_debug, log_info, log_warn};

/// Message queued to a connection's writer thread.
pub enum OutMsg {
    Control(Packet),
    /// Fan-out publish: pre-encoded PUBLISH head + payload, both shared
    /// across every subscriber of the topic.
    Pub { head: Bytes, payload: Bytes },
    Close,
}

#[derive(Debug, Default, Clone)]
pub struct BrokerStats {
    pub connects: u64,
    pub disconnects: u64,
    pub published: u64,
    pub delivered: u64,
    pub dropped_slow: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// One subscription entry stored in a shard's trie.
struct SubEntry {
    conn: u64,
    outbox: SyncSender<OutMsg>,
}

/// Shard-local routing state: the wildcard trie + retained store for the
/// topics hashing here, plus this shard's slice of the publish stats.
#[derive(Default)]
struct ShardState {
    subs: SubTrie<SubEntry>,
    retained: RetainedTrie,
    published: u64,
    delivered: u64,
    dropped_slow: u64,
    bytes_in: u64,
    bytes_out: u64,
}

struct Shard {
    state: Mutex<ShardState>,
    publishes: Arc<Counter>,
    matches: Arc<Counter>,
    lock_waits: Arc<Counter>,
}

impl Shard {
    fn new(idx: usize) -> Shard {
        let g = metrics::global();
        Shard {
            state: Mutex::new(ShardState::default()),
            publishes: g.counter(&format!("broker.shard{idx}.publishes")),
            matches: g.counter(&format!("broker.shard{idx}.matches")),
            lock_waits: g.counter(&format!("broker.shard{idx}.lock_waits")),
        }
    }

    /// Counted shard lock: a miss on the uncontended fast path records a
    /// `broker.shard<i>.lock_waits` tick — the contention sharding
    /// exists to eliminate.
    fn lock(&self) -> MutexGuard<'_, ShardState> {
        match self.state.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.lock_waits.inc();
                self.state.lock().unwrap_or_else(|p| p.into_inner())
            }
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }
}

/// Control-plane record for one connection; never touched by PUBLISH.
struct SessionMeta {
    #[allow(dead_code)]
    client_id: String,
    outbox: SyncSender<OutMsg>,
    subs: Vec<(String, u8)>,
    will: Option<LastWill>,
}

/// The sharded pub/sub routing core. [`Broker`] wraps it with TCP
/// connection handling; benches and tests drive it directly to measure
/// matching/fan-out cost without paying for 100k real sockets.
pub struct Router {
    shards: Vec<Shard>,
    sessions: Mutex<HashMap<u64, SessionMeta>>,
    connects: AtomicU64,
    disconnects: AtomicU64,
}

/// FNV-1a over a topic/filter's first level — the shard key.
fn level_hash(level: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in level.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Build the shared outbound PUBLISH (head, payload) pair for a delivery.
fn pub_msg(topic_name: &str, payload: &Bytes, retain: bool) -> Option<OutMsg> {
    let head = packet::publish_head(topic_name, 0, retain, false, None, payload.len()).ok()?;
    Some(OutMsg::Pub { head: Bytes::from(head), payload: payload.clone() })
}

impl Router {
    /// A router with `shards` state shards (clamped to >= 1). Pass 0 to
    /// resolve from `EDGEPIPE_BROKER_SHARDS`, defaulting to
    /// `min(available_parallelism, 8)`.
    pub fn new(shards: usize) -> Router {
        let n = if shards == 0 { default_shards() } else { shards };
        Router {
            shards: (0..n.max(1)).map(Shard::new).collect(),
            sessions: Mutex::new(HashMap::new()),
            connects: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, topic_or_filter: &str) -> usize {
        (level_hash(topic::first_level(topic_or_filter)) % self.shards.len() as u64) as usize
    }

    /// Shards a filter lives in: one for a literal first level, all of
    /// them for a wildcard-leading filter (`+`/`#`) — replication at
    /// SUBSCRIBE time keeps the publish path single-shard.
    fn filter_shards(&self, filter: &str) -> std::ops::Range<usize> {
        match topic::first_level(filter) {
            "+" | "#" => 0..self.shards.len(),
            lit => {
                let s = (level_hash(lit) % self.shards.len() as u64) as usize;
                s..s + 1
            }
        }
    }

    /// Register a connection. `id` must be unique for the router's life.
    pub fn session_open(
        &self,
        id: u64,
        client_id: String,
        outbox: SyncSender<OutMsg>,
        will: Option<LastWill>,
    ) {
        self.connects.fetch_add(1, Ordering::Relaxed);
        self.sessions
            .lock()
            .unwrap()
            .insert(id, SessionMeta { client_id, outbox, subs: Vec::new(), will });
    }

    /// Tear a connection down: drop every subscription from the shard
    /// tries and return the last-will (if any) for the caller to fire.
    pub fn session_close(&self, id: u64) -> Option<LastWill> {
        self.disconnects.fetch_add(1, Ordering::Relaxed);
        let meta = self.sessions.lock().unwrap().remove(&id)?;
        for (filter, _) in &meta.subs {
            for s in self.filter_shards(filter) {
                self.shards[s].lock().subs.remove_where(filter, |e| e.conn == id);
            }
        }
        meta.will
    }

    /// Add (or replace) a subscription and return the retained messages
    /// it should receive, resolved through the retained tries of the
    /// filter's shard(s) — no scan over unrelated retained topics.
    pub fn subscribe(&self, id: u64, filter: &str, qos: u8) -> Vec<Retained> {
        let outbox = {
            let mut sessions = self.sessions.lock().unwrap();
            let Some(meta) = sessions.get_mut(&id) else { return Vec::new() };
            meta.subs.retain(|(f, _)| f != filter);
            meta.subs.push((filter.to_string(), qos));
            meta.outbox.clone()
        };
        let mut retained = Vec::new();
        for s in self.filter_shards(filter) {
            let mut st = self.shards[s].lock();
            // Replace semantics: a re-subscribe must not double-deliver.
            st.subs.remove_where(filter, |e| e.conn == id);
            st.subs.insert(filter, SubEntry { conn: id, outbox: outbox.clone() });
            st.retained.collect_matching(filter, &mut retained);
        }
        retained
    }

    pub fn unsubscribe(&self, id: u64, filter: &str) {
        {
            let mut sessions = self.sessions.lock().unwrap();
            if let Some(meta) = sessions.get_mut(&id) {
                meta.subs.retain(|(f, _)| f != filter);
            }
        }
        for s in self.filter_shards(filter) {
            self.shards[s].lock().subs.remove_where(filter, |e| e.conn == id);
        }
    }

    /// The hot path: route one PUBLISH. Locks exactly the topic's shard,
    /// matches through the trie in O(topic depth), encodes the outbound
    /// head once, and fans the shared (head, payload) pair out to every
    /// matched session. Returns (delivered, dropped_slow).
    pub fn publish(&self, topic_name: &str, payload: &Bytes, retain: bool) -> (u64, u64) {
        // Encode the outbound head ONCE; all subscribers share head + payload.
        let Some(OutMsg::Pub { head, payload: shared }) = pub_msg(topic_name, payload, false)
        else {
            return (0, 0);
        };
        let shard = &self.shards[self.shard_of(topic_name)];
        shard.publishes.inc();
        let mut delivered = 0u64;
        let mut dropped = 0u64;
        let mut bytes = 0u64;
        let mut st = shard.lock();
        st.published += 1;
        st.bytes_in += payload.len() as u64;
        if retain {
            if payload.is_empty() {
                st.retained.remove(topic_name);
            } else {
                st.retained.insert(topic_name, payload.clone());
            }
        }
        let mut matched: Vec<&SubEntry> = Vec::new();
        st.subs.collect(topic_name, &mut matched);
        // One delivery per session even under overlapping filters
        // (e.g. `a/#` + `a/b`), as the flat-list broker behaved.
        if matched.len() > 1 {
            matched.sort_unstable_by_key(|e| e.conn);
            matched.dedup_by_key(|e| e.conn);
        }
        shard.matches.add(matched.len() as u64);
        for entry in &matched {
            match entry.outbox.try_send(OutMsg::Pub { head: head.clone(), payload: shared.clone() })
            {
                Ok(()) => {
                    delivered += 1;
                    bytes += shared.len() as u64;
                }
                Err(TrySendError::Full(_)) => dropped += 1,
                Err(TrySendError::Disconnected(_)) => {}
            }
        }
        st.delivered += delivered;
        st.dropped_slow += dropped;
        st.bytes_out += bytes;
        (delivered, dropped)
    }

    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// Retained topics currently stored, sorted (test helper).
    pub fn retained_topics(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().retained.topics())
            .map(|t| t.to_string())
            .collect();
        v.sort();
        v
    }

    /// Aggregate stats across shards + the control plane.
    pub fn stats(&self) -> BrokerStats {
        let mut out = BrokerStats {
            connects: self.connects.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            ..Default::default()
        };
        for s in &self.shards {
            let st = s.lock();
            out.published += st.published;
            out.delivered += st.delivered;
            out.dropped_slow += st.dropped_slow;
            out.bytes_in += st.bytes_in;
            out.bytes_out += st.bytes_out;
        }
        out
    }

    /// Every live session's outbox (shutdown broadcast).
    fn outboxes(&self) -> Vec<SyncSender<OutMsg>> {
        self.sessions.lock().unwrap().values().map(|s| s.outbox.clone()).collect()
    }
}

/// `EDGEPIPE_BROKER_SHARDS`, defaulting to `min(available_parallelism, 8)`.
fn default_shards() -> usize {
    if let Ok(v) = std::env::var("EDGEPIPE_BROKER_SHARDS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
        log_warn!("mqtt.broker", "ignoring invalid EDGEPIPE_BROKER_SHARDS=`{v}`");
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
}

/// Broker configuration.
#[derive(Debug, Clone)]
pub struct BrokerConfig {
    /// Per-connection outbound queue depth; overflow drops the message for
    /// that subscriber (slow-consumer policy).
    pub outbox_depth: usize,
    /// Fallback read timeout when a client requests keep_alive = 0.
    pub idle_timeout: Duration,
    /// Routing-state shards; 0 = `EDGEPIPE_BROKER_SHARDS` or
    /// `min(available_parallelism, 8)`.
    pub shards: usize,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        Self { outbox_depth: 64, idle_timeout: Duration::from_secs(3600), shards: 0 }
    }
}

/// A running broker; dropping it stops the listener.
pub struct Broker {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    router: Arc<Router>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Broker {
    /// Bind and start. Use port 0 for an ephemeral port.
    pub fn start(bind: &str) -> Result<Broker> {
        Broker::start_with(bind, BrokerConfig::default())
    }

    pub fn start_with(bind: &str, cfg: BrokerConfig) -> Result<Broker> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::Mqtt(format!("bind {bind}: {e}")))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let router = Arc::new(Router::new(cfg.shards));
        let conn_seq = Arc::new(AtomicU64::new(1));

        let t_shutdown = shutdown.clone();
        let t_router = router.clone();
        let cfg = Arc::new(cfg);
        let accept_thread = std::thread::Builder::new()
            .name("mqtt-broker-accept".into())
            .spawn(move || {
                log_info!(
                    "mqtt.broker",
                    "listening on {addr} ({} routing shards)",
                    t_router.shard_count()
                );
                while !t_shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            let id = conn_seq.fetch_add(1, Ordering::Relaxed);
                            let rt = t_router.clone();
                            let sd = t_shutdown.clone();
                            let c = cfg.clone();
                            let _ = std::thread::Builder::new()
                                .name(format!("mqtt-conn-{id}"))
                                .spawn(move || {
                                    if let Err(e) = serve_conn(id, stream, rt, sd, &c) {
                                        log_debug!("mqtt.broker", "conn {id} ({peer}): {e}");
                                    }
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => {
                            log_warn!("mqtt.broker", "accept: {e}");
                            break;
                        }
                    }
                }
            })
            .expect("spawn broker accept thread");
        Ok(Broker { addr, shutdown, router, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> BrokerStats {
        self.router.stats()
    }

    /// Number of live sessions (for tests).
    pub fn session_count(&self) -> usize {
        self.router.session_count()
    }

    /// Routing shards in use.
    pub fn shard_count(&self) -> usize {
        self.router.shard_count()
    }

    /// Retained topics currently stored (for tests).
    pub fn retained_topics(&self) -> Vec<String> {
        self.router.retained_topics()
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Close all sessions so conn threads unblock.
        for s in self.router.outboxes() {
            let _ = s.try_send(OutMsg::Close);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Broker {
    fn drop(&mut self) {
        self.stop();
    }
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<OutMsg>) {
    for msg in rx {
        let ok = match msg {
            OutMsg::Close => break,
            OutMsg::Control(p) => match p.encode() {
                Ok(w) => {
                    use std::io::Write;
                    stream.write_all(&w).is_ok()
                }
                Err(_) => continue,
            },
            OutMsg::Pub { head, payload } => {
                write_all_vectored(&mut stream, &[head.as_slice(), payload.as_slice()]).is_ok()
            }
        };
        if !ok {
            break;
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

fn serve_conn(
    id: u64,
    mut stream: TcpStream,
    router: Arc<Router>,
    shutdown: Arc<AtomicBool>,
    cfg: &BrokerConfig,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let connect = Packet::read(&mut stream)?;
    let (client_id, keep_alive, will) = match connect {
        Packet::Connect { client_id, keep_alive, will, .. } => (client_id, keep_alive, will),
        other => return Err(Error::Mqtt(format!("expected CONNECT, got {other:?}"))),
    };
    // Keep-alive enforcement: 1.5x grace per spec.
    let timeout = if keep_alive == 0 {
        cfg.idle_timeout
    } else {
        Duration::from_millis(keep_alive as u64 * 1500)
    };
    stream.set_read_timeout(Some(timeout))?;

    let (tx, rx) = sync_channel::<OutMsg>(cfg.outbox_depth);
    let wstream = stream.try_clone()?;
    let writer = std::thread::Builder::new()
        .name(format!("mqtt-wr-{id}"))
        .spawn(move || writer_loop(wstream, rx))
        .expect("spawn writer");

    router.session_open(id, client_id.clone(), tx.clone(), will);
    let _ = tx.send(OutMsg::Control(Packet::ConnAck {
        session_present: false,
        code: CONNACK_ACCEPTED,
    }));
    log_debug!("mqtt.broker", "conn {id}: client `{client_id}` connected");

    let mut clean_disconnect = false;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let pkt = match Packet::read(&mut stream) {
            Ok(p) => p,
            Err(Error::Io(ref e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                log_debug!("mqtt.broker", "conn {id}: keep-alive timeout");
                break;
            }
            Err(_) => break,
        };
        match pkt {
            Packet::Publish { topic: t, payload, qos, retain, packet_id, .. } => {
                if topic::validate_name(&t).is_err() {
                    break;
                }
                // `payload` is a shared view into this connection's packet
                // read; the router fans it out without duplicating it.
                router.publish(&t, &payload, retain);
                if qos == 1 {
                    if let Some(pid) = packet_id {
                        let _ = tx.send(OutMsg::Control(Packet::PubAck { packet_id: pid }));
                    }
                }
            }
            Packet::Subscribe { packet_id, filters } => {
                let mut codes = Vec::with_capacity(filters.len());
                let mut retained_out: Vec<Retained> = Vec::new();
                for (f, qos) in &filters {
                    if topic::validate_filter(f).is_err() {
                        codes.push(0x80);
                        continue;
                    }
                    codes.push((*qos).min(1));
                    retained_out.extend(router.subscribe(id, f, (*qos).min(1)));
                }
                let _ = tx.send(OutMsg::Control(Packet::SubAck { packet_id, codes }));
                for r in retained_out {
                    if let Some(msg) = pub_msg(&r.topic, &r.payload, true) {
                        let _ = tx.send(msg);
                    }
                }
            }
            Packet::Unsubscribe { packet_id, filters } => {
                for f in &filters {
                    router.unsubscribe(id, f);
                }
                let _ = tx.send(OutMsg::Control(Packet::UnsubAck { packet_id }));
            }
            Packet::PingReq => {
                let _ = tx.send(OutMsg::Control(Packet::PingResp));
            }
            Packet::Disconnect => {
                clean_disconnect = true;
                break;
            }
            Packet::PubAck { .. } => {}
            other => {
                log_warn!("mqtt.broker", "conn {id}: unexpected {other:?}");
                break;
            }
        }
    }

    // Teardown: remove session, fire will if unclean.
    let will = router.session_close(id);
    if !clean_disconnect {
        if let Some(w) = will {
            log_debug!("mqtt.broker", "conn {id}: firing last-will on `{}`", w.topic);
            router.publish(&w.topic, &Bytes::from(w.payload), w.retain);
        }
    }
    let _ = tx.send(OutMsg::Close);
    drop(tx);
    let _ = writer.join();
    Ok(())
}
