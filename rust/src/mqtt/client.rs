//! MQTT client (paho.mqtt.c analog): blocking connect/publish/subscribe
//! with a background reader thread, keep-alive pings, QoS 1 ack waiting,
//! and channel- or callback-based subscription delivery.
//!
//! Publish never copies the payload: the PUBLISH head is built separately
//! and head + payload go out in one vectored write ([`MqttClient::publish`]
//! for a borrowed slice, [`MqttClient::publish_frame`] for a shared
//! [`WireFrame`] whose header/payload are emitted as three parts).

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU16, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::buffer::Bytes;
use crate::mqtt::packet::{self, LastWill, Packet};
use crate::mqtt::topic;
use crate::mqtt::trie::SubTrie;
use crate::serial::wire::WireFrame;
use crate::util::{write_all_vectored, Error, Result};
use crate::{log_debug, log_warn};

/// An inbound publish delivered to a subscriber. The payload is a shared
/// view into the connection's single per-packet read allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub topic: String,
    pub payload: Bytes,
    pub retain: bool,
}

type Callback = Box<dyn Fn(&Message) + Send + Sync>;

enum Handler {
    Channel(SyncSender<Message>),
    Callback(Callback),
}

struct Sub {
    filter: String,
    handler: Handler,
}

/// Client-side subscription table: handlers in slots, a wildcard-aware
/// [`SubTrie`] of slot indices on top. Dispatching an inbound PUBLISH is
/// a trie walk (O(topic depth)) instead of a `matches()` scan over every
/// subscription — the broker-side structure, mirrored for clients that
/// hold many filters (e.g. a coordinator watching many operations).
#[derive(Default)]
struct SubTable {
    trie: SubTrie<usize>,
    slots: Vec<Option<Sub>>,
    free: Vec<usize>,
}

impl SubTable {
    fn add(&mut self, filter: &str, handler: Handler) {
        let sub = Sub { filter: filter.to_string(), handler };
        let slot = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(sub);
                i
            }
            None => {
                self.slots.push(Some(sub));
                self.slots.len() - 1
            }
        };
        self.trie.insert(filter, slot);
    }

    /// Drop every handler registered under `filter`.
    fn remove_filter(&mut self, filter: &str) {
        let slots = &mut self.slots;
        let free = &mut self.free;
        self.trie.remove_where(filter, |i| {
            slots[*i] = None;
            free.push(*i);
            true
        });
    }

    /// Drop one handler by slot (disconnected channel receiver).
    fn remove_slot(&mut self, slot: usize) {
        if let Some(sub) = self.slots[slot].take() {
            self.trie.remove_where(&sub.filter, |i| *i == slot);
            self.free.push(slot);
        }
    }

    fn clear(&mut self) {
        self.trie = SubTrie::new();
        self.slots.clear();
        self.free.clear();
    }
}

/// Client connection options.
#[derive(Debug, Clone)]
pub struct ClientOptions {
    pub client_id: String,
    pub keep_alive_secs: u16,
    pub will: Option<LastWill>,
    /// Subscription channel depth (overflow drops oldest-offered message).
    pub channel_depth: usize,
}

impl Default for ClientOptions {
    fn default() -> Self {
        Self {
            client_id: format!("edgepipe-{}", std::process::id()),
            keep_alive_secs: 20,
            will: None,
            channel_depth: 256,
        }
    }
}

struct Inner {
    writer: Mutex<TcpStream>,
    subs: Mutex<SubTable>,
    pending_acks: Mutex<HashMap<u16, SyncSender<Packet>>>,
    next_id: AtomicU16,
    connected: AtomicBool,
}

impl Inner {
    fn send(&self, p: &Packet) -> Result<()> {
        let (head, payload) = p.encode_parts()?;
        self.send_parts(&[head.as_slice(), payload.as_deref().unwrap_or(&[])])
    }

    /// Vectored write under the writer lock (single syscall, no assembly).
    fn send_parts(&self, parts: &[&[u8]]) -> Result<()> {
        let mut w = self.writer.lock().unwrap();
        write_all_vectored(&mut *w, parts).map_err(|e| {
            self.connected.store(false, Ordering::Relaxed);
            Error::Transport(format!("mqtt send: {e}"))
        })
    }

    fn alloc_id(&self) -> u16 {
        loop {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            if id != 0 {
                return id;
            }
        }
    }

    /// Register a waiter, send the parts, await the matching ack packet.
    fn request_parts(&self, parts: &[&[u8]], id: u16, timeout: Duration) -> Result<Packet> {
        let (tx, rx) = sync_channel(1);
        self.pending_acks.lock().unwrap().insert(id, tx);
        let sent = self.send_parts(parts);
        let out = sent.and_then(|_| {
            rx.recv_timeout(timeout)
                .map_err(|_| Error::Mqtt(format!("ack timeout for packet {id}")))
        });
        self.pending_acks.lock().unwrap().remove(&id);
        out
    }

    fn request(&self, p: &Packet, id: u16, timeout: Duration) -> Result<Packet> {
        let (head, payload) = p.encode_parts()?;
        self.request_parts(&[head.as_slice(), payload.as_deref().unwrap_or(&[])], id, timeout)
    }
}

/// A connected MQTT client. Cheap to clone (shared connection).
#[derive(Clone)]
pub struct MqttClient {
    inner: Arc<Inner>,
}

pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(5);

impl MqttClient {
    /// Connect to a broker (`host:port`).
    pub fn connect(addr: &str, opts: ClientOptions) -> Result<MqttClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Transport(format!("mqtt connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let mut rstream = stream.try_clone()?;
        // Reads must wake periodically so a dead broker is detected.
        rstream.set_read_timeout(Some(Duration::from_millis(
            (opts.keep_alive_secs.max(1) as u64) * 2000,
        )))?;

        let inner = Arc::new(Inner {
            writer: Mutex::new(stream),
            subs: Mutex::new(SubTable::default()),
            pending_acks: Mutex::new(HashMap::new()),
            next_id: AtomicU16::new(1),
            connected: AtomicBool::new(true),
        });

        inner.send(&Packet::Connect {
            client_id: opts.client_id.clone(),
            keep_alive: opts.keep_alive_secs,
            clean_session: true,
            will: opts.will.clone(),
        })?;
        match Packet::read(&mut rstream)? {
            Packet::ConnAck { code: 0, .. } => {}
            Packet::ConnAck { code, .. } => {
                return Err(Error::Mqtt(format!("connection refused: code {code}")))
            }
            other => return Err(Error::Mqtt(format!("expected CONNACK, got {other:?}"))),
        }

        // Reader thread: dispatch publishes + acks.
        let r_inner = inner.clone();
        std::thread::Builder::new()
            .name("mqtt-client-reader".into())
            .spawn(move || reader_loop(rstream, r_inner))
            .expect("spawn mqtt reader");

        // Keep-alive pinger.
        if opts.keep_alive_secs > 0 {
            let p_inner = inner.clone();
            let interval = Duration::from_millis(opts.keep_alive_secs as u64 * 500);
            std::thread::Builder::new()
                .name("mqtt-client-ping".into())
                .spawn(move || {
                    while p_inner.connected.load(Ordering::Relaxed) {
                        std::thread::sleep(interval);
                        if p_inner.send(&Packet::PingReq).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn mqtt pinger");
        }

        Ok(MqttClient { inner })
    }

    pub fn is_connected(&self) -> bool {
        self.inner.connected.load(Ordering::Relaxed)
    }

    /// Fire-and-forget publish (QoS 0). The payload is written straight
    /// from the caller's slice — no intermediate copy.
    pub fn publish(&self, topic_name: &str, payload: &[u8], retain: bool) -> Result<()> {
        topic::validate_name(topic_name)?;
        let head = packet::publish_head(topic_name, 0, retain, false, None, payload.len())?;
        self.inner.send_parts(&[head.as_slice(), payload])
    }

    /// Publish an already-encoded [`WireFrame`] (QoS 0): PUBLISH head,
    /// frame header, and shared frame payload leave in one vectored write
    /// — zero payload copies end-to-end. Compressed frames arrive here
    /// already deflated in place (header + payload are two views into one
    /// allocation), so the compressed hop costs one allocation total on
    /// the send side.
    pub fn publish_frame(&self, topic_name: &str, frame: &WireFrame, retain: bool) -> Result<()> {
        topic::validate_name(topic_name)?;
        let head = packet::publish_head(topic_name, 0, retain, false, None, frame.len())?;
        self.inner
            .send_parts(&[head.as_slice(), frame.header.as_slice(), frame.payload.as_slice()])
    }

    /// Acknowledged publish (QoS 1): blocks until PUBACK or timeout.
    pub fn publish_qos1(&self, topic_name: &str, payload: &[u8], retain: bool) -> Result<()> {
        topic::validate_name(topic_name)?;
        let id = self.inner.alloc_id();
        let head = packet::publish_head(topic_name, 1, retain, false, Some(id), payload.len())?;
        match self.inner.request_parts(&[head.as_slice(), payload], id, DEFAULT_TIMEOUT)? {
            Packet::PubAck { .. } => Ok(()),
            other => Err(Error::Mqtt(format!("expected PUBACK, got {other:?}"))),
        }
    }

    /// Subscribe and receive matching messages on a channel.
    pub fn subscribe(&self, filter: &str) -> Result<Receiver<Message>> {
        topic::validate_filter(filter)?;
        let (tx, rx) = sync_channel(self_channel_depth());
        self.do_subscribe(filter, Handler::Channel(tx))?;
        Ok(rx)
    }

    /// Subscribe with a callback invoked on the reader thread.
    pub fn subscribe_cb(
        &self,
        filter: &str,
        cb: impl Fn(&Message) + Send + Sync + 'static,
    ) -> Result<()> {
        topic::validate_filter(filter)?;
        self.do_subscribe(filter, Handler::Callback(Box::new(cb)))
    }

    fn do_subscribe(&self, filter: &str, handler: Handler) -> Result<()> {
        let id = self.inner.alloc_id();
        // Register the handler BEFORE the broker starts sending retained
        // messages, or we'd race and drop them.
        self.inner.subs.lock().unwrap().add(filter, handler);
        let p = Packet::Subscribe { packet_id: id, filters: vec![(filter.to_string(), 0)] };
        match self.inner.request(&p, id, DEFAULT_TIMEOUT) {
            Ok(Packet::SubAck { codes, .. }) => {
                if codes.first().copied().unwrap_or(0x80) == 0x80 {
                    self.inner.subs.lock().unwrap().remove_filter(filter);
                    return Err(Error::Mqtt(format!("subscription `{filter}` refused")));
                }
                Ok(())
            }
            Ok(other) => Err(Error::Mqtt(format!("expected SUBACK, got {other:?}"))),
            Err(e) => {
                self.inner.subs.lock().unwrap().remove_filter(filter);
                Err(e)
            }
        }
    }

    pub fn unsubscribe(&self, filter: &str) -> Result<()> {
        let id = self.inner.alloc_id();
        self.inner.subs.lock().unwrap().remove_filter(filter);
        let p = Packet::Unsubscribe { packet_id: id, filters: vec![filter.to_string()] };
        match self.inner.request(&p, id, DEFAULT_TIMEOUT)? {
            Packet::UnsubAck { .. } => Ok(()),
            other => Err(Error::Mqtt(format!("expected UNSUBACK, got {other:?}"))),
        }
    }

    /// Test/bench hook: clone the underlying stream (to simulate an
    /// unclean disconnect by shutting the socket without DISCONNECT).
    #[doc(hidden)]
    pub fn inner_stream_for_test(&self) -> Result<TcpStream> {
        Ok(self.inner.writer.lock().unwrap().try_clone()?)
    }

    /// Clean disconnect (suppresses the last-will).
    pub fn disconnect(&self) {
        let _ = self.inner.send(&Packet::Disconnect);
        self.inner.connected.store(false, Ordering::Relaxed);
        if let Ok(w) = self.inner.writer.lock() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
    }
}

fn self_channel_depth() -> usize {
    64
}

fn reader_loop(mut stream: TcpStream, inner: Arc<Inner>) {
    loop {
        if !inner.connected.load(Ordering::Relaxed) {
            break;
        }
        let pkt = match Packet::read(&mut stream) {
            Ok(p) => p,
            Err(Error::Io(ref e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Keep-alive pings should prevent this; treat as dead link.
                log_warn!("mqtt.client", "read timeout; assuming broker dead");
                break;
            }
            Err(e) => {
                log_debug!("mqtt.client", "reader: {e}");
                break;
            }
        };
        match pkt {
            Packet::Publish { topic: t, payload, retain, .. } => {
                // `payload` is already a shared view into the socket-read
                // allocation; fan-out to handlers clones the view only.
                let msg = Message { topic: t, payload, retain };
                let mut subs = inner.subs.lock().unwrap();
                // Trie walk instead of a linear matches() scan; indices
                // are copied out so dead slots can be removed mid-loop.
                let mut hits: Vec<&usize> = Vec::new();
                subs.trie.collect(&msg.topic, &mut hits);
                let hits: Vec<usize> = hits.into_iter().copied().collect();
                let mut dead: Vec<usize> = Vec::new();
                for slot in hits {
                    let Some(sub) = &subs.slots[slot] else { continue };
                    match &sub.handler {
                        Handler::Callback(cb) => cb(&msg),
                        Handler::Channel(tx) => match tx.try_send(msg.clone()) {
                            Ok(()) | Err(std::sync::mpsc::TrySendError::Full(_)) => {} // Full: drop msg
                            Err(std::sync::mpsc::TrySendError::Disconnected(_)) => dead.push(slot),
                        },
                    }
                }
                for slot in dead {
                    subs.remove_slot(slot);
                }
            }
            Packet::PubAck { packet_id } => notify(&inner, packet_id, Packet::PubAck { packet_id }),
            Packet::SubAck { packet_id, codes } => {
                notify(&inner, packet_id, Packet::SubAck { packet_id, codes })
            }
            Packet::UnsubAck { packet_id } => {
                notify(&inner, packet_id, Packet::UnsubAck { packet_id })
            }
            Packet::PingResp => {}
            other => {
                log_debug!("mqtt.client", "unexpected packet {other:?}");
            }
        }
    }
    inner.connected.store(false, Ordering::Relaxed);
    // Drop channel senders so receivers observe disconnection.
    inner.subs.lock().unwrap().clear();
    inner.pending_acks.lock().unwrap().clear();
}

fn notify(inner: &Inner, id: u16, pkt: Packet) {
    if let Some(tx) = inner.pending_acks.lock().unwrap().remove(&id) {
        let _ = tx.try_send(pkt);
    }
}
