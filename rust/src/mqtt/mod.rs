//! MQTT 3.1.1 substrate: [`packet`] codec, [`topic`] filters, [`broker`]
//! (in-repo Mosquitto analog) and [`client`] (paho analog).
//!
//! The paper chooses MQTT over ROS/ZeroMQ because home-IoT standards
//! (Matter, SmartThings) already speak it (§4.2.1); everything above the
//! socket — pub/sub elements, query discovery, failover — builds on this
//! module.

pub mod broker;
pub mod client;
pub mod packet;
pub mod topic;
pub mod trie;

pub use broker::{Broker, BrokerConfig, BrokerStats, Router};
pub use client::{ClientOptions, Message, MqttClient};
pub use packet::{LastWill, Packet};

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn client(broker: &Broker, id: &str) -> MqttClient {
        MqttClient::connect(
            &broker.addr().to_string(),
            ClientOptions { client_id: id.into(), ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn connect_publish_subscribe_roundtrip() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let sub = client(&broker, "sub");
        let publ = client(&broker, "pub");
        let rx = sub.subscribe("cam/left").unwrap();
        publ.publish("cam/left", b"frame-1", false).unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(msg.topic, "cam/left");
        assert_eq!(&msg.payload[..], b"frame-1");
    }

    #[test]
    fn wildcard_subscription_receives_multiple_topics() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let sub = client(&broker, "sub");
        let publ = client(&broker, "pub");
        let rx = sub.subscribe("/objdetect/#").unwrap();
        publ.publish("/objdetect/mobilev3", b"a", false).unwrap();
        publ.publish("/objdetect/yolov2", b"b", false).unwrap();
        publ.publish("/posenet/v1", b"x", false).unwrap();
        let m1 = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        let m2 = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(m1.topic, "/objdetect/mobilev3");
        assert_eq!(m2.topic, "/objdetect/yolov2");
        assert!(rx.recv_timeout(Duration::from_millis(200)).is_err());
    }

    #[test]
    fn retained_message_delivered_to_late_subscriber() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let publ = client(&broker, "pub");
        publ.publish("svc/ad", b"host:1234", true).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let sub = client(&broker, "sub");
        let rx = sub.subscribe("svc/+").unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(&msg.payload[..], b"host:1234");
        assert!(msg.retain);
    }

    #[test]
    fn empty_retained_clears() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let publ = client(&broker, "pub");
        publ.publish("svc/ad", b"x", true).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(broker.retained_topics(), vec!["svc/ad".to_string()]);
        publ.publish("svc/ad", b"", true).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert!(broker.retained_topics().is_empty());
    }

    #[test]
    fn qos1_publish_acknowledged() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let publ = client(&broker, "pub");
        publ.publish_qos1("t", b"payload", false).unwrap();
        assert_eq!(broker.stats().published, 1);
    }

    #[test]
    fn last_will_fires_on_unclean_disconnect() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let watcher = client(&broker, "watcher");
        let rx = watcher.subscribe("edge/query/objdetect/+").unwrap();
        {
            let dying = MqttClient::connect(
                &broker.addr().to_string(),
                ClientOptions {
                    client_id: "server-1".into(),
                    will: Some(LastWill {
                        topic: "edge/query/objdetect/server-1".into(),
                        payload: b"DEAD".to_vec(),
                        qos: 0,
                        retain: false,
                    }),
                    ..Default::default()
                },
            )
            .unwrap();
            // Kill the TCP stream without DISCONNECT -> broker fires will.
            if let Ok(w) = dying.inner_stream_for_test() {
                let _ = w.shutdown(std::net::Shutdown::Both);
            }
        }
        let msg = rx.recv_timeout(Duration::from_secs(3)).unwrap();
        assert_eq!(&msg.payload[..], b"DEAD");
    }

    #[test]
    fn clean_disconnect_suppresses_will() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let watcher = client(&broker, "watcher");
        let rx = watcher.subscribe("will/+").unwrap();
        let leaving = MqttClient::connect(
            &broker.addr().to_string(),
            ClientOptions {
                client_id: "polite".into(),
                will: Some(LastWill {
                    topic: "will/polite".into(),
                    payload: b"DEAD".to_vec(),
                    qos: 0,
                    retain: false,
                }),
                ..Default::default()
            },
        )
        .unwrap();
        leaving.disconnect();
        assert!(rx.recv_timeout(Duration::from_millis(500)).is_err());
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let sub = client(&broker, "sub");
        let publ = client(&broker, "pub");
        let rx = sub.subscribe("t").unwrap();
        publ.publish("t", b"1", false).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        sub.unsubscribe("t").unwrap();
        publ.publish("t", b"2", false).unwrap();
        assert!(rx.recv_timeout(Duration::from_millis(300)).is_err());
    }

    #[test]
    fn multiple_subscribers_fan_out() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let s1 = client(&broker, "s1");
        let s2 = client(&broker, "s2");
        let publ = client(&broker, "pub");
        let r1 = s1.subscribe("fan").unwrap();
        let r2 = s2.subscribe("fan").unwrap();
        publ.publish("fan", b"x", false).unwrap();
        assert_eq!(&r1.recv_timeout(Duration::from_secs(2)).unwrap().payload[..], b"x");
        assert_eq!(&r2.recv_timeout(Duration::from_secs(2)).unwrap().payload[..], b"x");
        assert_eq!(broker.stats().delivered, 2);
    }

    #[test]
    fn callback_subscription() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let sub = client(&broker, "sub");
        let publ = client(&broker, "pub");
        let (tx, rx) = std::sync::mpsc::channel();
        sub.subscribe_cb("cb/topic", move |m| {
            tx.send(m.payload.len()).unwrap();
        })
        .unwrap();
        publ.publish("cb/topic", &[0u8; 17], false).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), 17);
    }

    #[test]
    fn large_payload_roundtrip() {
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let sub = client(&broker, "sub");
        let publ = client(&broker, "pub");
        let rx = sub.subscribe("big").unwrap();
        let payload = vec![0x5Au8; 2 * 1024 * 1024]; // FullHD frame scale
        publ.publish("big", &payload, false).unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(msg.payload.len(), payload.len());
    }

    #[test]
    fn dollar_topics_not_fanned_out_to_wildcard_subscribers() {
        // Broker-side §4.7.2: a '$'-prefixed topic reaches only
        // subscribers that name the '$' level literally — never '#'/'+'
        // wildcard subscribers (live fan-out AND retained delivery).
        let broker = Broker::start("127.0.0.1:0").unwrap();
        let wild = client(&broker, "wild");
        let explicit = client(&broker, "explicit");
        let publ = client(&broker, "pub");
        let rx_wild = wild.subscribe("#").unwrap();
        let rx_explicit = explicit.subscribe("$internal/#").unwrap();
        publ.publish("$internal/stats", b"secret", true).unwrap();
        let msg = rx_explicit.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(&msg.payload[..], b"secret");
        assert!(
            rx_wild.recv_timeout(Duration::from_millis(300)).is_err(),
            "wildcard subscriber leaked a $-topic"
        );
        // Retained path: a late '#' subscriber must not receive it either.
        let late = client(&broker, "late");
        let rx_late = late.subscribe("#").unwrap();
        assert!(rx_late.recv_timeout(Duration::from_millis(300)).is_err());
        // Ordinary topics still fan out to '#'.
        publ.publish("plain/stats", b"ok", false).unwrap();
        assert_eq!(&rx_wild.recv_timeout(Duration::from_secs(2)).unwrap().payload[..], b"ok");
    }

    #[test]
    fn session_count_tracks_connections() {
        let mut broker = Broker::start("127.0.0.1:0").unwrap();
        let c1 = client(&broker, "a");
        let _c2 = client(&broker, "b");
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(broker.session_count(), 2);
        c1.disconnect();
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(broker.session_count(), 1);
        broker.stop();
    }
}
