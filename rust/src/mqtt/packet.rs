//! MQTT 3.1.1 control-packet codec (the subset the among-device transport
//! uses: CONNECT/CONNACK, PUBLISH QoS 0/1 + PUBACK, SUBSCRIBE/SUBACK,
//! UNSUBSCRIBE/UNSUBACK, PING, DISCONNECT).
//!
//! PUBLISH payloads are [`Bytes`]: decoding slices the payload out of the
//! received body without copying, and the send side emits
//! [`publish_head`] + payload as separate scatter-gather parts so one
//! encoded frame can be shared across every subscriber of a topic.

use std::io::Read;

use crate::buffer::Bytes;
use crate::util::{Error, Result};

/// Session will (LWT): published by the broker when a client vanishes —
/// the mechanism behind R4's automatic failover (server-down detection).
#[derive(Debug, Clone, PartialEq)]
pub struct LastWill {
    pub topic: String,
    pub payload: Vec<u8>,
    pub qos: u8,
    pub retain: bool,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Packet {
    Connect {
        client_id: String,
        keep_alive: u16,
        clean_session: bool,
        will: Option<LastWill>,
    },
    ConnAck {
        session_present: bool,
        code: u8,
    },
    Publish {
        topic: String,
        payload: Bytes,
        qos: u8,
        retain: bool,
        dup: bool,
        packet_id: Option<u16>,
    },
    PubAck {
        packet_id: u16,
    },
    Subscribe {
        packet_id: u16,
        filters: Vec<(String, u8)>,
    },
    SubAck {
        packet_id: u16,
        codes: Vec<u8>,
    },
    Unsubscribe {
        packet_id: u16,
        filters: Vec<String>,
    },
    UnsubAck {
        packet_id: u16,
    },
    PingReq,
    PingResp,
    Disconnect,
}

pub const PROTO_NAME: &str = "MQTT";
pub const PROTO_LEVEL: u8 = 4; // 3.1.1
pub const CONNACK_ACCEPTED: u8 = 0;
pub const CONNACK_ID_REJECTED: u8 = 2;

const MAX_REMAINING: usize = 268_435_455;

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
}

fn put_bytes16(out: &mut Vec<u8>, b: &[u8]) {
    put_u16(out, b.len() as u16);
    out.extend_from_slice(b);
}

/// Append the MQTT variable-length "remaining length" encoding.
/// Public so wire-replica tooling (bench baselines) reuses one encoder.
pub fn put_remaining(out: &mut Vec<u8>, mut rem: usize) {
    loop {
        let mut b = (rem % 128) as u8;
        rem /= 128;
        if rem > 0 {
            b |= 0x80;
        }
        out.push(b);
        if rem == 0 {
            break;
        }
    }
}

/// Build everything of a PUBLISH packet that precedes the payload: fixed
/// header, remaining length, topic, optional packet id. Writing
/// `head ++ payload` yields a complete wire packet — the hot path pairs
/// this with a vectored write so the (shared) payload is never copied.
/// The payload is opaque here: compressed EdgeFrames ride through with
/// `payload_len` set to the *compressed* frame length, so the MQTT layer
/// never inflates or re-deflates what the wire codec produced.
pub fn publish_head(
    topic: &str,
    qos: u8,
    retain: bool,
    dup: bool,
    packet_id: Option<u16>,
    payload_len: usize,
) -> Result<Vec<u8>> {
    if qos > 1 {
        return Err(Error::Mqtt("QoS 2 not supported".into()));
    }
    if qos > 0 && packet_id.is_none() {
        return Err(Error::Mqtt("QoS1 publish needs packet id".into()));
    }
    let var_len = 2 + topic.len() + if qos > 0 { 2 } else { 0 } + payload_len;
    if var_len > MAX_REMAINING {
        return Err(Error::Mqtt(format!("packet too large: {var_len}")));
    }
    // Worst case: flags(1) + remaining-length(4) + topic-len(2) + topic +
    // packet-id(2); the old `7 + topic` capacity re-allocated on every
    // multi-megabyte (multibyte remaining-length) QoS1 publish.
    let mut head = Vec::with_capacity(9 + topic.len());
    let mut flags = 0x30 | (qos << 1);
    if retain {
        flags |= 0x01;
    }
    if dup {
        flags |= 0x08;
    }
    head.push(flags);
    put_remaining(&mut head, var_len);
    put_str(&mut head, topic);
    if qos > 0 {
        put_u16(&mut head, packet_id.unwrap_or(0));
    }
    Ok(head)
}

impl Packet {
    /// Split into wire parts: (everything before the payload, payload).
    /// Non-PUBLISH packets are fully contained in the first part.
    pub fn encode_parts(&self) -> Result<(Vec<u8>, Option<Bytes>)> {
        if let Packet::Publish { topic, payload, qos, retain, dup, packet_id } = self {
            let head = publish_head(topic, *qos, *retain, *dup, *packet_id, payload.len())?;
            return Ok((head, Some(payload.clone())));
        }
        let (type_flags, body) = self.encode_body()?;
        if body.len() > MAX_REMAINING {
            return Err(Error::Mqtt(format!("packet too large: {}", body.len())));
        }
        let mut out = Vec::with_capacity(body.len() + 5);
        out.push(type_flags);
        put_remaining(&mut out, body.len());
        out.extend_from_slice(&body);
        Ok((out, None))
    }

    /// Serialize to one contiguous wire buffer (fixed header + body).
    /// PUBLISH copies its payload once (counted); the transport hot path
    /// uses [`Packet::encode_parts`] / [`publish_head`] instead.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let (mut head, payload) = self.encode_parts()?;
        if let Some(p) = payload {
            crate::buffer::record_copy(p.len());
            head.extend_from_slice(&p);
        }
        Ok(head)
    }

    fn encode_body(&self) -> Result<(u8, Vec<u8>)> {
        let mut b = Vec::new();
        Ok(match self {
            Packet::Connect { client_id, keep_alive, clean_session, will } => {
                put_str(&mut b, PROTO_NAME);
                b.push(PROTO_LEVEL);
                let mut flags = 0u8;
                if *clean_session {
                    flags |= 0x02;
                }
                if let Some(w) = will {
                    flags |= 0x04 | (w.qos << 3);
                    if w.retain {
                        flags |= 0x20;
                    }
                }
                b.push(flags);
                put_u16(&mut b, *keep_alive);
                put_str(&mut b, client_id);
                if let Some(w) = will {
                    put_str(&mut b, &w.topic);
                    put_bytes16(&mut b, &w.payload);
                }
                (0x10, b)
            }
            Packet::ConnAck { session_present, code } => {
                b.push(*session_present as u8);
                b.push(*code);
                (0x20, b)
            }
            Packet::Publish { .. } => {
                unreachable!("publish is encoded via encode_parts")
            }
            Packet::PubAck { packet_id } => {
                put_u16(&mut b, *packet_id);
                (0x40, b)
            }
            Packet::Subscribe { packet_id, filters } => {
                put_u16(&mut b, *packet_id);
                for (f, qos) in filters {
                    put_str(&mut b, f);
                    b.push(*qos);
                }
                (0x82, b)
            }
            Packet::SubAck { packet_id, codes } => {
                put_u16(&mut b, *packet_id);
                b.extend_from_slice(codes);
                (0x90, b)
            }
            Packet::Unsubscribe { packet_id, filters } => {
                put_u16(&mut b, *packet_id);
                for f in filters {
                    put_str(&mut b, f);
                }
                (0xA2, b)
            }
            Packet::UnsubAck { packet_id } => {
                put_u16(&mut b, *packet_id);
                (0xB0, b)
            }
            Packet::PingReq => (0xC0, b),
            Packet::PingResp => (0xD0, b),
            Packet::Disconnect => (0xE0, b),
        })
    }

    /// Parse one packet from (first byte, borrowed body). PUBLISH payloads
    /// are copied out (counted); receive paths that own the body should
    /// use [`Packet::decode_owned`].
    pub fn decode(type_flags: u8, body: &[u8]) -> Result<Packet> {
        Self::decode_inner(type_flags, body, None)
    }

    /// Parse one packet from an owned body. PUBLISH payloads become
    /// zero-copy slice views into `body` — the hop's single allocation
    /// (the socket read) is shared all the way into the pipeline.
    pub fn decode_owned(type_flags: u8, body: Bytes) -> Result<Packet> {
        Self::decode_inner(type_flags, &body, Some(&body))
    }

    fn decode_inner(type_flags: u8, body: &[u8], shared: Option<&Bytes>) -> Result<Packet> {
        let mut c = Cursor { buf: body, off: 0 };
        let ptype = type_flags >> 4;
        Ok(match ptype {
            1 => {
                let proto = c.str16()?;
                let level = c.u8()?;
                if proto != PROTO_NAME || level != PROTO_LEVEL {
                    return Err(Error::Mqtt(format!("unsupported protocol {proto}/{level}")));
                }
                let flags = c.u8()?;
                let keep_alive = c.u16()?;
                let client_id = c.str16()?;
                let will = if flags & 0x04 != 0 {
                    let topic = c.str16()?;
                    let payload = c.bytes16()?;
                    Some(LastWill {
                        topic,
                        payload,
                        qos: (flags >> 3) & 0x03,
                        retain: flags & 0x20 != 0,
                    })
                } else {
                    None
                };
                Packet::Connect { client_id, keep_alive, clean_session: flags & 0x02 != 0, will }
            }
            2 => {
                let sp = c.u8()? & 0x01 != 0;
                let code = c.u8()?;
                Packet::ConnAck { session_present: sp, code }
            }
            3 => {
                let qos = (type_flags >> 1) & 0x03;
                if qos > 1 {
                    return Err(Error::Mqtt("QoS 2 not supported".into()));
                }
                let topic = c.str16()?;
                let packet_id = if qos > 0 { Some(c.u16()?) } else { None };
                let payload = match shared {
                    Some(b) => b.slice(c.off..),
                    None => Bytes::copy_from_slice(c.rest()),
                };
                Packet::Publish {
                    topic,
                    payload,
                    qos,
                    retain: type_flags & 0x01 != 0,
                    dup: type_flags & 0x08 != 0,
                    packet_id,
                }
            }
            4 => Packet::PubAck { packet_id: c.u16()? },
            8 => {
                let packet_id = c.u16()?;
                let mut filters = Vec::new();
                while !c.at_end() {
                    let f = c.str16()?;
                    let qos = c.u8()?;
                    filters.push((f, qos));
                }
                if filters.is_empty() {
                    return Err(Error::Mqtt("SUBSCRIBE with no filters".into()));
                }
                Packet::Subscribe { packet_id, filters }
            }
            9 => {
                let packet_id = c.u16()?;
                Packet::SubAck { packet_id, codes: c.rest().to_vec() }
            }
            10 => {
                let packet_id = c.u16()?;
                let mut filters = Vec::new();
                while !c.at_end() {
                    filters.push(c.str16()?);
                }
                Packet::Unsubscribe { packet_id, filters }
            }
            11 => Packet::UnsubAck { packet_id: c.u16()? },
            12 => Packet::PingReq,
            13 => Packet::PingResp,
            14 => Packet::Disconnect,
            other => return Err(Error::Mqtt(format!("unsupported packet type {other}"))),
        })
    }

    /// Read one packet from a blocking reader (fixed header + body).
    /// The body is this hop's single allocation; PUBLISH payloads are
    /// shared views into it.
    pub fn read<R: Read>(r: &mut R) -> Result<Packet> {
        let mut first = [0u8; 1];
        r.read_exact(&mut first)?;
        let mut rem: usize = 0;
        let mut shift = 0u32;
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)?;
            rem |= ((b[0] & 0x7f) as usize) << shift;
            if b[0] & 0x80 == 0 {
                break;
            }
            shift += 7;
            if shift > 21 {
                return Err(Error::Mqtt("remaining length overflow".into()));
            }
        }
        if rem > MAX_REMAINING {
            return Err(Error::Mqtt("packet too large".into()));
        }
        let mut body = vec![0u8; rem];
        r.read_exact(&mut body)?;
        Packet::decode_owned(first[0], Bytes::from(body))
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn u8(&mut self) -> Result<u8> {
        let v = *self.buf.get(self.off).ok_or_else(|| Error::Mqtt("short packet".into()))?;
        self.off += 1;
        Ok(v)
    }

    fn u16(&mut self) -> Result<u16> {
        let hi = self.u8()? as u16;
        let lo = self.u8()? as u16;
        Ok(hi << 8 | lo)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .buf
            .get(self.off..self.off + n)
            .ok_or_else(|| Error::Mqtt("short packet".into()))?;
        self.off += n;
        Ok(s)
    }

    fn str16(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let b = self.bytes(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Mqtt(format!("bad utf8: {e}")))
    }

    fn bytes16(&mut self) -> Result<Vec<u8>> {
        let n = self.u16()? as usize;
        Ok(self.bytes(n)?.to_vec())
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.off..];
        self.off = self.buf.len();
        s
    }

    fn at_end(&self) -> bool {
        self.off == self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(p: Packet) {
        let wire = p.encode().unwrap();
        let mut r = std::io::Cursor::new(wire);
        assert_eq!(Packet::read(&mut r).unwrap(), p);
    }

    fn publish(topic: &str, payload: Vec<u8>, qos: u8, retain: bool, dup: bool, packet_id: Option<u16>) -> Packet {
        Packet::Publish { topic: topic.into(), payload: payload.into(), qos, retain, dup, packet_id }
    }

    #[test]
    fn connect_roundtrip_plain() {
        roundtrip(Packet::Connect {
            client_id: "edge-1".into(),
            keep_alive: 30,
            clean_session: true,
            will: None,
        });
    }

    #[test]
    fn connect_roundtrip_with_will() {
        roundtrip(Packet::Connect {
            client_id: "srv".into(),
            keep_alive: 10,
            clean_session: true,
            will: Some(LastWill {
                topic: "edge/query/objdetect/srv".into(),
                payload: vec![],
                qos: 0,
                retain: true,
            }),
        });
    }

    #[test]
    fn publish_qos0_roundtrip() {
        roundtrip(publish("camleft", vec![1, 2, 3], 0, false, false, None));
    }

    #[test]
    fn publish_qos1_retain_roundtrip() {
        roundtrip(publish("t", vec![9; 1000], 1, true, true, Some(77)));
    }

    #[test]
    fn publish_empty_payload_roundtrip() {
        // Empty retained publish = "clear retained" — used for failover.
        roundtrip(publish("t", vec![], 0, true, false, None));
    }

    #[test]
    fn publish_head_plus_payload_equals_encode() {
        let p = publish("cam/left", vec![7u8; 300], 1, true, false, Some(5));
        let contiguous = p.encode().unwrap();
        let (head, payload) = p.encode_parts().unwrap();
        let payload = payload.unwrap();
        let mut assembled = head;
        assembled.extend_from_slice(&payload);
        assert_eq!(assembled, contiguous);
    }

    #[test]
    fn decode_owned_publish_payload_is_shared_view() {
        // 100-byte payload keeps remaining-length to one byte, so the
        // body starts at wire[2..].
        let p = publish("t", (0..100u8).collect(), 0, false, false, None);
        let wire = p.encode().unwrap();
        let mut r = std::io::Cursor::new(&wire);
        let got = Packet::read(&mut r).unwrap();
        assert_eq!(got, p);
        // Direct decode_owned: payload must share the body's backing.
        let body = Bytes::from(wire[2..].to_vec());
        match Packet::decode_owned(0x30, body.clone()).unwrap() {
            Packet::Publish { payload, .. } => {
                assert!(payload.same_backing(&body));
                assert_eq!(&payload[..], &(0..100u8).collect::<Vec<u8>>()[..]);
            }
            other => panic!("expected publish, got {other:?}"),
        }
    }

    #[test]
    fn sub_unsub_roundtrip() {
        roundtrip(Packet::Subscribe {
            packet_id: 5,
            filters: vec![("/objdetect/#".into(), 0), ("cam/+".into(), 1)],
        });
        roundtrip(Packet::SubAck { packet_id: 5, codes: vec![0, 1] });
        roundtrip(Packet::Unsubscribe { packet_id: 6, filters: vec!["a/b".into()] });
        roundtrip(Packet::UnsubAck { packet_id: 6 });
    }

    #[test]
    fn control_packets_roundtrip() {
        roundtrip(Packet::PingReq);
        roundtrip(Packet::PingResp);
        roundtrip(Packet::Disconnect);
        roundtrip(Packet::ConnAck { session_present: false, code: 0 });
        roundtrip(Packet::PubAck { packet_id: 99 });
    }

    #[test]
    fn large_payload_multibyte_remaining_length() {
        roundtrip(publish("big", vec![0xAB; 300_000], 0, false, false, None));
    }

    #[test]
    fn qos2_rejected() {
        let p = publish("t", vec![], 2, false, false, Some(1));
        assert!(p.encode().is_err());
    }

    #[test]
    fn qos1_without_id_rejected() {
        let p = publish("t", vec![], 1, false, false, None);
        assert!(p.encode().is_err());
    }

    #[test]
    fn bad_protocol_rejected() {
        let mut wire = Packet::Connect {
            client_id: "x".into(),
            keep_alive: 0,
            clean_session: true,
            will: None,
        }
        .encode()
        .unwrap();
        wire[4] = b'X'; // corrupt protocol name
        let mut r = std::io::Cursor::new(wire);
        assert!(Packet::read(&mut r).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let wire = Packet::PubAck { packet_id: 3 }.encode().unwrap();
        let mut r = std::io::Cursor::new(&wire[..wire.len() - 1]);
        assert!(Packet::read(&mut r).is_err());
    }

    #[test]
    fn empty_subscribe_rejected() {
        // type 8 with only a packet id
        let body = vec![0u8, 1];
        assert!(Packet::decode(0x82, &body).is_err());
    }
}
