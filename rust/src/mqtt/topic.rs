//! MQTT topic names and filters (wildcards `+` and `#`) — the discovery
//! mechanism of R3: clients choose publishers dynamically by topic filter,
//! e.g. subscribing `/objdetect/#` matches `/objdetect/mobilev3` and
//! `/objdetect/yolov2` (§4.2.2).
//!
//! [`matches`] is the linear REFERENCE implementation of §4.7 semantics;
//! the broker's production matching path is the segment-wise trie in
//! [`crate::mqtt::trie`], whose walks are property-tested against this
//! function over randomized topic/filter pairs
//! (`tests/test_broker_trie.rs`) so the two can never drift.

use crate::util::{Error, Result};

/// First `/`-separated level of a topic or filter (`""` for a leading
/// slash) — the broker's shard key: every topic a literal-first filter
/// can match shares the filter's first level, so subscriptions and the
/// topics they match always hash to the same shard.
pub fn first_level(topic_or_filter: &str) -> &str {
    topic_or_filter.split('/').next().unwrap_or("")
}

/// Validate a topic NAME (publish target): non-empty, no wildcards, no NUL.
pub fn validate_name(topic: &str) -> Result<()> {
    if topic.is_empty() || topic.len() > 65535 {
        return Err(Error::Mqtt(format!("bad topic length {}", topic.len())));
    }
    if topic.contains(['+', '#', '\0']) {
        return Err(Error::Mqtt(format!("topic `{topic}` contains wildcard/NUL")));
    }
    Ok(())
}

/// Validate a topic FILTER (subscription): `+` must occupy a whole level,
/// `#` must be the last level.
pub fn validate_filter(filter: &str) -> Result<()> {
    if filter.is_empty() || filter.len() > 65535 {
        return Err(Error::Mqtt(format!("bad filter length {}", filter.len())));
    }
    if filter.contains('\0') {
        return Err(Error::Mqtt("filter contains NUL".into()));
    }
    let levels: Vec<&str> = filter.split('/').collect();
    for (i, level) in levels.iter().enumerate() {
        if level.contains('#') {
            if *level != "#" || i != levels.len() - 1 {
                return Err(Error::Mqtt(format!("`#` misplaced in `{filter}`")));
            }
        }
        if level.contains('+') && *level != "+" {
            return Err(Error::Mqtt(format!("`+` must fill a level in `{filter}`")));
        }
    }
    Ok(())
}

/// MQTT 3.1.1 §4.7 matching. Assumes both sides validated.
///
/// Per §4.7.2, topics whose FIRST level starts with `$` (broker-internal
/// namespaces like `$SYS`) are invisible to filters that start with a
/// wildcard: `#` and `+/...` must not match `$SYS/...` — only a filter
/// that spells the `$` level out literally (`$SYS/#`) may. Without this,
/// every wildcard subscriber leaks broker-internal traffic.
pub fn matches(filter: &str, topic: &str) -> bool {
    if topic.starts_with('$') {
        let first = filter.split('/').next().unwrap_or("");
        if first == "#" || first == "+" {
            return false;
        }
    }
    let mut f = filter.split('/');
    let mut t = topic.split('/');
    loop {
        match (f.next(), t.next()) {
            // '#' matches the rest INCLUDING the parent level
            // ("sport/tennis/#" matches "sport/tennis" per spec §4.7).
            (Some("#"), _) => return true,
            (Some("+"), Some(_)) => continue,
            (Some(fl), Some(tl)) if fl == tl => continue,
            (None, None) => return true,
            _ => return false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match() {
        assert!(matches("a/b/c", "a/b/c"));
        assert!(!matches("a/b/c", "a/b"));
        assert!(!matches("a/b", "a/b/c"));
        assert!(!matches("a/b/c", "a/b/x"));
    }

    #[test]
    fn plus_wildcard() {
        assert!(matches("a/+/c", "a/b/c"));
        assert!(matches("+/+/+", "a/b/c"));
        assert!(!matches("a/+", "a/b/c"));
        assert!(matches("+", "abc"));
        assert!(!matches("+", "a/b"));
    }

    #[test]
    fn hash_wildcard() {
        assert!(matches("a/#", "a/b/c"));
        assert!(matches("a/#", "a"));
        assert!(matches("#", "anything/at/all"));
        assert!(!matches("a/#", "b/c"));
    }

    #[test]
    fn paper_objdetect_example() {
        // §4.2.2: client subscribes "/objdetect/#" to pick any server.
        assert!(matches("/objdetect/#", "/objdetect/mobilev3"));
        assert!(matches("/objdetect/#", "/objdetect/yolov2"));
        assert!(!matches("/objdetect/#", "/posenet/v1"));
    }

    #[test]
    fn leading_slash_levels_are_distinct() {
        assert!(!matches("a/b", "/a/b"));
        assert!(matches("/+/b", "/a/b")); // '+' matches the empty first level? no:
                                          // "/a/b" splits to ["", "a", "b"], "/+/b" to ["", "+", "b"]
    }

    #[test]
    fn dollar_topics_hidden_from_leading_wildcards() {
        // §4.7.2: a filter starting with a wildcard must not match topics
        // whose first level starts with '$'.
        assert!(!matches("#", "$SYS/broker/load"));
        assert!(!matches("#", "$SYS"));
        assert!(!matches("+/broker/load", "$SYS/broker/load"));
        assert!(!matches("+", "$SYS"));
        // Spelling the $-level out literally still works.
        assert!(matches("$SYS/#", "$SYS/broker/load"));
        assert!(matches("$SYS/+/load", "$SYS/broker/load"));
        assert!(matches("$SYS/broker/load", "$SYS/broker/load"));
        // Only the FIRST topic level is special: '$' deeper in the tree
        // is an ordinary character.
        assert!(matches("a/#", "a/$weird/level"));
        assert!(matches("a/+/level", "a/$weird/level"));
    }

    #[test]
    fn name_validation() {
        assert!(validate_name("a/b").is_ok());
        assert!(validate_name("").is_err());
        assert!(validate_name("a/+/b").is_err());
        assert!(validate_name("a/#").is_err());
        assert!(validate_name("a\0b").is_err());
    }

    #[test]
    fn filter_validation() {
        assert!(validate_filter("a/+/b").is_ok());
        assert!(validate_filter("a/#").is_ok());
        assert!(validate_filter("#").is_ok());
        assert!(validate_filter("a/#/b").is_err());
        assert!(validate_filter("a/b#").is_err());
        assert!(validate_filter("a/b+/c").is_err());
        assert!(validate_filter("").is_err());
    }
}
