//! Segment-wise subscription/retained-topic tries — O(topic depth)
//! matching instead of O(subscriptions) linear scans.
//!
//! Two structures share the level-by-level layout:
//!
//! - [`SubTrie`]: topic FILTERS (with `+`/`#` wildcards) mapped to
//!   subscriber values. [`SubTrie::collect`] walks a published topic
//!   name down the trie, visiting only the literal child for each level
//!   plus the `+` branch and any `#` leaves passed on the way — the
//!   cost is bounded by topic depth times the number of wildcard
//!   branches alive at each level, independent of how many
//!   subscriptions exist on unrelated topics.
//! - [`RetainedTrie`]: retained topic NAMES (no wildcards) mapped to
//!   payloads. [`RetainedTrie::collect_matching`] walks a subscription
//!   filter down the trie (a `+` level fans out across children, a
//!   trailing `#` collects a subtree), so a new subscriber's retained
//!   delivery no longer scans every retained topic in the broker.
//!
//! Both walks reproduce the MQTT 3.1.1 §4.7 semantics already pinned by
//! `topic::matches` tests, including the §4.7.2 rule: topics whose FIRST
//! level starts with `$` are invisible to filters whose first level is a
//! wildcard; `$` deeper in the tree is an ordinary character. The
//! equivalence is enforced by randomized property tests
//! (`tests/test_broker_trie.rs`) comparing every trie walk against the
//! linear [`topic::matches`] reference.

use std::collections::HashMap;
use std::sync::Arc;

use crate::buffer::Bytes;

/// One trie level: literal children, the `+` branch, and terminal values.
struct Node<T> {
    children: HashMap<Box<str>, Node<T>>,
    /// Subtree for filters with `+` at this level.
    plus: Option<Box<Node<T>>>,
    /// Values of filters ending exactly at this level.
    here: Vec<T>,
    /// Values of filters ending with `#` as the NEXT level (`a/b/#`
    /// stores at the `a/b` node; per §4.7 it matches `a/b` itself and
    /// everything below it).
    hash: Vec<T>,
}

impl<T> Default for Node<T> {
    fn default() -> Self {
        Node { children: HashMap::new(), plus: None, here: Vec::new(), hash: Vec::new() }
    }
}

impl<T> Node<T> {
    fn is_empty(&self) -> bool {
        self.children.is_empty() && self.plus.is_none() && self.here.is_empty() && self.hash.is_empty()
    }
}

/// Subscription trie: filter → values, matched by topic name.
pub struct SubTrie<T> {
    root: Node<T>,
    len: usize,
}

impl<T> Default for SubTrie<T> {
    fn default() -> Self {
        SubTrie { root: Node::default(), len: 0 }
    }
}

impl<T> SubTrie<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of stored values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert `value` under `filter` (assumed already validated).
    pub fn insert(&mut self, filter: &str, value: T) {
        let mut node = &mut self.root;
        for level in filter.split('/') {
            match level {
                "#" => {
                    // validate_filter guarantees '#' is last.
                    node.hash.push(value);
                    self.len += 1;
                    return;
                }
                "+" => node = node.plus.get_or_insert_with(Default::default),
                lit => {
                    if !node.children.contains_key(lit) {
                        node.children.insert(Box::from(lit), Node::default());
                    }
                    node = node.children.get_mut(lit).expect("just inserted");
                }
            }
        }
        node.here.push(value);
        self.len += 1;
    }

    /// Remove every value under `filter` for which `pred` holds,
    /// pruning emptied branches. Returns how many were removed.
    pub fn remove_where(&mut self, filter: &str, mut pred: impl FnMut(&T) -> bool) -> usize {
        let levels: Vec<&str> = filter.split('/').collect();
        let (removed, _) = remove_rec(&mut self.root, &levels, &mut pred);
        self.len -= removed;
        removed
    }

    /// Append every value whose filter matches `topic` to `out`.
    ///
    /// A session subscribed to several overlapping filters appears once
    /// per matching filter; the caller dedups (the broker delivers one
    /// copy per session, as the flat-list implementation did).
    pub fn collect<'a>(&'a self, topic: &str, out: &mut Vec<&'a T>) {
        let levels: Vec<&str> = topic.split('/').collect();
        // §4.7.2: wildcard-leading filters never match '$'-first topics.
        let hide_from_wildcards = topic.starts_with('$');
        collect_rec(&self.root, &levels, hide_from_wildcards, out);
    }

    /// Convenience wrapper for tests: matching values as a fresh Vec.
    pub fn matches<'a>(&'a self, topic: &str) -> Vec<&'a T> {
        let mut out = Vec::new();
        self.collect(topic, &mut out);
        out
    }
}

/// Recursive removal; returns (values removed, subtree now empty).
fn remove_rec<T>(
    node: &mut Node<T>,
    levels: &[&str],
    pred: &mut impl FnMut(&T) -> bool,
) -> (usize, bool) {
    match levels.split_first() {
        None => {
            let before = node.here.len();
            node.here.retain(|v| !pred(v));
            (before - node.here.len(), node.is_empty())
        }
        Some((&"#", _)) => {
            let before = node.hash.len();
            node.hash.retain(|v| !pred(v));
            (before - node.hash.len(), node.is_empty())
        }
        Some((&"+", rest)) => {
            let mut removed = 0;
            if let Some(p) = node.plus.as_deref_mut() {
                let (r, empty) = remove_rec(p, rest, pred);
                removed = r;
                if empty {
                    node.plus = None;
                }
            }
            (removed, node.is_empty())
        }
        Some((lit, rest)) => {
            let mut removed = 0;
            if let Some(child) = node.children.get_mut(*lit) {
                let (r, empty) = remove_rec(child, rest, pred);
                removed = r;
                if empty {
                    node.children.remove(*lit);
                }
            }
            (removed, node.is_empty())
        }
    }
}

fn collect_rec<'a, T>(
    node: &'a Node<T>,
    levels: &[&str],
    hide_from_wildcards: bool,
    out: &mut Vec<&'a T>,
) {
    // Filters ending in '#' at this node match the remaining levels —
    // including none at all ("sport/tennis/#" matches "sport/tennis").
    if !hide_from_wildcards {
        out.extend(node.hash.iter());
    }
    match levels.split_first() {
        None => out.extend(node.here.iter()),
        Some((level, rest)) => {
            if !hide_from_wildcards {
                if let Some(p) = node.plus.as_deref() {
                    collect_rec(p, rest, false, out);
                }
            }
            if let Some(child) = node.children.get(*level) {
                // The '$'-hiding rule applies to the FIRST level only: a
                // literal first-level match re-admits wildcards below.
                collect_rec(child, rest, false, out);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Retained-topic trie
// ---------------------------------------------------------------------------

/// One retained message: shared topic string + shared payload view, so
/// delivery to a new subscriber clones two Arcs, never the bytes.
#[derive(Clone)]
pub struct Retained {
    pub topic: Arc<str>,
    pub payload: Bytes,
}

#[derive(Default)]
struct RNode {
    children: HashMap<Box<str>, RNode>,
    value: Option<Retained>,
}

impl RNode {
    fn is_empty(&self) -> bool {
        self.children.is_empty() && self.value.is_none()
    }
}

/// Retained topics stored level-wise, queried by subscription filter.
#[derive(Default)]
pub struct RetainedTrie {
    root: RNode,
    len: usize,
}

impl RetainedTrie {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Store (or replace) the retained payload for `topic`.
    pub fn insert(&mut self, topic: &str, payload: Bytes) {
        let mut node = &mut self.root;
        for level in topic.split('/') {
            if !node.children.contains_key(level) {
                node.children.insert(Box::from(level), RNode::default());
            }
            node = node.children.get_mut(level).expect("just inserted");
        }
        if node.value.replace(Retained { topic: Arc::from(topic), payload }).is_none() {
            self.len += 1;
        }
    }

    /// Clear the retained payload for `topic` (empty-payload publish).
    pub fn remove(&mut self, topic: &str) {
        let levels: Vec<&str> = topic.split('/').collect();
        if rremove_rec(&mut self.root, &levels).0 {
            self.len -= 1;
        }
    }

    /// Append every retained message whose topic matches `filter`.
    pub fn collect_matching(&self, filter: &str, out: &mut Vec<Retained>) {
        let levels: Vec<&str> = filter.split('/').collect();
        rcollect_rec(&self.root, &levels, true, out);
    }

    /// All stored topics (test/introspection helper).
    pub fn topics(&self) -> Vec<Arc<str>> {
        let mut out = Vec::with_capacity(self.len);
        fn walk(node: &RNode, out: &mut Vec<Arc<str>>) {
            if let Some(r) = &node.value {
                out.push(r.topic.clone());
            }
            for child in node.children.values() {
                walk(child, out);
            }
        }
        walk(&self.root, &mut out);
        out
    }
}

/// Returns (value removed, subtree now empty).
fn rremove_rec(node: &mut RNode, levels: &[&str]) -> (bool, bool) {
    match levels.split_first() {
        None => {
            let removed = node.value.take().is_some();
            (removed, node.is_empty())
        }
        Some((lit, rest)) => {
            let mut removed = false;
            if let Some(child) = node.children.get_mut(*lit) {
                let (r, empty) = rremove_rec(child, rest);
                removed = r;
                if empty {
                    node.children.remove(*lit);
                }
            }
            (removed, node.is_empty())
        }
    }
}

/// Walk a FILTER over stored topics. `first` tracks whether we are still
/// matching the first topic level (for the §4.7.2 `$` rule).
fn rcollect_rec(node: &RNode, levels: &[&str], first: bool, out: &mut Vec<Retained>) {
    match levels.split_first() {
        None => {
            if let Some(r) = &node.value {
                out.push(r.clone());
            }
        }
        Some((&"#", _)) => {
            // '#' matches this level and below; at the first level it
            // must skip '$'-prefixed children entirely.
            fn subtree(node: &RNode, out: &mut Vec<Retained>) {
                if let Some(r) = &node.value {
                    out.push(r.clone());
                }
                for child in node.children.values() {
                    subtree(child, out);
                }
            }
            if let Some(r) = &node.value {
                out.push(r.clone());
            }
            for (seg, child) in &node.children {
                if first && seg.starts_with('$') {
                    continue;
                }
                subtree(child, out);
            }
        }
        Some((&"+", rest)) => {
            for (seg, child) in &node.children {
                if first && seg.starts_with('$') {
                    continue;
                }
                rcollect_rec(child, rest, false, out);
            }
        }
        Some((lit, rest)) => {
            if let Some(child) = node.children.get(*lit) {
                rcollect_rec(child, rest, false, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collected(trie: &SubTrie<u32>, topic: &str) -> Vec<u32> {
        let mut v: Vec<u32> = trie.matches(topic).into_iter().copied().collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn exact_plus_hash_basics() {
        let mut t = SubTrie::new();
        t.insert("a/b/c", 1);
        t.insert("a/+/c", 2);
        t.insert("a/#", 3);
        t.insert("#", 4);
        t.insert("a/b", 5);
        assert_eq!(collected(&t, "a/b/c"), vec![1, 2, 3, 4]);
        assert_eq!(collected(&t, "a/b"), vec![3, 4, 5]);
        // '#' matches the parent level itself.
        assert_eq!(collected(&t, "a"), vec![3, 4]);
        assert_eq!(collected(&t, "x"), vec![4]);
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn dollar_topics_hidden_from_leading_wildcards() {
        let mut t = SubTrie::new();
        t.insert("#", 1);
        t.insert("+/broker/load", 2);
        t.insert("$SYS/#", 3);
        t.insert("$SYS/broker/load", 4);
        t.insert("$SYS/+/load", 5);
        assert_eq!(collected(&t, "$SYS/broker/load"), vec![3, 4, 5]);
        assert_eq!(collected(&t, "$SYS"), vec![3]);
        // '$' deeper in the tree is ordinary.
        t.insert("a/#", 6);
        t.insert("a/+/level", 7);
        assert_eq!(collected(&t, "a/$weird/level"), vec![1, 6, 7]);
    }

    #[test]
    fn empty_levels_are_distinct() {
        let mut t = SubTrie::new();
        t.insert("a/b", 1);
        t.insert("/a/b", 2);
        t.insert("/+/b", 3);
        assert_eq!(collected(&t, "a/b"), vec![1]);
        assert_eq!(collected(&t, "/a/b"), vec![2, 3]);
    }

    #[test]
    fn remove_where_prunes_branches() {
        let mut t = SubTrie::new();
        t.insert("a/b/c", 1);
        t.insert("a/b/c", 2);
        t.insert("a/+/#", 3);
        assert_eq!(t.remove_where("a/b/c", |v| *v == 1), 1);
        assert_eq!(collected(&t, "a/b/c"), vec![2, 3]);
        assert_eq!(t.remove_where("a/b/c", |v| *v == 2), 1);
        assert_eq!(t.remove_where("a/+/#", |v| *v == 3), 1);
        assert!(t.is_empty());
        assert!(t.root.children.is_empty(), "emptied branches must be pruned");
        // Removing from a now-empty trie is a no-op.
        assert_eq!(t.remove_where("a/b/c", |_| true), 0);
    }

    #[test]
    fn retained_insert_replace_remove() {
        let mut r = RetainedTrie::new();
        r.insert("svc/ad", Bytes::from(b"one".as_slice().to_vec()));
        r.insert("svc/ad", Bytes::from(b"two".as_slice().to_vec()));
        assert_eq!(r.len(), 1);
        let mut out = Vec::new();
        r.collect_matching("svc/+", &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].payload.as_slice(), b"two");
        assert_eq!(&*out[0].topic, "svc/ad");
        r.remove("svc/ad");
        assert!(r.is_empty());
        assert!(r.root.children.is_empty(), "emptied branches must be pruned");
    }

    #[test]
    fn retained_filter_walk_semantics() {
        let mut r = RetainedTrie::new();
        for t in ["a", "a/b", "a/b/c", "x/y", "$SYS/load", "$SYS/x/y"] {
            r.insert(t, Bytes::from(t.as_bytes().to_vec()));
        }
        let q = |f: &str| {
            let mut out = Vec::new();
            r.collect_matching(f, &mut out);
            let mut v: Vec<String> = out.iter().map(|m| m.topic.to_string()).collect();
            v.sort();
            v
        };
        assert_eq!(q("a/#"), vec!["a", "a/b", "a/b/c"]);
        assert_eq!(q("a/+"), vec!["a/b"]);
        assert_eq!(q("#"), vec!["a", "a/b", "a/b/c", "x/y"]);
        assert_eq!(q("+/y"), vec!["x/y"]);
        assert_eq!(q("$SYS/#"), vec!["$SYS/load", "$SYS/x/y"]);
        assert_eq!(q("$SYS/+"), vec!["$SYS/load"]);
        assert!(q("b/#").is_empty());
        let mut topics = r.topics().iter().map(|t| t.to_string()).collect::<Vec<_>>();
        topics.sort();
        assert_eq!(topics, vec!["$SYS/load", "$SYS/x/y", "a", "a/b", "a/b/c", "x/y"]);
    }
}
