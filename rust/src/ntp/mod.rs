//! Simplified NTP over UDP: 4-timestamp clock-offset estimation between
//! pipelines (§4.2.3 — the timestamp-synchronization substrate).
//!
//! The publisher (mqttsink side) runs an [`NtpServer`]; the subscriber
//! (mqttsrc side) runs [`estimate_offset`] to learn `offset` such that
//! `remote_universal + offset ≈ local_universal`, then corrects incoming
//! buffer timestamps via [`crate::clock::PipelineClock::remote_pts_to_local`].
//!
//! Protocol: client sends `t1` (its send time); server replies with
//! `(t1, t2, t3)` = (echo, receive time, transmit time); client stamps
//! `t4` on receipt. Standard NTP math:
//! `offset = ((t2 - t1) + (t3 - t4)) / 2`, `delay = (t4 - t1) - (t3 - t2)`.

use std::net::UdpSocket;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::clock::universal_time;
use crate::util::{Error, Result};
use crate::{log_debug, log_info};

const MAGIC: &[u8; 4] = b"EPNT";
const REQ_LEN: usize = 4 + 8;
const RESP_LEN: usize = 4 + 24;

/// A running NTP responder bound to a UDP port.
pub struct NtpServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
}

impl NtpServer {
    pub fn start(bind: &str) -> Result<NtpServer> {
        let sock =
            UdpSocket::bind(bind).map_err(|e| Error::Transport(format!("ntp bind {bind}: {e}")))?;
        let addr = sock.local_addr()?;
        sock.set_read_timeout(Some(Duration::from_millis(200)))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let t_shutdown = shutdown.clone();
        std::thread::Builder::new()
            .name("ntp-server".into())
            .spawn(move || {
                log_info!("ntp", "server on {addr}");
                let mut buf = [0u8; 64];
                while !t_shutdown.load(Ordering::Relaxed) {
                    match sock.recv_from(&mut buf) {
                        Ok((n, peer)) if n >= REQ_LEN && &buf[..4] == MAGIC => {
                            let t2 = universal_time();
                            let mut resp = [0u8; RESP_LEN];
                            resp[..4].copy_from_slice(MAGIC);
                            resp[4..12].copy_from_slice(&buf[4..12]); // echo t1
                            resp[12..20].copy_from_slice(&t2.to_le_bytes());
                            let t3 = universal_time();
                            resp[20..28].copy_from_slice(&t3.to_le_bytes());
                            let _ = sock.send_to(&resp, peer);
                        }
                        Ok(_) => {}
                        Err(e)
                            if e.kind() == std::io::ErrorKind::WouldBlock
                                || e.kind() == std::io::ErrorKind::TimedOut => {}
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn ntp server");
        Ok(NtpServer { addr, shutdown })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for NtpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

/// One offset sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Remote-to-local clock offset in ns (add to remote timestamps).
    pub offset_ns: i64,
    /// Round-trip delay in ns (quality indicator; lower = better).
    pub delay_ns: i64,
}

/// Query a server once.
pub fn query(server: &str, timeout: Duration) -> Result<Sample> {
    let sock = UdpSocket::bind("0.0.0.0:0")?;
    sock.set_read_timeout(Some(timeout))?;
    let mut req = [0u8; REQ_LEN];
    req[..4].copy_from_slice(MAGIC);
    let t1 = universal_time();
    req[4..12].copy_from_slice(&t1.to_le_bytes());
    sock.send_to(&req, server)
        .map_err(|e| Error::Transport(format!("ntp send {server}: {e}")))?;
    let mut resp = [0u8; RESP_LEN];
    let (n, _) = sock
        .recv_from(&mut resp)
        .map_err(|e| Error::Transport(format!("ntp recv: {e}")))?;
    let t4 = universal_time();
    if n < RESP_LEN || &resp[..4] != MAGIC {
        return Err(Error::Transport("bad ntp response".into()));
    }
    let echo_t1 = u64::from_le_bytes(resp[4..12].try_into().unwrap());
    if echo_t1 != t1 {
        return Err(Error::Transport("ntp response/request mismatch".into()));
    }
    let t2 = u64::from_le_bytes(resp[12..20].try_into().unwrap()) as i128;
    let t3 = u64::from_le_bytes(resp[20..28].try_into().unwrap()) as i128;
    let t1 = t1 as i128;
    let t4 = t4 as i128;
    let offset = ((t2 - t1) + (t3 - t4)) / 2;
    let delay = (t4 - t1) - (t3 - t2);
    Ok(Sample { offset_ns: offset as i64, delay_ns: delay as i64 })
}

/// Query `n` times and return the sample with the lowest round-trip delay
/// (the standard burst-and-pick-best estimator).
pub fn estimate_offset(server: &str, n: usize, timeout: Duration) -> Result<Sample> {
    let mut best: Option<Sample> = None;
    let mut last_err = None;
    for _ in 0..n.max(1) {
        match query(server, timeout) {
            Ok(s) => {
                if best.map_or(true, |b| s.delay_ns < b.delay_ns) {
                    best = Some(s);
                }
            }
            Err(e) => last_err = Some(e),
        }
    }
    best.ok_or_else(|| last_err.unwrap_or_else(|| Error::Transport("ntp: no samples".into())))
}

/// Continuously refreshed offset estimate shared with transport elements.
#[derive(Clone)]
pub struct SyncedClock {
    offset: Arc<std::sync::atomic::AtomicI64>,
    valid: Arc<AtomicBool>,
}

impl Default for SyncedClock {
    fn default() -> Self {
        Self::new()
    }
}

impl SyncedClock {
    pub fn new() -> Self {
        Self {
            offset: Arc::new(std::sync::atomic::AtomicI64::new(0)),
            valid: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Offset to add to remote universal timestamps (0 until synced).
    pub fn offset_ns(&self) -> i64 {
        self.offset.load(Ordering::Relaxed)
    }

    pub fn is_synced(&self) -> bool {
        self.valid.load(Ordering::Relaxed)
    }

    pub fn set(&self, offset_ns: i64) {
        self.offset.store(offset_ns, Ordering::Relaxed);
        self.valid.store(true, Ordering::Relaxed);
    }

    /// Sync once against `server` (burst of `n`).
    pub fn sync_once(&self, server: &str, n: usize) -> Result<Sample> {
        let s = estimate_offset(server, n, Duration::from_millis(500))?;
        self.set(s.offset_ns);
        log_debug!("ntp", "synced to {server}: offset {} us, delay {} us", s.offset_ns / 1000, s.delay_ns / 1000);
        Ok(s)
    }

    /// Spawn a background refresher (every `interval`).
    pub fn sync_periodic(&self, server: String, interval: Duration) {
        let me = self.clone();
        std::thread::Builder::new()
            .name("ntp-refresh".into())
            .spawn(move || loop {
                let _ = me.sync_once(&server, 4);
                std::thread::sleep(interval);
            })
            .expect("spawn ntp refresher");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_host_offset_near_zero() {
        let server = NtpServer::start("127.0.0.1:0").unwrap();
        let s = estimate_offset(&server.addr().to_string(), 8, Duration::from_secs(1)).unwrap();
        // Same machine, same clock: offset must be within the RTT.
        assert!(s.offset_ns.abs() < 50_000_000, "offset {} ns", s.offset_ns);
        assert!(s.delay_ns >= 0, "delay {} ns", s.delay_ns);
    }

    #[test]
    fn burst_picks_lowest_delay() {
        let server = NtpServer::start("127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();
        let a = query(&addr, Duration::from_secs(1)).unwrap();
        let best = estimate_offset(&addr, 10, Duration::from_secs(1)).unwrap();
        assert!(best.delay_ns <= a.delay_ns.max(best.delay_ns));
    }

    #[test]
    fn unreachable_server_errors() {
        // Reserved port with (very likely) nothing listening + short timeout.
        let r = query("127.0.0.1:9", Duration::from_millis(100));
        assert!(r.is_err());
    }

    #[test]
    fn synced_clock_lifecycle() {
        let c = SyncedClock::new();
        assert!(!c.is_synced());
        assert_eq!(c.offset_ns(), 0);
        c.set(12345);
        assert!(c.is_synced());
        assert_eq!(c.offset_ns(), 12345);
    }

    #[test]
    fn synced_clock_via_server() {
        let server = NtpServer::start("127.0.0.1:0").unwrap();
        let c = SyncedClock::new();
        c.sync_once(&server.addr().to_string(), 4).unwrap();
        assert!(c.is_synced());
        assert!(c.offset_ns().abs() < 50_000_000);
    }
}
