//! Pipeline graph + hybrid runner (GStreamer core analog).
//!
//! Build a [`Pipeline`] by adding elements and linking pads (or parse a
//! gst-launch-style description — [`parser`]), then [`Pipeline::start`]
//! it: links become bounded inboxes, EOS and errors surface on the bus.
//! `Workload::Compute` elements are handed to the process-wide worker
//! pool ([`crate::element::sched`]) so N pipelines share K threads;
//! `Workload::Blocking` elements (sockets, app channels, live pacing)
//! get a dedicated thread as before. `EDGEPIPE_SCHED=threads` forces the
//! legacy thread-per-element runner for every node.

pub mod parser;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::clock::PipelineClock;
use crate::element::sched::{self, NodeRun, Scheduler, Task, TaskGroup};
use crate::element::{
    BusMsg, Ctx, Downstream, Element, EosTracker, Inbox, Item, Progress, Workload,
};
use crate::util::{Error, Result};
use crate::{log_debug, log_info};

/// How [`Pipeline::start`] maps elements to execution resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// `Compute` elements share the worker pool; `Blocking` ones get
    /// threads (the default).
    Pool,
    /// Legacy thread-per-element runner for every node.
    Threads,
}

impl ExecMode {
    /// `EDGEPIPE_SCHED=threads` (or `off`) opts out of the pool.
    pub fn from_env() -> Self {
        match std::env::var("EDGEPIPE_SCHED").ok().as_deref() {
            Some("threads") | Some("off") => ExecMode::Threads,
            _ => ExecMode::Pool,
        }
    }
}

struct Node {
    name: String,
    element: Box<dyn Element>,
}

/// A pipeline under construction.
pub struct Pipeline {
    nodes: Vec<Node>,
    /// (src node, src pad) -> (dst node, dst pad)
    links: Vec<((usize, usize), (usize, usize))>,
    names: HashMap<String, usize>,
}

impl Default for Pipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Pipeline {
    pub fn new() -> Self {
        Self { nodes: Vec::new(), links: Vec::new(), names: HashMap::new() }
    }

    /// Add an element under a unique name (empty = auto-generated).
    pub fn add(&mut self, name: &str, element: Box<dyn Element>) -> Result<usize> {
        let name = if name.is_empty() {
            format!("element{}", self.nodes.len())
        } else {
            name.to_string()
        };
        if self.names.contains_key(&name) {
            return Err(Error::Pipeline(format!("duplicate element name `{name}`")));
        }
        let id = self.nodes.len();
        self.names.insert(name.clone(), id);
        self.nodes.push(Node { name, element });
        Ok(id)
    }

    pub fn by_name(&self, name: &str) -> Option<usize> {
        self.names.get(name).copied()
    }

    pub fn node_name(&self, id: usize) -> &str {
        &self.nodes[id].name
    }

    pub fn element_mut(&mut self, id: usize) -> &mut dyn Element {
        self.nodes[id].element.as_mut()
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Link `from`'s src pad to `to`'s sink pad. A src pad may fan out to
    /// several sinks (implicit tee); a sink pad accepts exactly one link.
    pub fn link_pads(&mut self, from: usize, from_pad: usize, to: usize, to_pad: usize) -> Result<()> {
        let nf = self.nodes.get(from).ok_or_else(|| Error::Pipeline(format!("bad node {from}")))?;
        let nt = self.nodes.get(to).ok_or_else(|| Error::Pipeline(format!("bad node {to}")))?;
        if from_pad >= nf.element.n_src_pads() {
            return Err(Error::Pipeline(format!(
                "`{}` has {} src pads, pad {from_pad} requested",
                nf.name,
                nf.element.n_src_pads()
            )));
        }
        if to_pad >= nt.element.n_sink_pads() {
            return Err(Error::Pipeline(format!(
                "`{}` has {} sink pads, pad {to_pad} requested",
                nt.name,
                nt.element.n_sink_pads()
            )));
        }
        if self.links.iter().any(|(_, t)| *t == (to, to_pad)) {
            return Err(Error::Pipeline(format!(
                "sink pad {to_pad} of `{}` already linked",
                nt.name
            )));
        }
        self.links.push(((from, from_pad), (to, to_pad)));
        Ok(())
    }

    /// Link pad 0 -> pad 0 (the common chain case).
    pub fn link(&mut self, from: usize, to: usize) -> Result<()> {
        self.link_pads(from, 0, to, 0)
    }

    fn validate(&self) -> Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            for pad in 0..n.element.n_sink_pads() {
                if !self.links.iter().any(|(_, t)| *t == (i, pad)) {
                    return Err(Error::Pipeline(format!(
                        "sink pad {pad} of `{}` is not linked",
                        n.name
                    )));
                }
            }
            if n.element.n_sink_pads() == 0 && n.element.n_src_pads() == 0 {
                return Err(Error::Pipeline(format!("`{}` has no pads", n.name)));
            }
        }
        if self.nodes.is_empty() {
            return Err(Error::Pipeline("empty pipeline".into()));
        }
        Ok(())
    }

    /// Start streaming with the mode from `EDGEPIPE_SCHED` (pool unless
    /// opted out). Consumes the pipeline.
    pub fn start(self) -> Result<Running> {
        self.start_mode(ExecMode::from_env())
    }

    /// Start streaming: pooled tasks for compute elements, threads for
    /// blocking ones (or threads for everything under
    /// [`ExecMode::Threads`]). Consumes the pipeline.
    pub fn start_mode(self, mode: ExecMode) -> Result<Running> {
        self.start_inner(mode, None)
    }

    /// Bench/test hook: run the pipeline's `Compute` elements on a
    /// specific (detached) pool instead of [`sched::global`] — lets one
    /// process compare queue architectures. Production code always goes
    /// through [`Pipeline::start`].
    #[doc(hidden)]
    pub fn start_pooled_on(self, scheduler: &Arc<Scheduler>) -> Result<Running> {
        self.start_inner(ExecMode::Pool, Some(scheduler))
    }

    fn start_inner(self, mode: ExecMode, on: Option<&Arc<Scheduler>>) -> Result<Running> {
        self.validate()?;
        let clock = PipelineClock::start();
        let stop = Arc::new(AtomicBool::new(false));
        let (bus_tx, bus_rx): (Sender<BusMsg>, Receiver<BusMsg>) = channel();

        // Inboxes for nodes with sink pads.
        let mut inboxes: Vec<Option<Arc<Inbox>>> = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let pads = n.element.n_sink_pads();
            if pads == 0 {
                inboxes.push(None);
            } else {
                let cfgs = (0..pads).map(|p| n.element.sink_queue_cfg(p)).collect();
                inboxes.push(Some(Arc::new(Inbox::new(cfgs))));
            }
        }

        // Downstream tables.
        let mut downstreams: Vec<Vec<Vec<(Arc<Inbox>, usize)>>> = self
            .nodes
            .iter()
            .map(|n| vec![Vec::new(); n.element.n_src_pads()])
            .collect();
        for ((f, fp), (t, tp)) in &self.links {
            let ib = inboxes[*t].as_ref().expect("linked sink without inbox").clone();
            downstreams[*f][*fp].push((ib, *tp));
        }

        let n_sinks = self.nodes.iter().filter(|n| n.element.n_src_pads() == 0).count();
        let mut handles = Vec::new();
        let mut pooled: Vec<(Node, Ctx, Option<Arc<Inbox>>)> = Vec::new();
        for (i, node) in self.nodes.into_iter().enumerate() {
            let ds = Downstream { outputs: std::mem::take(&mut downstreams[i]) };
            let ctx = Ctx::new(node.name.clone(), clock, ds, bus_tx.clone(), stop.clone());
            let inbox = inboxes[i].clone();
            let pool = mode == ExecMode::Pool && node.element.workload() == Workload::Compute;
            if pool {
                pooled.push((node, ctx, inbox));
            } else {
                handles.push(spawn_node(node, ctx, inbox)?);
            }
        }
        let group = TaskGroup::new(pooled.len());
        let mut tasks = Vec::with_capacity(pooled.len());
        if !pooled.is_empty() {
            // The global pool spins up lazily, only when a pipeline
            // actually has pooled elements.
            let scheduler = match on {
                Some(s) => s,
                None => sched::global(),
            };
            for (node, ctx, inbox) in pooled {
                tasks.push(scheduler.spawn(NodeRun::new(node.element, ctx, inbox, group.clone())));
            }
        }
        log_info!(
            "pipeline",
            "started: {} elements ({} pooled, {} threaded), {} sinks",
            tasks.len() + handles.len(),
            tasks.len(),
            handles.len(),
            n_sinks
        );
        Ok(Running { bus_rx, stop, inboxes, handles, tasks, group, n_sinks, finished: false })
    }
}

fn spawn_node(mut node: Node, mut ctx: Ctx, inbox: Option<Arc<Inbox>>) -> Result<JoinHandle<()>> {
    let thread_name = format!("ep-{}", node.name);
    std::thread::Builder::new()
        .name(thread_name)
        .spawn(move || {
            if let Err(e) = node.element.start(&mut ctx) {
                ctx.post_error(format!("start: {e}"));
                ctx.push_eos_all();
                return;
            }
            let is_sink = ctx.n_src_pads_linked() == 0 && inbox.is_some();
            match inbox {
                None => {
                    // Source: produce until EOS/stop/error.
                    loop {
                        if ctx.stopped() {
                            break;
                        }
                        match node.element.produce(&mut ctx) {
                            Ok(true) => {}
                            Ok(false) => break,
                            Err(e) => {
                                ctx.post_error(format!("produce: {e}"));
                                break;
                            }
                        }
                    }
                }
                Some(ib) => {
                    let mut tracker = EosTracker::new(ib.n_pads());
                    loop {
                        match ib.pop_any() {
                            None => break,
                            Some((pad, item)) => {
                                let eos = matches!(item, Item::Eos);
                                match node.element.process(pad, item, &mut ctx) {
                                    Ok(Progress::Done) => break,
                                    Ok(_) => {}
                                    Err(e) => {
                                        ctx.post_error(format!("handle: {e}"));
                                        break;
                                    }
                                }
                                if eos && tracker.mark(pad) {
                                    break;
                                }
                            }
                        }
                    }
                }
            }
            ctx.push_eos_all();
            node.element.stop(&mut ctx);
            if is_sink || ctx.n_src_pads_linked() == 0 {
                ctx.post_eos();
            }
            log_debug!("pipeline", "element `{}` done", ctx.name);
        })
        .map_err(|e| Error::Pipeline(format!("spawn: {e}")))
}

/// Outcome of waiting on a running pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum WaitOutcome {
    /// All sink elements reached EOS.
    Eos,
    /// An element posted an error.
    Error { element: String, message: String },
    Timeout,
}

/// A live pipeline.
pub struct Running {
    bus_rx: Receiver<BusMsg>,
    stop: Arc<AtomicBool>,
    inboxes: Vec<Option<Arc<Inbox>>>,
    handles: Vec<JoinHandle<()>>,
    /// Pooled-element handles; kept alive until teardown so parked tasks
    /// (whose inbox wakers hold weak refs) stay reachable.
    tasks: Vec<Arc<Task>>,
    group: Arc<TaskGroup>,
    n_sinks: usize,
    finished: bool,
}

impl Running {
    /// Wait until all sinks EOS, an error posts, or the timeout expires.
    /// Info messages are discarded here; use [`Running::bus`] to observe.
    pub fn wait(&mut self, timeout: Duration) -> WaitOutcome {
        let deadline = Instant::now() + timeout;
        let mut eos_seen = 0usize;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return WaitOutcome::Timeout;
            }
            match self.bus_rx.recv_timeout(deadline - now) {
                Ok(BusMsg::Eos { .. }) => {
                    eos_seen += 1;
                    if eos_seen >= self.n_sinks {
                        self.finished = true;
                        return WaitOutcome::Eos;
                    }
                }
                Ok(BusMsg::Error { element, message }) => {
                    return WaitOutcome::Error { element, message };
                }
                Ok(BusMsg::Info { .. }) => {}
                Err(_) => return WaitOutcome::Timeout,
            }
        }
    }

    /// Ask live sources to wind down, then wait for drainage.
    pub fn stop(mut self, grace: Duration) -> WaitOutcome {
        self.stop.store(true, Ordering::Relaxed);
        let out = self.wait(grace);
        self.teardown();
        out
    }

    /// Run for a fixed duration then stop (bench/example helper).
    pub fn run_for(self, d: Duration) -> WaitOutcome {
        std::thread::sleep(d);
        self.stop(Duration::from_secs(10))
    }

    /// Wait for natural EOS (bounded sources), tearing down afterwards.
    pub fn wait_eos(mut self, timeout: Duration) -> WaitOutcome {
        let out = self.wait(timeout);
        self.stop.store(true, Ordering::Relaxed);
        self.teardown();
        out
    }

    pub fn bus(&self) -> &Receiver<BusMsg> {
        &self.bus_rx
    }

    fn teardown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for ib in self.inboxes.iter().flatten() {
            ib.close();
        }
        // Closing inboxes re-enqueues every parked task; each then runs
        // its shutdown path (drain -> EOS fan-out -> stop) on a worker.
        self.group.wait();
        self.tasks.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Running {
    fn drop(&mut self) {
        self.teardown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Buffer;
    use crate::element::QueueCfg;
    use std::sync::atomic::AtomicU64;

    /// Source producing `n` counted buffers.
    struct CountSrc {
        n: u64,
        sent: u64,
    }

    impl Element for CountSrc {
        fn n_sink_pads(&self) -> usize {
            0
        }
        fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
            unreachable!()
        }
        fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
            if self.sent >= self.n {
                return Ok(false);
            }
            ctx.push_buffer(Buffer::new(self.sent.to_le_bytes().to_vec()).with_pts(self.sent))?;
            self.sent += 1;
            Ok(true)
        }
    }

    /// Sink counting buffers into a shared atomic.
    struct CountSink {
        count: Arc<AtomicU64>,
    }

    impl Element for CountSink {
        fn n_src_pads(&self) -> usize {
            0
        }
        fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<()> {
            if item.is_buffer() {
                self.count.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        }
    }

    /// Identity filter.
    struct Pass;
    impl Element for Pass {
        fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
            if !matches!(item, Item::Eos) {
                ctx.push(0, item)?;
            }
            Ok(())
        }
    }

    fn counted_pipeline(n: u64) -> (Pipeline, Arc<AtomicU64>) {
        let mut p = Pipeline::new();
        let count = Arc::new(AtomicU64::new(0));
        let s = p.add("src", Box::new(CountSrc { n, sent: 0 })).unwrap();
        let f = p.add("pass", Box::new(Pass)).unwrap();
        let k = p.add("sink", Box::new(CountSink { count: count.clone() })).unwrap();
        p.link(s, f).unwrap();
        p.link(f, k).unwrap();
        (p, count)
    }

    #[test]
    fn linear_pipeline_delivers_all_buffers_then_eos() {
        let (p, count) = counted_pipeline(100);
        let running = p.start().unwrap();
        assert_eq!(running.wait_eos(Duration::from_secs(5)), WaitOutcome::Eos);
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn fanout_duplicates_stream() {
        let mut p = Pipeline::new();
        let c1 = Arc::new(AtomicU64::new(0));
        let c2 = Arc::new(AtomicU64::new(0));
        let s = p.add("src", Box::new(CountSrc { n: 50, sent: 0 })).unwrap();
        let k1 = p.add("sink1", Box::new(CountSink { count: c1.clone() })).unwrap();
        let k2 = p.add("sink2", Box::new(CountSink { count: c2.clone() })).unwrap();
        p.link(s, k1).unwrap();
        p.link(s, k2).unwrap();
        let running = p.start().unwrap();
        assert_eq!(running.wait_eos(Duration::from_secs(5)), WaitOutcome::Eos);
        assert_eq!(c1.load(Ordering::Relaxed), 50);
        assert_eq!(c2.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn unlinked_sink_pad_rejected() {
        let mut p = Pipeline::new();
        p.add("sink", Box::new(CountSink { count: Arc::new(AtomicU64::new(0)) })).unwrap();
        assert!(p.start().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut p = Pipeline::new();
        p.add("x", Box::new(Pass)).unwrap();
        assert!(p.add("x", Box::new(Pass)).is_err());
    }

    #[test]
    fn double_link_to_same_sink_pad_rejected() {
        let mut p = Pipeline::new();
        let a = p.add("a", Box::new(CountSrc { n: 1, sent: 0 })).unwrap();
        let b = p.add("b", Box::new(CountSrc { n: 1, sent: 0 })).unwrap();
        let k = p.add("k", Box::new(CountSink { count: Arc::new(AtomicU64::new(0)) })).unwrap();
        p.link(a, k).unwrap();
        assert!(p.link(b, k).is_err());
    }

    #[test]
    fn bad_pad_indices_rejected() {
        let mut p = Pipeline::new();
        let a = p.add("a", Box::new(CountSrc { n: 1, sent: 0 })).unwrap();
        let k = p.add("k", Box::new(CountSink { count: Arc::new(AtomicU64::new(0)) })).unwrap();
        assert!(p.link_pads(a, 3, k, 0).is_err());
        assert!(p.link_pads(a, 0, k, 5).is_err());
    }

    #[test]
    fn error_element_surfaces_on_bus() {
        struct Fail;
        impl Element for Fail {
            fn n_src_pads(&self) -> usize {
                0
            }
            fn handle(&mut self, _: usize, item: Item, _: &mut Ctx) -> Result<()> {
                if item.is_buffer() {
                    return Err(Error::Pipeline("boom".into()));
                }
                Ok(())
            }
        }
        let mut p = Pipeline::new();
        let s = p.add("src", Box::new(CountSrc { n: 10, sent: 0 })).unwrap();
        let k = p.add("fail", Box::new(Fail)).unwrap();
        p.link(s, k).unwrap();
        let mut running = p.start().unwrap();
        match running.wait(Duration::from_secs(5)) {
            WaitOutcome::Error { element, message } => {
                assert_eq!(element, "fail");
                assert!(message.contains("boom"));
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn stop_interrupts_live_source() {
        struct Forever;
        impl Element for Forever {
            fn n_sink_pads(&self) -> usize {
                0
            }
            fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
                unreachable!()
            }
            fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
                std::thread::sleep(Duration::from_millis(1));
                ctx.push_buffer(Buffer::new(vec![0]))?;
                Ok(true)
            }
        }
        let mut p = Pipeline::new();
        let count = Arc::new(AtomicU64::new(0));
        let s = p.add("src", Box::new(Forever)).unwrap();
        let k = p.add("sink", Box::new(CountSink { count: count.clone() })).unwrap();
        p.link(s, k).unwrap();
        let running = p.start().unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(running.stop(Duration::from_secs(5)), WaitOutcome::Eos);
        assert!(count.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn leaky_queue_cfg_respected() {
        struct LeakySink {
            count: Arc<AtomicU64>,
        }
        impl Element for LeakySink {
            fn n_src_pads(&self) -> usize {
                0
            }
            fn sink_queue_cfg(&self, _: usize) -> QueueCfg {
                QueueCfg { capacity: 1, leaky: crate::element::Leaky::Downstream }
            }
            fn handle(&mut self, _: usize, item: Item, _: &mut Ctx) -> Result<()> {
                if item.is_buffer() {
                    // Slow consumer.
                    std::thread::sleep(Duration::from_millis(5));
                    self.count.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }
        }
        let mut p = Pipeline::new();
        let count = Arc::new(AtomicU64::new(0));
        let s = p.add("src", Box::new(CountSrc { n: 500, sent: 0 })).unwrap();
        let k = p.add("sink", Box::new(LeakySink { count: count.clone() })).unwrap();
        p.link(s, k).unwrap();
        let running = p.start().unwrap();
        assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
        // Leak must have dropped most of the 500 (source is unthrottled).
        assert!(count.load(Ordering::Relaxed) < 500);
    }
}
