//! gst-launch-style pipeline description parser.
//!
//! Supports the syntax the paper's listings use:
//! - chains:           `videotestsrc ! tensor_converter ! appsink`
//! - properties:       `queue leaky=2`, `mqttsrc sub-topic=camleft`
//! - naming:           `tee name=ts`, later `ts. ! queue ! ...`
//! - named pads:       `dmux.src_0 ! ...`, `... ! mix.sink_1`
//! - pad properties:   `compositor name=mix sink_0::zorder=2`
//! - caps filters:     `... ! video/x-raw,width=300,height=300 ! ...`
//! - quoted values:    `dimensions="4:20:1:1,20:1:1:1"`
//!
//! Like the paper's listings (and unlike strict gst-launch), an element
//! directly following a `name.` source reference links implicitly.

use std::collections::BTreeMap;

use crate::element::registry::{PipelineEnv, Props, Registry};
use crate::pipeline::Pipeline;
use crate::util::{Error, Result};

/// Parse a description into a ready-to-start [`Pipeline`].
pub fn parse(desc: &str, registry: &Registry, env: &PipelineEnv) -> Result<Pipeline> {
    let tokens = tokenize(desc)?;
    build(&tokens, registry, env)
}

/// Count the "lines of pipeline code": non-empty `!`-separated segments.
/// Used by the §5.2 "within 100 LoC" reproduction (bench_loc).
pub fn segment_count(desc: &str) -> usize {
    tokenize(desc).map(|t| t.iter().filter(|x| x != &"!").count()).unwrap_or(0)
}

fn tokenize(desc: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    for c in desc.chars() {
        match c {
            '"' => {
                quoted = !quoted;
                cur.push(c);
            }
            c if c.is_whitespace() && !quoted => {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
            }
            '!' if !quoted => {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                out.push("!".to_string());
            }
            _ => cur.push(c),
        }
    }
    if quoted {
        return Err(Error::Parse("unterminated quote in pipeline description".into()));
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    Ok(out)
}

#[derive(Debug, PartialEq)]
enum Tok<'a> {
    Link,
    /// `name.` / `name.src_0` — chain source reference.
    SrcRef { name: &'a str, pad: Option<usize> },
    /// `name.sink_0` — chain destination reference.
    SinkRef { name: &'a str, pad: Option<usize> },
    /// `k=v` (includes pad props `sink_0::zorder=2`).
    Prop { key: &'a str, value: &'a str },
    /// `video/x-raw,width=...` etc.
    CapsFilter(&'a str),
    Element(&'a str),
}

fn classify(tok: &str) -> Tok<'_> {
    if tok == "!" {
        return Tok::Link;
    }
    // caps filter: contains '/' before any '=' or ','
    let eq = tok.find('=').unwrap_or(usize::MAX);
    let slash = tok.find('/').unwrap_or(usize::MAX);
    if slash < eq && slash != usize::MAX && tok.find(',').map_or(true, |c| slash < c) {
        return Tok::CapsFilter(tok);
    }
    if eq != usize::MAX {
        let (k, v) = tok.split_once('=').unwrap();
        return Tok::Prop { key: k, value: v };
    }
    // pad reference: name. | name.src_N | name.sink_N
    if let Some((name, pad)) = tok.split_once('.') {
        if !name.is_empty() {
            if pad.is_empty() {
                return Tok::SrcRef { name, pad: None };
            }
            if let Some(n) = pad.strip_prefix("src_").and_then(|s| s.parse().ok()) {
                return Tok::SrcRef { name, pad: Some(n) };
            }
            if pad == "src" {
                return Tok::SrcRef { name, pad: Some(0) };
            }
            if let Some(n) = pad.strip_prefix("sink_").and_then(|s| s.parse().ok()) {
                return Tok::SinkRef { name, pad: Some(n) };
            }
            if pad == "sink" {
                return Tok::SinkRef { name, pad: Some(0) };
            }
        }
    }
    Tok::Element(tok)
}

struct Builder<'r> {
    pipeline: Pipeline,
    registry: &'r Registry,
    env: &'r PipelineEnv,
    /// Next implicit sink pad to use per node (for `! mux.` style links).
    next_sink: BTreeMap<usize, usize>,
}

fn build(tokens: &[String], registry: &Registry, env: &PipelineEnv) -> Result<Pipeline> {
    let mut b = Builder { pipeline: Pipeline::new(), registry, env, next_sink: BTreeMap::new() };

    // Pass 1: create every element node so pad references may point
    // forward (Listing 2 links `mux.sink_0` before/after its definition).
    let mut node_for_token: Vec<Option<usize>> = vec![None; tokens.len()];
    {
        let mut i = 0;
        while i < tokens.len() {
            match classify(&tokens[i]) {
                Tok::CapsFilter(spec) => {
                    let mut props = Props::new();
                    props.insert("caps".into(), spec.trim_matches('"').to_string());
                    node_for_token[i] = Some(b.make_node("capsfilter", &props, "")?);
                    i += 1;
                }
                Tok::Element(kind) => {
                    let mut props = Props::new();
                    let mut node_name = String::new();
                    let mut j = i + 1;
                    while j < tokens.len() {
                        if let Tok::Prop { key, value } = classify(&tokens[j]) {
                            if key == "name" {
                                node_name = value.to_string();
                            } else {
                                props.insert(key.to_string(), value.trim_matches('"').to_string());
                            }
                            j += 1;
                        } else {
                            break;
                        }
                    }
                    node_for_token[i] = Some(b.make_node(kind, &props, &node_name)?);
                    i = j;
                }
                _ => i += 1,
            }
        }
    }

    // Pass 2: wire links. Current chain head: (node, src_pad).
    let mut current: Option<(usize, usize)> = None;
    let mut pending_link = false;

    let mut i = 0;
    while i < tokens.len() {
        match classify(&tokens[i]) {
            Tok::Link => {
                if current.is_none() {
                    return Err(Error::Parse("`!` with nothing to link from".into()));
                }
                pending_link = true;
                i += 1;
            }
            Tok::SrcRef { name, pad } => {
                let id = b
                    .pipeline
                    .by_name(name)
                    .ok_or_else(|| Error::Parse(format!("unknown element `{name}`")))?;
                let pad = pad.unwrap_or(0);
                b.ensure_src(id, pad)?;
                current = Some((id, pad));
                // Paper-style implicit link: `ts. videoconvert ! ...`
                pending_link = true;
                i += 1;
            }
            Tok::SinkRef { name, pad } => {
                if !pending_link {
                    return Err(Error::Parse(format!("`{name}.sink` without preceding `!`")));
                }
                let (from, from_pad) =
                    current.ok_or_else(|| Error::Parse("link without source".into()))?;
                let id = b
                    .pipeline
                    .by_name(name)
                    .ok_or_else(|| Error::Parse(format!("unknown element `{name}`")))?;
                let pad = match pad {
                    Some(p) => p,
                    None => b.alloc_sink(id),
                };
                b.ensure_sink(id, pad)?;
                b.pipeline.link_pads(from, from_pad, id, pad)?;
                current = None;
                pending_link = false;
                i += 1;
            }
            Tok::CapsFilter(_) => {
                let id = node_for_token[i].expect("pass-1 node");
                if pending_link {
                    let (from, from_pad) =
                        current.ok_or_else(|| Error::Parse("link without source".into()))?;
                    b.pipeline.link_pads(from, from_pad, id, 0)?;
                }
                current = Some((id, 0));
                pending_link = false;
                i += 1;
            }
            Tok::Prop { .. } => {
                return Err(Error::Parse(format!(
                    "stray property `{}` (no preceding element)",
                    tokens[i]
                )));
            }
            Tok::Element(_) => {
                // Properties were consumed in pass 1; skip them here.
                let mut j = i + 1;
                while j < tokens.len() && matches!(classify(&tokens[j]), Tok::Prop { .. }) {
                    j += 1;
                }
                let id = node_for_token[i].expect("pass-1 node");
                if pending_link {
                    let (from, from_pad) =
                        current.ok_or_else(|| Error::Parse("link without source".into()))?;
                    let pad = b.alloc_sink(id);
                    b.ensure_sink(id, pad)?;
                    b.pipeline.link_pads(from, from_pad, id, pad)?;
                }
                current = Some((id, 0));
                pending_link = false;
                i = j;
            }
        }
    }
    if pending_link {
        return Err(Error::Parse("dangling `!` at end of description".into()));
    }
    Ok(b.pipeline)
}

impl Builder<'_> {
    fn make_node(&mut self, kind: &str, props: &Props, name: &str) -> Result<usize> {
        let el = self.registry.make(kind, props, self.env)?;
        let auto = format!("{kind}{}", self.pipeline.n_nodes());
        let name = if name.is_empty() { auto } else { name.to_string() };
        self.pipeline.add(&name, el)
    }

    fn alloc_sink(&mut self, id: usize) -> usize {
        let next = self.next_sink.entry(id).or_insert(0);
        let pad = *next;
        *next += 1;
        pad
    }

    fn ensure_sink(&mut self, id: usize, pad: usize) -> Result<()> {
        let el = self.pipeline.element_mut(id);
        if pad < el.n_sink_pads() {
            return Ok(());
        }
        if el.ensure_sink_pads(pad + 1) {
            Ok(())
        } else {
            Err(Error::Parse(format!("element cannot grow to sink pad {pad}")))
        }
    }

    fn ensure_src(&mut self, id: usize, pad: usize) -> Result<()> {
        let el = self.pipeline.element_mut(id);
        if pad < el.n_src_pads() {
            return Ok(());
        }
        if el.ensure_src_pads(pad + 1) {
            Ok(())
        } else {
            Err(Error::Parse(format!("element cannot grow to src pad {pad}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_respects_quotes_and_bangs() {
        let t = tokenize(r#"a ! b opt="x,y z" ! c"#).unwrap();
        assert_eq!(t, vec!["a", "!", "b", r#"opt="x,y z""#, "!", "c"]);
    }

    #[test]
    fn tokenize_bang_without_spaces() {
        let t = tokenize("a!b").unwrap();
        assert_eq!(t, vec!["a", "!", "b"]);
    }

    #[test]
    fn tokenize_unterminated_quote_errors() {
        assert!(tokenize(r#"a opt="x"#).is_err());
    }

    #[test]
    fn classify_tokens() {
        assert_eq!(classify("!"), Tok::Link);
        assert!(matches!(classify("videotestsrc"), Tok::Element("videotestsrc")));
        assert!(matches!(classify("leaky=2"), Tok::Prop { key: "leaky", value: "2" }));
        assert!(matches!(classify("video/x-raw,width=3"), Tok::CapsFilter(_)));
        assert!(matches!(classify("other/flexbuf"), Tok::CapsFilter(_)));
        assert!(matches!(classify("ts."), Tok::SrcRef { name: "ts", pad: None }));
        assert!(matches!(classify("d.src_2"), Tok::SrcRef { name: "d", pad: Some(2) }));
        assert!(matches!(classify("mix.sink_1"), Tok::SinkRef { name: "mix", pad: Some(1) }));
        // property whose value contains '/': not caps
        assert!(matches!(classify("model=/path/m.tflite"), Tok::Prop { .. }));
        // pad property
        assert!(matches!(classify("sink_0::zorder=2"), Tok::Prop { .. }));
    }

    #[test]
    fn segment_count_counts_elements() {
        assert_eq!(segment_count("a ! b ! c"), 3);
        assert_eq!(segment_count("a prop=1 ! b"), 3); // props count as written tokens
    }

    // Full build tests live in rust/tests/ (they need the element registry).
}
