//! Batch-first inference backend API.
//!
//! [`InferenceBackend`] is the seam between pipeline elements and model
//! execution. `tensor_filter` used to hard-code a private three-arm
//! backend enum; now it drives any `InferenceBackend` — one frame at a
//! time through [`InferenceBackend::infer_one`], or many frames per call
//! through [`InferenceBackend::infer_batch`] when the cross-pipeline
//! [`BatchCollector`](super::batch::BatchCollector) coalesces load from
//! several pipelines sharing one model.
//!
//! The trait is deliberately batch-first: `infer_batch` is the one
//! required inference method, and `infer_one` is a blanket wrapper over
//! it, so a backend written for the single-frame path is automatically
//! correct under batching (it just never sees a batch larger than 1
//! until a collector feeds it one).

use std::sync::Arc;

use crate::buffer::{Buffer, Bytes};
use crate::caps::Caps;
use crate::tensor::Format;
use crate::util::{Error, Result};

use super::Model;

/// Custom per-frame inference closure (the paper's custom-filter
/// sub-plugin mechanism; also the test seam). Kept source-compatible
/// with the pre-trait `TensorFilter::custom` constructor.
pub type CustomFn = Box<dyn FnMut(&Buffer) -> Result<Vec<u8>> + Send>;

/// A model-execution backend a `tensor_filter` (or a shared
/// [`BatchCollector`](super::batch::BatchCollector)) drives.
///
/// Implementations must be `Send`: a backend lives inside one element or
/// one collector and is driven from whichever worker holds it, never
/// from two threads at once.
pub trait InferenceBackend: Send {
    /// Stable label for metrics keys and error messages (model name for
    /// PJRT backends).
    fn label(&self) -> &str;

    /// Caps negotiation hook: validate the upstream caps and return the
    /// caps this backend's output stream carries. Errors are returned
    /// plain; the element wraps them with its name.
    fn negotiate(&mut self, incoming: &Caps) -> Result<Caps>;

    /// Run inference on a batch of frame payloads. Must return exactly
    /// one output payload per input, in input order — the collector
    /// demuxes results positionally back to the originating pipelines.
    fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>>;

    /// Single-frame convenience: every unbatched caller funnels through
    /// here, so per-frame backends only implement `infer_batch`.
    fn infer_one(&mut self, input: &Bytes) -> Result<Vec<u8>> {
        let mut out = self.infer_batch(std::slice::from_ref(input))?;
        if out.len() != 1 {
            return Err(Error::Runtime(format!(
                "backend `{}` returned {} outputs for 1 input",
                self.label(),
                out.len()
            )));
        }
        Ok(out.pop().expect("length checked above"))
    }

    /// Direct (unbatched) per-buffer path: runs [`Self::infer_one`] on
    /// the payload and rewraps timestamps/meta. Passthrough overrides it
    /// to forward the Arc-shared payload without copying; Custom
    /// overrides it so closures observe the real [`Buffer`] (pts/meta),
    /// exactly as before the redesign.
    fn infer_buffer(&mut self, b: &Buffer) -> Result<Buffer> {
        Ok(b.map_payload(self.infer_one(&b.data)?))
    }
}

/// PJRT-compiled AOT model execution (the production path).
pub struct PjrtBackend {
    model: Arc<Model>,
}

impl PjrtBackend {
    pub fn new(model: Arc<Model>) -> Self {
        Self { model }
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }
}

impl InferenceBackend for PjrtBackend {
    fn label(&self) -> &str {
        &self.model.manifest.name
    }

    fn negotiate(&mut self, incoming: &Caps) -> Result<Caps> {
        if !incoming.is_tensors() {
            return Err(Error::Caps(format!(
                "tensor_filter needs tensors caps, got `{incoming}`"
            )));
        }
        if incoming.tensor_format()? != Format::Static {
            return Err(Error::Caps("needs static tensors".into()));
        }
        let want = self.model.input_info()?;
        if let Ok(got) = incoming.tensors_info() {
            if got != want {
                return Err(Error::Caps(format!(
                    "model `{}` expects {} got {}",
                    self.model.manifest.name,
                    want.dimensions_string(),
                    got.dimensions_string()
                )));
            }
        }
        Ok(Caps::tensors(&self.model.output_info()?))
    }

    fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
        self.model.infer_bytes_batch(inputs)
    }
}

/// Transport-isolation stand-in (the Fig 7 query benches): output caps
/// and payloads are the input, untouched and uncopied.
pub struct PassthroughBackend;

impl InferenceBackend for PassthroughBackend {
    fn label(&self) -> &str {
        "passthrough"
    }

    fn negotiate(&mut self, incoming: &Caps) -> Result<Caps> {
        Ok(incoming.clone())
    }

    fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
        Ok(inputs.iter().map(|b| b.to_vec()).collect())
    }

    fn infer_buffer(&mut self, b: &Buffer) -> Result<Buffer> {
        Ok(b.clone()) // payload is Arc-shared: no copy on the direct path
    }
}

/// Closure-backed backend wrapping a [`CustomFn`].
///
/// On the direct path the closure sees the full `Buffer` (pts, meta) —
/// bit-for-bit the pre-trait behaviour. On the batched path the
/// collector only carries payloads, so each frame reaches the closure as
/// a payload-only `Buffer`.
pub struct CustomBackend {
    f: CustomFn,
}

impl CustomBackend {
    pub fn new(f: CustomFn) -> Self {
        Self { f }
    }
}

impl InferenceBackend for CustomBackend {
    fn label(&self) -> &str {
        "custom"
    }

    fn negotiate(&mut self, incoming: &Caps) -> Result<Caps> {
        Ok(incoming.clone())
    }

    fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::with_capacity(inputs.len());
        for payload in inputs {
            out.push((self.f)(&Buffer::from_bytes(payload.clone()))?);
        }
        Ok(out)
    }

    fn infer_buffer(&mut self, b: &Buffer) -> Result<Buffer> {
        Ok(b.map_payload((self.f)(b)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_one_funnels_through_infer_batch() {
        struct Doubler;
        impl InferenceBackend for Doubler {
            fn label(&self) -> &str {
                "doubler"
            }
            fn negotiate(&mut self, c: &Caps) -> Result<Caps> {
                Ok(c.clone())
            }
            fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
                Ok(inputs.iter().map(|b| b.iter().map(|&x| x * 2).collect()).collect())
            }
        }
        let mut d = Doubler;
        let out = d.infer_one(&Bytes::from(vec![1u8, 2, 3])).unwrap();
        assert_eq!(out, vec![2, 4, 6]);
    }

    #[test]
    fn bad_backend_output_count_is_an_error() {
        struct Silent;
        impl InferenceBackend for Silent {
            fn label(&self) -> &str {
                "silent"
            }
            fn negotiate(&mut self, c: &Caps) -> Result<Caps> {
                Ok(c.clone())
            }
            fn infer_batch(&mut self, _inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
                Ok(Vec::new())
            }
        }
        assert!(Silent.infer_one(&Bytes::from(vec![1u8])).is_err());
    }

    #[test]
    fn passthrough_forwards_buffer_without_copy() {
        let b = Buffer::new(vec![9u8, 8, 7]);
        let out = PassthroughBackend.infer_buffer(&b).unwrap();
        assert_eq!(&out.data[..], &[9, 8, 7]);
    }

    #[test]
    fn custom_sees_full_buffer_on_direct_path() {
        let mut c = CustomBackend::new(Box::new(|b: &Buffer| Ok(vec![b.data.len() as u8])));
        let out = c.infer_buffer(&Buffer::new(vec![0u8; 5])).unwrap();
        assert_eq!(&out.data[..], &[5]);
        let batched = c.infer_batch(&[Bytes::from(vec![0u8; 3]), Bytes::from(vec![0u8; 4])]).unwrap();
        assert_eq!(batched, vec![vec![3], vec![4]]);
    }
}
