//! Cross-pipeline adaptive inference batching.
//!
//! When M pipelines run the same model, per-frame inference pays M
//! single-frame dispatches where one batched call would do. A
//! [`BatchCollector`] sits between `tensor_filter` instances and a
//! shared [`InferenceBackend`]: each filter submits its ready frame and
//! parks; the collector dispatches one `infer_batch` call when B frames
//! are waiting or the oldest waiting frame is T ms old (`batch=` /
//! `batch-timeout-ms=`, whichever first), then demuxes the outputs back
//! to the submitting filters positionally — exact, in submission order.
//!
//! ## Adaptive target
//!
//! Each member (filter instance) has at most one frame in flight, so
//! once every registered member has a frame waiting no further frame can
//! arrive until results go back. The collector therefore dispatches at
//! `min(B, members)`: an M=1 pipeline dispatches every frame immediately
//! (no added latency when there is nothing to coalesce), M=64 pipelines
//! fill real batches, and the T ms budget only pays when some member is
//! slow, idle, or mid-shutdown.
//!
//! ## Scheduling
//!
//! Dispatch runs inline on the pooled task whose submit completed the
//! batch (a worker was going to run that inference anyway); waiting
//! filters park via the same waker protocol the inbox uses
//! ([`crate::element::inbox::Waker`]), so a slow batch never wedges a
//! worker. A process-wide `ep-batch-timer` daemon fires member wakers
//! when a latency budget expires and the woken member drives the flush
//! from its own pooled task ([`BatchCollector::poll_due`]); if every
//! member is parked on downstream backpressure and nobody can run, the
//! timer flushes the overdue batch itself — results then wait in their
//! slots. Thread-mode filters skip wakers and block on [`Slot::wait`],
//! which drives due-flushes on its own deadline.
//!
//! Per-model metrics: `batch.<model>.size` / `batch.<model>.occupancy`
//! histograms and `batch.<model>.flushes_full` /
//! `batch.<model>.flushes_timer` counters.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use crate::buffer::Bytes;
use crate::caps::Caps;
use crate::element::inbox::Waker;
use crate::log_warn;
use crate::metrics::{self, Counter};
use crate::util::{Error, Result};

use super::backend::InferenceBackend;

/// Batching policy of one collector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchCfg {
    /// Dispatch when this many frames are waiting (upper bound on the
    /// batch size; the `batch=` element property).
    pub max_batch: usize,
    /// Latency budget: dispatch a partial batch once the oldest waiting
    /// frame is this old (the `batch-timeout-ms=` element property).
    pub timeout: Duration,
}

impl Default for BatchCfg {
    fn default() -> Self {
        Self { max_batch: 8, timeout: Duration::from_millis(5) }
    }
}

/// Completion cell for one submitted frame: the collector writes exactly
/// one result; the submitting filter takes it (pooled path) or blocks on
/// it (thread path).
pub struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

struct SlotState {
    result: Option<Result<Vec<u8>>>,
    waker: Option<Waker>,
}

impl Slot {
    fn new(waker: Option<Waker>) -> Arc<Slot> {
        Arc::new(Slot { state: Mutex::new(SlotState { result: None, waker }), cv: Condvar::new() })
    }

    /// Take the result if the batch already ran (non-blocking).
    pub fn take(&self) -> Option<Result<Vec<u8>>> {
        self.state.lock().unwrap().result.take()
    }

    /// Clone the registered waker without consuming it (timer re-fires).
    fn peek_waker(&self) -> Option<Waker> {
        self.state.lock().unwrap().waker.clone()
    }

    /// Deliver the result; returns the waker to fire (outside the lock).
    fn complete(&self, r: Result<Vec<u8>>) -> Option<Waker> {
        let mut s = self.state.lock().unwrap();
        s.result = Some(r);
        let w = s.waker.take();
        self.cv.notify_all();
        w
    }

    /// Block until the result arrives (thread-mode filters own their
    /// thread). Drives [`BatchCollector::poll_due`] every millisecond so
    /// a lone thread-mode pipeline never depends on the timer daemon for
    /// progress.
    pub fn wait(&self, collector: &BatchCollector) -> Result<Vec<u8>> {
        loop {
            {
                let s = self.state.lock().unwrap();
                let (mut s, _timed_out) =
                    self.cv.wait_timeout(s, Duration::from_millis(1)).unwrap();
                if let Some(r) = s.result.take() {
                    return r;
                }
            }
            collector.poll_due();
        }
    }
}

struct PendingFrame {
    payload: Bytes,
    slot: Arc<Slot>,
    since: Instant,
}

struct State {
    pending: VecDeque<PendingFrame>,
    /// Registered filter instances (each holds ≤ 1 frame in flight).
    members: usize,
    /// A batch is currently executing; leftover/new frames wait for the
    /// dispatcher's post-run re-check rather than starting a second call.
    dispatching: bool,
}

/// Per-model frame coalescer (see module docs).
pub struct BatchCollector {
    label: String,
    cfg: BatchCfg,
    backend: Mutex<Box<dyn InferenceBackend>>,
    state: Mutex<State>,
    flushes_full: Arc<Counter>,
    flushes_timer: Arc<Counter>,
    size_key: String,
    occupancy_key: String,
}

impl BatchCollector {
    /// Build a collector around a shared backend. `max_batch` is clamped
    /// to ≥ 1 and `timeout` to ≥ 1 ms (the parser rejects zeros with a
    /// targeted error; this guards programmatic construction).
    pub fn new(label: &str, backend: Box<dyn InferenceBackend>, cfg: BatchCfg) -> Arc<Self> {
        let cfg = BatchCfg {
            max_batch: cfg.max_batch.max(1),
            timeout: cfg.timeout.max(Duration::from_millis(1)),
        };
        let g = metrics::global();
        let c = Arc::new(BatchCollector {
            label: label.to_string(),
            cfg,
            backend: Mutex::new(backend),
            state: Mutex::new(State { pending: VecDeque::new(), members: 0, dispatching: false }),
            flushes_full: g.counter(&format!("batch.{label}.flushes_full")),
            flushes_timer: g.counter(&format!("batch.{label}.flushes_timer")),
            size_key: format!("batch.{label}.size"),
            occupancy_key: format!("batch.{label}.occupancy"),
        });
        timer().register(&c);
        c
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn cfg(&self) -> BatchCfg {
        self.cfg
    }

    /// A filter instance joins (element `start`). Membership feeds the
    /// adaptive dispatch target `min(max_batch, members)`.
    pub fn register_member(&self) {
        self.state.lock().unwrap().members += 1;
    }

    /// A filter instance leaves (element `stop`). Leaving can complete a
    /// waiting batch — the adaptive target just shrank — so re-check.
    pub fn deregister_member(&self) {
        {
            let mut st = self.state.lock().unwrap();
            st.members = st.members.saturating_sub(1);
        }
        self.try_dispatch();
    }

    /// Caps negotiation through the shared backend.
    pub fn negotiate(&self, incoming: &Caps) -> Result<Caps> {
        self.backend.lock().unwrap_or_else(|p| p.into_inner()).negotiate(incoming)
    }

    /// Hand one ready frame to the collector. Returns the frame's
    /// completion slot; when the submit itself completed a batch the
    /// dispatch ran inline on this thread and the slot is already ready.
    /// `waker` (the submitter's pooled-task waker) fires on completion
    /// and on timer flushes; thread-mode callers pass `None` and block
    /// on [`Slot::wait`].
    pub fn submit(&self, payload: Bytes, waker: Option<Waker>) -> Arc<Slot> {
        let slot = Slot::new(waker);
        {
            let mut st = self.state.lock().unwrap();
            st.pending.push_back(PendingFrame {
                payload,
                slot: slot.clone(),
                since: Instant::now(),
            });
        }
        self.try_dispatch();
        slot
    }

    /// Flush hook: dispatch if the target is met or the budget expired.
    /// Called by woken members ([`crate::element::Element::pump`]), by
    /// blocked [`Slot::wait`]ers, and by the timer's backstop.
    pub fn poll_due(&self) {
        self.try_dispatch();
    }

    /// Core dispatch loop: drain-and-run while a batch is ready (target
    /// met or budget expired). The state lock is never held across
    /// `infer_batch`; `dispatching` keeps concurrent callers from
    /// starting a second call on the same backend.
    fn try_dispatch(&self) {
        loop {
            let (batch, full) = {
                let mut st = self.state.lock().unwrap();
                if st.dispatching || st.pending.is_empty() {
                    return;
                }
                let target = self.cfg.max_batch.min(st.members.max(1));
                let due = st
                    .pending
                    .front()
                    .is_some_and(|f| f.since.elapsed() >= self.cfg.timeout);
                if st.pending.len() < target && !due {
                    drop(st);
                    // Not ready: make sure the timer knows a budget is
                    // running (cheap notify; the timer recomputes the
                    // nearest deadline across all collectors).
                    timer().kick();
                    return;
                }
                let full = st.pending.len() >= target;
                let n = st.pending.len().min(self.cfg.max_batch);
                st.dispatching = true;
                (st.pending.drain(..n).collect::<Vec<_>>(), full)
            };
            self.run_batch(batch, full);
            self.state.lock().unwrap().dispatching = false;
            // Another batch may have formed while this one ran.
        }
    }

    /// Execute one batch and demux results positionally back to the
    /// submitters' slots (exact: `infer_batch` guarantees one output per
    /// input, in order). Wakers fire after every slot of the batch is
    /// complete.
    fn run_batch(&self, batch: Vec<PendingFrame>, full: bool) {
        let n = batch.len();
        if full {
            self.flushes_full.inc();
        } else {
            self.flushes_timer.inc();
        }
        let g = metrics::global();
        g.observe(&self.size_key, n as f64);
        g.observe(&self.occupancy_key, n as f64 / self.cfg.max_batch as f64);
        let payloads: Vec<Bytes> = batch.iter().map(|f| f.payload.clone()).collect();
        // A panicking backend must not leave `dispatching` wedged: the
        // panic becomes a per-frame error each member surfaces itself.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.backend.lock().unwrap_or_else(|p| p.into_inner()).infer_batch(&payloads)
        }))
        .unwrap_or_else(|_| Err(Error::Runtime(format!("backend `{}` panicked", self.label))));
        let mut wakers: Vec<Waker> = Vec::with_capacity(n);
        match result {
            Ok(outs) if outs.len() == n => {
                for (f, out) in batch.iter().zip(outs) {
                    if let Some(w) = f.slot.complete(Ok(out)) {
                        wakers.push(w);
                    }
                }
            }
            Ok(outs) => {
                let msg = format!(
                    "backend `{}` returned {} outputs for a batch of {n}",
                    self.label,
                    outs.len()
                );
                for f in &batch {
                    if let Some(w) = f.slot.complete(Err(Error::Runtime(msg.clone()))) {
                        wakers.push(w);
                    }
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for f in &batch {
                    if let Some(w) = f.slot.complete(Err(Error::Runtime(msg.clone()))) {
                        wakers.push(w);
                    }
                }
            }
        }
        for w in wakers {
            w();
        }
    }

    /// Timer pass: fire waiting members' wakers once the budget expires
    /// (the woken filter flushes from its own pooled task); if the batch
    /// is still undispatched at 2x the budget — every member parked on
    /// downstream backpressure, say — flush it here. Returns when this
    /// collector next needs attention.
    fn timer_tick(&self, now: Instant) -> Option<Instant> {
        let mut wakers: Vec<Waker> = Vec::new();
        let mut flush_here = false;
        let next = {
            let st = self.state.lock().unwrap();
            match st.pending.front() {
                None => None,
                // A dispatch is running; its post-run re-check (or the
                // next submit's kick) re-arms us.
                Some(_) if st.dispatching => None,
                Some(f) => {
                    let deadline = f.since + self.cfg.timeout;
                    if now < deadline {
                        Some(deadline)
                    } else if now < deadline + self.cfg.timeout {
                        for p in st.pending.iter() {
                            if let Some(w) = p.slot.peek_waker() {
                                wakers.push(w);
                            }
                        }
                        Some(deadline + self.cfg.timeout)
                    } else {
                        flush_here = true;
                        Some(now + self.cfg.timeout)
                    }
                }
            }
        };
        for w in wakers {
            w();
        }
        if flush_here {
            self.poll_due();
        }
        next
    }
}

/// The process-wide batch timer: one daemon thread watching every live
/// collector's oldest-frame deadline (collectors register weakly; dead
/// ones are swept each pass).
struct Timer {
    collectors: Mutex<Vec<Weak<BatchCollector>>>,
    cv: Condvar,
}

impl Timer {
    fn register(&self, c: &Arc<BatchCollector>) {
        self.collectors.lock().unwrap().push(Arc::downgrade(c));
        self.cv.notify_one();
    }

    /// Wake the timer loop early so it recomputes the nearest deadline
    /// (called whenever frames are left waiting on a budget).
    fn kick(&self) {
        self.cv.notify_one();
    }

    fn run(&'static self) {
        loop {
            let live: Vec<Arc<BatchCollector>> = {
                let mut cs = self.collectors.lock().unwrap();
                cs.retain(|w| w.strong_count() > 0);
                cs.iter().filter_map(Weak::upgrade).collect()
            };
            let now = Instant::now();
            let mut next: Option<Instant> = None;
            for c in &live {
                if let Some(d) = c.timer_tick(now) {
                    next = Some(next.map_or(d, |n| n.min(d)));
                }
            }
            let guard = self.collectors.lock().unwrap();
            let sleep = match next {
                Some(d) => d.saturating_duration_since(Instant::now()).max(Duration::from_micros(200)),
                // Idle: nothing pending anywhere; kicks/registrations
                // wake us early, the cap just bounds staleness.
                None => Duration::from_millis(50),
            };
            let _ = self.cv.wait_timeout(guard, sleep).unwrap();
        }
    }
}

fn timer() -> &'static Timer {
    static T: OnceLock<&'static Timer> = OnceLock::new();
    T.get_or_init(|| {
        let t: &'static Timer =
            Box::leak(Box::new(Timer { collectors: Mutex::new(Vec::new()), cv: Condvar::new() }));
        std::thread::Builder::new()
            .name("ep-batch-timer".into())
            .spawn(move || t.run())
            .expect("spawn batch timer");
        t
    })
}

/// Log-once helper for collectors joined with a mismatched config (the
/// first pipeline's policy wins; one model, one batching policy).
pub(super) fn warn_cfg_mismatch(label: &str, have: BatchCfg, want: BatchCfg) {
    log_warn!(
        "runtime",
        "batch collector `{label}`: ignoring cfg {want:?}; joined existing collector with {have:?}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct Echo {
        sizes: Arc<Mutex<Vec<usize>>>,
    }

    impl InferenceBackend for Echo {
        fn label(&self) -> &str {
            "echo"
        }
        fn negotiate(&mut self, c: &Caps) -> Result<Caps> {
            Ok(c.clone())
        }
        fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
            self.sizes.lock().unwrap().push(inputs.len());
            Ok(inputs.iter().map(|b| b.to_vec()).collect())
        }
    }

    fn echo_collector(label: &str, cfg: BatchCfg) -> (Arc<BatchCollector>, Arc<Mutex<Vec<usize>>>) {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let c = BatchCollector::new(label, Box::new(Echo { sizes: sizes.clone() }), cfg);
        (c, sizes)
    }

    #[test]
    fn full_flush_dispatches_inline_at_target() {
        let cfg = BatchCfg { max_batch: 3, timeout: Duration::from_secs(10) };
        let (c, sizes) = echo_collector("t_full", cfg);
        for _ in 0..3 {
            c.register_member();
        }
        let s1 = c.submit(Bytes::from(vec![1u8]), None);
        let s2 = c.submit(Bytes::from(vec![2u8]), None);
        assert!(s1.take().is_none(), "no dispatch below the target");
        let s3 = c.submit(Bytes::from(vec![3u8]), None);
        // The third submit met the target and dispatched inline.
        assert_eq!(s1.take().unwrap().unwrap(), vec![1]);
        assert_eq!(s2.take().unwrap().unwrap(), vec![2]);
        assert_eq!(s3.take().unwrap().unwrap(), vec![3]);
        assert_eq!(*sizes.lock().unwrap(), vec![3]);
        assert_eq!(c.flushes_full.count(), 1);
        assert_eq!(c.flushes_timer.count(), 0);
    }

    #[test]
    fn adaptive_target_dispatches_single_member_immediately() {
        let cfg = BatchCfg { max_batch: 64, timeout: Duration::from_secs(10) };
        let (c, sizes) = echo_collector("t_single", cfg);
        c.register_member();
        let s = c.submit(Bytes::from(vec![7u8]), None);
        // One member -> target 1 -> inline dispatch; the huge budget
        // never comes into play.
        assert_eq!(s.take().unwrap().unwrap(), vec![7]);
        assert_eq!(*sizes.lock().unwrap(), vec![1]);
    }

    #[test]
    fn timer_flush_covers_partial_batches() {
        let cfg = BatchCfg { max_batch: 4, timeout: Duration::from_millis(10) };
        let (c, _sizes) = echo_collector("t_timer", cfg);
        for _ in 0..4 {
            c.register_member();
        }
        let s = c.submit(Bytes::from(vec![9u8]), None);
        // Blocking wait drives poll_due on the budget itself, so this
        // terminates even without the timer daemon.
        let out = s.wait(&c).unwrap();
        assert_eq!(out, vec![9]);
        assert_eq!(c.flushes_timer.count(), 1);
        assert_eq!(c.flushes_full.count(), 0);
    }

    #[test]
    fn timer_daemon_flushes_wakerless_overdue_batch() {
        let cfg = BatchCfg { max_batch: 8, timeout: Duration::from_millis(5) };
        let (c, _sizes) = echo_collector("t_daemon", cfg);
        c.register_member();
        c.register_member();
        let s = c.submit(Bytes::from(vec![4u8]), None);
        // Nobody waits, nobody polls: only the ep-batch-timer backstop
        // (overdue at 2x budget) can flush this.
        let t0 = Instant::now();
        let out = loop {
            if let Some(r) = s.take() {
                break r;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "timer backstop never flushed");
            std::thread::sleep(Duration::from_millis(1));
        };
        assert_eq!(out.unwrap(), vec![4]);
    }

    #[test]
    fn member_departure_completes_waiting_batch() {
        let cfg = BatchCfg { max_batch: 8, timeout: Duration::from_secs(10) };
        let (c, _sizes) = echo_collector("t_leave", cfg);
        c.register_member();
        c.register_member();
        let s = c.submit(Bytes::from(vec![5u8]), None);
        assert!(s.take().is_none(), "target is 2; one frame waits");
        c.deregister_member(); // target shrinks to 1 -> dispatch
        assert_eq!(s.take().unwrap().unwrap(), vec![5]);
    }

    #[test]
    fn waker_fires_on_completion() {
        let cfg = BatchCfg { max_batch: 2, timeout: Duration::from_secs(10) };
        let (c, _sizes) = echo_collector("t_waker", cfg);
        c.register_member();
        c.register_member();
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = fired.clone();
        let w: Waker = Arc::new(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        });
        let s1 = c.submit(Bytes::from(vec![1u8]), Some(w));
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        let _s2 = c.submit(Bytes::from(vec![2u8]), None);
        assert!(s1.take().is_some());
        assert!(fired.load(Ordering::SeqCst) >= 1, "completion must fire the parked waker");
    }

    #[test]
    fn backend_error_reaches_every_slot() {
        struct Broken;
        impl InferenceBackend for Broken {
            fn label(&self) -> &str {
                "broken"
            }
            fn negotiate(&mut self, c: &Caps) -> Result<Caps> {
                Ok(c.clone())
            }
            fn infer_batch(&mut self, _inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
                Err(Error::Runtime("boom".into()))
            }
        }
        let cfg = BatchCfg { max_batch: 2, timeout: Duration::from_secs(10) };
        let c = BatchCollector::new("t_err", Box::new(Broken), cfg);
        c.register_member();
        c.register_member();
        let s1 = c.submit(Bytes::from(vec![1u8]), None);
        let s2 = c.submit(Bytes::from(vec![2u8]), None);
        assert!(s1.take().unwrap().is_err());
        assert!(s2.take().unwrap().is_err());
        // The collector survives: a later batch still dispatches.
        let s3 = c.submit(Bytes::from(vec![3u8]), None);
        let s4 = c.submit(Bytes::from(vec![4u8]), None);
        assert!(s3.take().unwrap().is_err());
        assert!(s4.take().unwrap().is_err());
    }

    #[test]
    fn zero_cfg_values_are_clamped() {
        let c = BatchCollector::new(
            "t_clamp",
            Box::new(Echo { sizes: Arc::new(Mutex::new(Vec::new())) }),
            BatchCfg { max_batch: 0, timeout: Duration::ZERO },
        );
        assert_eq!(c.cfg().max_batch, 1);
        assert!(c.cfg().timeout >= Duration::from_millis(1));
    }
}
