//! Parser for the line-oriented model manifest emitted by
//! `python/compile/aot.py` (see that file for the format).

use std::path::Path;

use crate::util::{Error, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// Row-major dims as lowered (e.g. [1, 300, 300, 3]).
    pub dims: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelManifest {
    pub name: String,
    pub input: TensorSpec,
    pub outputs: Vec<TensorSpec>,
    pub params: Vec<ParamSpec>,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|d| d.trim().parse::<usize>().map_err(|_| Error::Runtime(format!("bad dim `{d}`"))))
        .collect()
}

impl ModelManifest {
    pub fn load(path: &Path) -> Result<ModelManifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ModelManifest> {
        let mut name = None;
        let mut input = None;
        let mut outputs = Vec::new();
        let mut params = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields.as_slice() {
                ["model", n] => name = Some(n.to_string()),
                ["input", n, "f32", dims] => {
                    input = Some(TensorSpec { name: n.to_string(), dims: parse_dims(dims)? });
                }
                ["output", n, "f32", dims] => {
                    outputs.push(TensorSpec { name: n.to_string(), dims: parse_dims(dims)? });
                }
                ["param", n, "f32", dims, off, len] => {
                    params.push(ParamSpec {
                        name: n.to_string(),
                        dims: parse_dims(dims)?,
                        offset: off
                            .parse()
                            .map_err(|_| Error::Runtime(format!("line {}: bad offset", ln + 1)))?,
                        nbytes: len
                            .parse()
                            .map_err(|_| Error::Runtime(format!("line {}: bad nbytes", ln + 1)))?,
                    });
                }
                _ => {
                    return Err(Error::Runtime(format!(
                        "manifest line {}: unrecognized `{line}`",
                        ln + 1
                    )))
                }
            }
        }
        let manifest = ModelManifest {
            name: name.ok_or_else(|| Error::Runtime("manifest missing `model`".into()))?,
            input: input.ok_or_else(|| Error::Runtime("manifest missing `input`".into()))?,
            outputs,
            params,
        };
        if manifest.outputs.is_empty() {
            return Err(Error::Runtime("manifest has no outputs".into()));
        }
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        let mut expect = 0usize;
        for p in &self.params {
            if p.offset != expect {
                return Err(Error::Runtime(format!(
                    "param `{}` offset {} != expected {expect} (non-contiguous)",
                    p.name, p.offset
                )));
            }
            let n: usize = p.dims.iter().product();
            if p.nbytes != n * 4 {
                return Err(Error::Runtime(format!(
                    "param `{}` nbytes {} != dims size {}",
                    p.name,
                    p.nbytes,
                    n * 4
                )));
            }
            expect += p.nbytes;
        }
        Ok(())
    }

    pub fn total_weight_bytes(&self) -> usize {
        self.params.iter().map(|p| p.nbytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model detect
input x f32 1,96,96,3
output activation f32 1
param c0.w f32 3,3,3,8 0 864
param c0.b f32 8 864 32
";

    #[test]
    fn parses_sample() {
        let m = ModelManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "detect");
        assert_eq!(m.input.dims, vec![1, 96, 96, 3]);
        assert_eq!(m.outputs.len(), 1);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[1].offset, 864);
        assert_eq!(m.total_weight_bytes(), 896);
    }

    #[test]
    fn missing_model_line_errors() {
        assert!(ModelManifest::parse("input x f32 1\noutput y f32 1\n").is_err());
    }

    #[test]
    fn missing_outputs_errors() {
        assert!(ModelManifest::parse("model m\ninput x f32 1\n").is_err());
    }

    #[test]
    fn non_contiguous_params_rejected() {
        let bad = "model m\ninput x f32 1\noutput y f32 1\nparam p f32 2 4 8\n";
        assert!(ModelManifest::parse(bad).is_err());
    }

    #[test]
    fn size_mismatch_rejected() {
        let bad = "model m\ninput x f32 1\noutput y f32 1\nparam p f32 2 0 4\n";
        assert!(ModelManifest::parse(bad).is_err());
    }

    #[test]
    fn garbage_line_rejected() {
        assert!(ModelManifest::parse("model m\nwhatever\n").is_err());
    }
}
