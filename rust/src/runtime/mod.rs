//! PJRT runtime: load AOT HLO artifacts and execute them from the Rust
//! hot path. Python is never involved at request time.
//!
//! Per model `<name>` the `artifacts/` directory holds:
//! - `<name>.hlo.txt`      — HLO text of `fn(x, *params)` (1-tuple-safe
//!                            interchange; see python/compile/aot.py)
//! - `<name>.weights.bin`  — flat f32 params
//! - `<name>.manifest.txt` — io/param shapes + byte ranges
//!
//! Weights are uploaded to the device ONCE at load (`PjRtBuffer`s); each
//! inference only uploads the input tensor and executes (`execute_b`).

pub mod manifest;

pub use manifest::{ModelManifest, ParamSpec, TensorSpec};

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use crate::tensor::{DType, TensorInfo, TensorsInfo};
use crate::util::{Error, Result};
use crate::{log_debug, log_info};

fn rt_err(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// A loaded, compiled, ready-to-run model.
pub struct Model {
    pub manifest: ModelManifest,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident parameter buffers (uploaded once).
    params: Vec<xla::PjRtBuffer>,
    client: xla::PjRtClient,
}

// The underlying PJRT CPU client is thread-safe; the xla crate just wraps
// raw pointers without declaring it.
unsafe impl Send for Model {}
unsafe impl Sync for Model {}

impl Model {
    /// Load `<dir>/<name>.{hlo.txt,weights.bin,manifest.txt}` and compile.
    pub fn load(dir: &Path, name: &str, client: &xla::PjRtClient) -> Result<Model> {
        let manifest = ModelManifest::load(&dir.join(format!("{name}.manifest.txt")))?;
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
        )
        .map_err(rt_err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(rt_err)?;

        let weights = std::fs::read(dir.join(format!("{name}.weights.bin")))?;
        let mut params = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let end = p.offset + p.nbytes;
            if end > weights.len() {
                return Err(Error::Runtime(format!(
                    "{name}: param {} range {}..{end} exceeds weights.bin ({})",
                    p.name,
                    p.offset,
                    weights.len()
                )));
            }
            let chunk = &weights[p.offset..end];
            let n: usize = p.dims.iter().product();
            let mut vals = vec![0f32; n];
            for (i, c) in chunk.chunks_exact(4).enumerate() {
                vals[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            let dims: Vec<usize> = p.dims.clone();
            let buf = client
                .buffer_from_host_buffer(&vals, &dims, None)
                .map_err(rt_err)?;
            params.push(buf);
        }
        log_info!(
            "runtime",
            "loaded model `{name}`: input {:?}, {} outputs, {} params",
            manifest.input.dims,
            manifest.outputs.len(),
            params.len()
        );
        Ok(Model { manifest, exe, params, client: client.clone() })
    }

    /// Run inference on a raw f32 input slice (row-major, manifest dims).
    /// Returns one Vec<f32> per model output.
    pub fn infer_f32(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let want: usize = self.manifest.input.dims.iter().product();
        if input.len() != want {
            return Err(Error::Runtime(format!(
                "model `{}` expects {want} input f32s, got {}",
                self.manifest.name,
                input.len()
            )));
        }
        let x = self
            .client
            .buffer_from_host_buffer(input, &self.manifest.input.dims, None)
            .map_err(rt_err)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.params.len());
        args.push(&x);
        args.extend(self.params.iter());
        let result = self.exe.execute_b(&args).map_err(rt_err)?;
        let lit = result[0][0].to_literal_sync().map_err(rt_err)?;
        let outputs = lit.to_tuple().map_err(rt_err)?;
        if outputs.len() != self.manifest.outputs.len() {
            return Err(Error::Runtime(format!(
                "model `{}` returned {} outputs, manifest declares {}",
                self.manifest.name,
                outputs.len(),
                self.manifest.outputs.len()
            )));
        }
        let mut out = Vec::with_capacity(outputs.len());
        for lit in outputs {
            out.push(lit.to_vec::<f32>().map_err(rt_err)?);
        }
        Ok(out)
    }

    /// Inference over a little-endian f32 byte payload; returns the
    /// concatenated output payload (static `other/tensors` frame layout).
    pub fn infer_bytes(&self, input: &[u8]) -> Result<Vec<u8>> {
        if input.len() % 4 != 0 {
            return Err(Error::Runtime(format!("input {} bytes not f32-aligned", input.len())));
        }
        let mut vals = vec![0f32; input.len() / 4];
        for (i, c) in input.chunks_exact(4).enumerate() {
            vals[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        let outs = self.infer_f32(&vals)?;
        let total: usize = outs.iter().map(|o| o.len() * 4).sum();
        let mut payload = Vec::with_capacity(total);
        for o in outs {
            for v in o {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(payload)
    }

    /// `other/tensors` caps info of the model input (f32, innermost-first).
    pub fn input_info(&self) -> Result<TensorsInfo> {
        Ok(TensorsInfo::one(spec_to_info(&self.manifest.input)?))
    }

    /// `other/tensors` caps info of the model outputs.
    pub fn output_info(&self) -> Result<TensorsInfo> {
        let mut ti = TensorsInfo::default();
        for o in &self.manifest.outputs {
            ti.push(spec_to_info(o)?)?;
        }
        Ok(ti)
    }
}

/// Convert manifest row-major dims to NNStreamer innermost-first dims.
fn spec_to_info(spec: &TensorSpec) -> Result<TensorInfo> {
    let mut dims: Vec<u32> = spec.dims.iter().map(|&d| d as u32).collect();
    dims.reverse();
    // squeeze leading 1s beyond rank 4 (e.g. batch dim of 1x300x300x3)
    while dims.len() > 4 && dims.last() == Some(&1) {
        dims.pop();
    }
    if dims.is_empty() {
        dims.push(1);
    }
    TensorInfo::new(DType::F32, &dims)
}

/// Shared model store: one PJRT client, models compiled once per process.
pub struct ModelStore {
    client: xla::PjRtClient,
    dir: std::path::PathBuf,
    models: Mutex<HashMap<String, Arc<Model>>>,
}

unsafe impl Send for ModelStore {}
unsafe impl Sync for ModelStore {}

impl ModelStore {
    pub fn new(dir: &Path) -> Result<ModelStore> {
        let client = xla::PjRtClient::cpu().map_err(rt_err)?;
        log_debug!("runtime", "PJRT client: {}", client.platform_name());
        Ok(ModelStore { client, dir: dir.to_path_buf(), models: Mutex::new(HashMap::new()) })
    }

    pub fn get(&self, name: &str) -> Result<Arc<Model>> {
        if let Some(m) = self.models.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        // Compile outside the lock (slow); racing loads are harmless.
        let model = Arc::new(Model::load(&self.dir, name, &self.client)?);
        self.models.lock().unwrap().insert(name.to_string(), model.clone());
        Ok(model)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Process-global stores keyed by artifacts dir.
pub fn store_for(dir: &str) -> Result<Arc<ModelStore>> {
    static STORES: OnceLock<Mutex<HashMap<String, Arc<ModelStore>>>> = OnceLock::new();
    let stores = STORES.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = stores.lock().unwrap();
    if let Some(s) = map.get(dir) {
        return Ok(s.clone());
    }
    let store = Arc::new(ModelStore::new(Path::new(dir))?);
    map.insert(dir.to_string(), store.clone());
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("detect.manifest.txt").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn load_and_run_detect_model() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        assert_eq!(m.manifest.input.dims, vec![1, 96, 96, 3]);
        let input = vec![0.1f32; 1 * 96 * 96 * 3];
        let outs = m.infer_f32(&input).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), 1);
        let p = outs[0][0];
        assert!((0.0..=1.0).contains(&p), "activation {p}");
    }

    #[test]
    fn inference_is_deterministic() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        let input = vec![0.25f32; 96 * 96 * 3];
        let a = m.infer_f32(&input).unwrap();
        let b = m.infer_f32(&input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn wrong_input_size_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        assert!(m.infer_f32(&[0.0; 7]).is_err());
    }

    #[test]
    fn infer_bytes_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        let input = crate::tensor::f32_to_bytes(&vec![0.5f32; 96 * 96 * 3]);
        let out = m.infer_bytes(&input).unwrap();
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn store_caches_models() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let a = store.get("detect").unwrap();
        let b = store.get("detect").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_model_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        assert!(store.get("nonexistent").is_err());
    }

    #[test]
    fn info_conversion_reverses_dims() {
        let spec = TensorSpec { name: "x".into(), dims: vec![1, 300, 300, 3] };
        let info = spec_to_info(&spec).unwrap();
        assert_eq!(info.dims, [3, 300, 300, 1]);
    }
}
