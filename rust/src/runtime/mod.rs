//! PJRT runtime: load AOT HLO artifacts and execute them from the Rust
//! hot path. Python is never involved at request time.
//!
//! Per model `<name>` the `artifacts/` directory holds:
//! - `<name>.hlo.txt`      — HLO text of `fn(x, *params)` (1-tuple-safe
//!                            interchange; see python/compile/aot.py)
//! - `<name>.weights.bin`  — flat f32 params
//! - `<name>.manifest.txt` — io/param shapes + byte ranges
//!
//! Weights are uploaded to the device ONCE at load (`PjRtBuffer`s); each
//! inference only uploads the input tensor and executes (`execute_b`).

pub mod backend;
pub mod batch;
pub mod manifest;

pub use backend::{CustomBackend, CustomFn, InferenceBackend, PassthroughBackend, PjrtBackend};
pub use batch::{BatchCfg, BatchCollector, Slot};
pub use manifest::{ModelManifest, ParamSpec, TensorSpec};

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use crate::buffer::Bytes;
use crate::tensor::{DType, TensorInfo, TensorsInfo};
use crate::util::{Error, Result};
use crate::{log_debug, log_info};

fn rt_err(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// A loaded, compiled, ready-to-run model.
pub struct Model {
    pub manifest: ModelManifest,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident parameter buffers (uploaded once).
    params: Vec<xla::PjRtBuffer>,
    client: xla::PjRtClient,
}

// The underlying PJRT CPU client is thread-safe; the xla crate just wraps
// raw pointers without declaring it.
unsafe impl Send for Model {}
unsafe impl Sync for Model {}

impl Model {
    /// Load `<dir>/<name>.{hlo.txt,weights.bin,manifest.txt}` and compile.
    pub fn load(dir: &Path, name: &str, client: &xla::PjRtClient) -> Result<Model> {
        let manifest = ModelManifest::load(&dir.join(format!("{name}.manifest.txt")))?;
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
        )
        .map_err(rt_err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(rt_err)?;

        let weights = std::fs::read(dir.join(format!("{name}.weights.bin")))?;
        let mut params = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let end = p.offset + p.nbytes;
            if end > weights.len() {
                return Err(Error::Runtime(format!(
                    "{name}: param {} range {}..{end} exceeds weights.bin ({})",
                    p.name,
                    p.offset,
                    weights.len()
                )));
            }
            let chunk = &weights[p.offset..end];
            let n: usize = p.dims.iter().product();
            let mut vals = vec![0f32; n];
            for (i, c) in chunk.chunks_exact(4).enumerate() {
                vals[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            let dims: Vec<usize> = p.dims.clone();
            let buf = client
                .buffer_from_host_buffer(&vals, &dims, None)
                .map_err(rt_err)?;
            params.push(buf);
        }
        log_info!(
            "runtime",
            "loaded model `{name}`: input {:?}, {} outputs, {} params",
            manifest.input.dims,
            manifest.outputs.len(),
            params.len()
        );
        Ok(Model { manifest, exe, params, client: client.clone() })
    }

    /// Run inference on a raw f32 input slice (row-major, manifest dims).
    /// Returns one Vec<f32> per model output.
    pub fn infer_f32(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let want: usize = self.manifest.input.dims.iter().product();
        if input.len() != want {
            return Err(Error::Runtime(format!(
                "model `{}` expects {want} input f32s, got {}",
                self.manifest.name,
                input.len()
            )));
        }
        let x = self
            .client
            .buffer_from_host_buffer(input, &self.manifest.input.dims, None)
            .map_err(rt_err)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.params.len());
        args.push(&x);
        args.extend(self.params.iter());
        let result = self.exe.execute_b(&args).map_err(rt_err)?;
        let lit = result[0][0].to_literal_sync().map_err(rt_err)?;
        let outputs = lit.to_tuple().map_err(rt_err)?;
        if outputs.len() != self.manifest.outputs.len() {
            return Err(Error::Runtime(format!(
                "model `{}` returned {} outputs, manifest declares {}",
                self.manifest.name,
                outputs.len(),
                self.manifest.outputs.len()
            )));
        }
        let mut out = Vec::with_capacity(outputs.len());
        for lit in outputs {
            out.push(lit.to_vec::<f32>().map_err(rt_err)?);
        }
        Ok(out)
    }

    /// Inference over a little-endian f32 byte payload; returns the
    /// concatenated output payload (static `other/tensors` frame layout).
    pub fn infer_bytes(&self, input: &[u8]) -> Result<Vec<u8>> {
        if input.len() % 4 != 0 {
            return Err(Error::Runtime(format!("input {} bytes not f32-aligned", input.len())));
        }
        let mut vals = vec![0f32; input.len() / 4];
        for (i, c) in input.chunks_exact(4).enumerate() {
            vals[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        let outs = self.infer_f32(&vals)?;
        let total: usize = outs.iter().map(|o| o.len() * 4).sum();
        let mut payload = Vec::with_capacity(total);
        for o in outs {
            for v in o {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(payload)
    }

    /// Batched variant of [`Self::infer_bytes`]: one output payload per
    /// input payload, in input order.
    ///
    /// The AOT artifacts are compiled at batch=1, so today this loops
    /// `infer_bytes` per frame — the cross-pipeline batching win is the
    /// amortized dispatch/scheduling cost (one pooled task runs M frames
    /// back-to-back instead of M tasks interleaving), and this method is
    /// the seam where a true multi-batch executable plugs in once
    /// artifacts carry a batch dimension > 1.
    pub fn infer_bytes_batch(&self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::with_capacity(inputs.len());
        for input in inputs {
            out.push(self.infer_bytes(input)?);
        }
        Ok(out)
    }

    /// `other/tensors` caps info of the model input (f32, innermost-first).
    pub fn input_info(&self) -> Result<TensorsInfo> {
        Ok(TensorsInfo::one(spec_to_info(&self.manifest.input)?))
    }

    /// `other/tensors` caps info of the model outputs.
    pub fn output_info(&self) -> Result<TensorsInfo> {
        let mut ti = TensorsInfo::default();
        for o in &self.manifest.outputs {
            ti.push(spec_to_info(o)?)?;
        }
        Ok(ti)
    }
}

/// Convert manifest row-major dims to NNStreamer innermost-first dims.
fn spec_to_info(spec: &TensorSpec) -> Result<TensorInfo> {
    let mut dims: Vec<u32> = spec.dims.iter().map(|&d| d as u32).collect();
    dims.reverse();
    // squeeze leading 1s beyond rank 4 (e.g. batch dim of 1x300x300x3)
    while dims.len() > 4 && dims.last() == Some(&1) {
        dims.pop();
    }
    if dims.is_empty() {
        dims.push(1);
    }
    TensorInfo::new(DType::F32, &dims)
}

/// Per-directory model cache: one PJRT client, models compiled once.
///
/// Since the PR 7 redesign this is a thin per-dir view owned by the
/// process-wide [`ModelRegistry`] — element code should go through
/// [`models()`] (`runtime::models().get(dir, name)`), which dedupes
/// `Arc<Model>` loads across every pipeline in the process.
pub struct ModelStore {
    client: xla::PjRtClient,
    dir: std::path::PathBuf,
    models: Mutex<HashMap<String, Arc<Model>>>,
}

unsafe impl Send for ModelStore {}
unsafe impl Sync for ModelStore {}

impl ModelStore {
    pub fn new(dir: &Path) -> Result<ModelStore> {
        let client = xla::PjRtClient::cpu().map_err(rt_err)?;
        log_debug!("runtime", "PJRT client: {}", client.platform_name());
        Ok(ModelStore { client, dir: dir.to_path_buf(), models: Mutex::new(HashMap::new()) })
    }

    pub fn get(&self, name: &str) -> Result<Arc<Model>> {
        if let Some(m) = self.models.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        // Compile outside the lock (slow); racing loads are harmless.
        let model = Arc::new(Model::load(&self.dir, name, &self.client)?);
        self.models.lock().unwrap().insert(name.to_string(), model.clone());
        Ok(model)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Process-wide shared-model registry: the ONE constructor path for
/// models in element code. Keyed by artifacts dir (one [`ModelStore`] /
/// PJRT client per dir) and by `(dir, name)` for the per-model
/// [`BatchCollector`]s, so M pipelines naming the same `model=` share
/// one `Arc<Model>` and — when batching is enabled — one collector.
pub struct ModelRegistry {
    stores: Mutex<HashMap<String, Arc<ModelStore>>>,
    collectors: Mutex<HashMap<(String, String), Arc<BatchCollector>>>,
}

impl ModelRegistry {
    /// The per-dir store view (compiles lazily; cached per process).
    pub fn store(&self, dir: &str) -> Result<Arc<ModelStore>> {
        if let Some(s) = self.stores.lock().unwrap().get(dir) {
            return Ok(s.clone());
        }
        // Client construction outside the lock; racing creates are
        // harmless (first insert wins via the re-check below).
        let store = Arc::new(ModelStore::new(Path::new(dir))?);
        let mut map = self.stores.lock().unwrap();
        Ok(map.entry(dir.to_string()).or_insert(store).clone())
    }

    /// Load-or-share a model: every pipeline asking for the same
    /// `(dir, name)` gets a clone of the same `Arc<Model>`.
    pub fn get(&self, dir: &str, name: &str) -> Result<Arc<Model>> {
        self.store(dir)?.get(name)
    }

    /// The shared per-model batch collector, PJRT-backed. The first
    /// caller's `cfg` wins; later callers with a different cfg join the
    /// existing collector (one model, one batching policy) with a
    /// warning.
    pub fn collector(&self, dir: &str, name: &str, cfg: BatchCfg) -> Result<Arc<BatchCollector>> {
        let model = self.get(dir, name)?;
        self.collector_with(dir, name, cfg, move || {
            Ok(Box::new(PjrtBackend::new(model)) as Box<dyn InferenceBackend>)
        })
    }

    /// Like [`Self::collector`] but with a caller-supplied backend
    /// factory (tests, custom backends). The factory only runs when no
    /// collector exists yet for `(dir, name)`.
    pub fn collector_with(
        &self,
        dir: &str,
        name: &str,
        cfg: BatchCfg,
        make: impl FnOnce() -> Result<Box<dyn InferenceBackend>>,
    ) -> Result<Arc<BatchCollector>> {
        let key = (dir.to_string(), name.to_string());
        if let Some(c) = self.collectors.lock().unwrap().get(&key) {
            if c.cfg() != cfg {
                batch::warn_cfg_mismatch(name, c.cfg(), cfg);
            }
            return Ok(c.clone());
        }
        // Build the backend (may compile a model) outside the lock.
        let fresh = BatchCollector::new(name, make()?, cfg);
        let mut map = self.collectors.lock().unwrap();
        let c = map.entry(key).or_insert_with(|| fresh.clone()).clone();
        if !Arc::ptr_eq(&c, &fresh) && c.cfg() != fresh.cfg() {
            // Raced with another pipeline that installed a different
            // policy first; first-wins, same as the fast path above.
            batch::warn_cfg_mismatch(name, c.cfg(), cfg);
        }
        Ok(c)
    }
}

/// The process-wide [`ModelRegistry`].
pub fn models() -> &'static ModelRegistry {
    static REG: OnceLock<ModelRegistry> = OnceLock::new();
    REG.get_or_init(|| ModelRegistry {
        stores: Mutex::new(HashMap::new()),
        collectors: Mutex::new(HashMap::new()),
    })
}

/// Process-global per-dir store lookup.
///
/// Deprecated path: kept for callers that still think in per-dir stores;
/// new element code should use [`models()`] directly
/// (`runtime::models().get(dir, name)`).
pub fn store_for(dir: &str) -> Result<Arc<ModelStore>> {
    models().store(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("detect.manifest.txt").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn load_and_run_detect_model() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        assert_eq!(m.manifest.input.dims, vec![1, 96, 96, 3]);
        let input = vec![0.1f32; 1 * 96 * 96 * 3];
        let outs = m.infer_f32(&input).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), 1);
        let p = outs[0][0];
        assert!((0.0..=1.0).contains(&p), "activation {p}");
    }

    #[test]
    fn inference_is_deterministic() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        let input = vec![0.25f32; 96 * 96 * 3];
        let a = m.infer_f32(&input).unwrap();
        let b = m.infer_f32(&input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn wrong_input_size_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        assert!(m.infer_f32(&[0.0; 7]).is_err());
    }

    #[test]
    fn infer_bytes_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let m = store.get("detect").unwrap();
        let input = crate::tensor::f32_to_bytes(&vec![0.5f32; 96 * 96 * 3]);
        let out = m.infer_bytes(&input).unwrap();
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn store_caches_models() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        let a = store.get("detect").unwrap();
        let b = store.get("detect").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_model_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ModelStore::new(&dir).unwrap();
        assert!(store.get("nonexistent").is_err());
    }

    #[test]
    fn store_for_is_a_registry_view() {
        let a = store_for("/tmp/edgepipe-test-store-view").unwrap();
        let b = models().store("/tmp/edgepipe-test-store-view").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "store_for and the registry must share per-dir stores");
    }

    #[test]
    fn registry_dedupes_collectors_first_cfg_wins() {
        let cfg_a = BatchCfg { max_batch: 4, timeout: std::time::Duration::from_millis(7) };
        let cfg_b = BatchCfg { max_batch: 16, timeout: std::time::Duration::from_millis(2) };
        let a = models()
            .collector_with("/tmp/edgepipe-test-collectors", "m", cfg_a, || {
                Ok(Box::new(PassthroughBackend))
            })
            .unwrap();
        let b = models()
            .collector_with("/tmp/edgepipe-test-collectors", "m", cfg_b, || {
                panic!("factory must not run for an existing collector")
            })
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(b.cfg(), cfg_a, "first pipeline's batching policy wins");
    }

    #[test]
    fn registry_shares_one_model_across_pipelines() {
        let Some(dir) = artifacts_dir() else { return };
        let dir = dir.to_str().unwrap().to_string();
        let m = models().get(&dir, "detect").unwrap();
        let base = Arc::strong_count(&m);
        let a = models().get(&dir, "detect").unwrap();
        let b = models().get(&dir, "detect").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(
            Arc::strong_count(&m) >= base + 2 && Arc::strong_count(&m) >= 3,
            "same (dir, name) must share one Arc<Model>"
        );
    }

    #[test]
    fn info_conversion_reverses_dims() {
        let spec = TensorSpec { name: "x".into(), dims: vec![1, 300, 300, 3] };
        let info = spec_to_info(&spec).unwrap();
        assert_eq!(info.dims, [3, 300, 300, 1]);
    }
}
