//! Frame compression for inter-pipeline transmission (R3; gst-gz analog).
//!
//! zlib via `flate2`. Transport elements apply this per-frame when
//! `compress=zlib` (or `compress=auto`) is configured; the wire flag
//! travels in the EdgeFrame header so receivers self-configure.
//!
//! ## Streaming API (the one-allocation compressed hop)
//!
//! The hot path never materialises an intermediate compressed buffer:
//!
//! - [`deflate_into`] deflates a payload **directly onto the tail of the
//!   frame being assembled**, so `wire::encode_vectored` emits a zlib
//!   `WireFrame` whose header and compressed payload share one backing
//!   allocation.
//! - [`inflate_guarded`] inflates a received frame view into a single
//!   output buffer, enforcing the decompressed-size limit *incrementally*
//!   while the stream is inflating (a zlib bomb is rejected mid-stream,
//!   and never causes more than `max` bytes of output to be reserved),
//!   and rejecting truncated streams instead of silently returning a
//!   prefix.
//!
//! [`AutoCodec`] implements the adaptive `Codec::Auto` mode: it samples
//! the per-link compression ratio and stops paying for deflate when the
//! stream is incompressible (pre-compressed video, encrypted blobs),
//! re-probing periodically in case the content changes. Decisions are
//! recorded in the per-link `metrics` registry.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::{Error, Result};

/// Compression codec for transport frames.
///
/// The `u8` value of `None`/`Zlib`/`Delta`/`Sparse` is the on-wire codec
/// flag. `Auto` is a *policy*, not a wire codec: encoders resolve it to
/// one of the concrete arms per frame before the header is written, so
/// it never travels (its discriminant is reserved and rejected on
/// receive).
///
/// `Delta` and `Sparse` are *stateful link codecs*: they need the
/// per-link history kept by `wire::LinkCodec` / `wire::LinkDecoder`
/// (delta chains) or the stream's tensor layout (sparse COO), so the
/// stateless [`compress`]/[`decompress`] helpers reject them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum Codec {
    #[default]
    None = 0,
    Zlib = 1,
    Auto = 2,
    Delta = 3,
    Sparse = 4,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Zlib => "zlib",
            Codec::Auto => "auto",
            Codec::Delta => "delta",
            Codec::Sparse => "sparse",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Codec::None,
            "zlib" | "gz" => Codec::Zlib,
            "auto" => Codec::Auto,
            "delta" => Codec::Delta,
            "sparse" => Codec::Sparse,
            other => return Err(Error::Serial(format!("unknown codec `{other}`"))),
        })
    }
}

/// Max decompressed size we accept (guards hostile frames): 256 MiB.
pub const MAX_DECOMPRESSED: u64 = 256 * 1024 * 1024;

/// Process-wide count of deflate operations (each call that compresses
/// one payload). The broker fan-out bench asserts this grows once per
/// *published* frame, never per subscriber.
static DEFLATES: AtomicU64 = AtomicU64::new(0);

/// Total deflate operations so far in this process.
pub fn deflate_ops() -> u64 {
    DEFLATES.load(Ordering::Relaxed)
}

/// Count one deflate operation performed outside [`deflate_into`]
/// (the delta codec runs its own streaming compressor).
pub(crate) fn note_deflate() {
    DEFLATES.fetch_add(1, Ordering::Relaxed);
}

/// Streaming compressor: zlib-deflate `data` appended directly onto
/// `out` (the frame being assembled). Returns the number of compressed
/// bytes written. No intermediate compressed buffer is allocated; `out`
/// grows in place as the encoder needs space.
pub fn deflate_into(out: &mut Vec<u8>, data: &[u8]) -> Result<usize> {
    DEFLATES.fetch_add(1, Ordering::Relaxed);
    let start = out.len();
    let mut c = flate2::Compress::new(flate2::Compression::fast(), true);
    loop {
        // Guarantee spare output capacity so every iteration progresses.
        if out.capacity() - out.len() < 1024 {
            out.reserve((data.len() / 2 + 64).max(4096));
        }
        let consumed = c.total_in() as usize;
        let status = c
            .compress_vec(&data[consumed..], out, flate2::FlushCompress::Finish)
            .map_err(|e| Error::Serial(format!("deflate: {e}")))?;
        if status == flate2::Status::StreamEnd {
            return Ok(out.len() - start);
        }
        // Status::Ok / Status::BufError: more output space needed; the
        // reserve at the top of the loop provides it.
    }
}

/// Streaming inflater: decompress a zlib stream (typically a payload view
/// into a received frame) into one fresh buffer.
///
/// The `max` output limit is enforced *while* inflating: output capacity
/// is grown geometrically but never reserved past `max`, and the moment
/// the stream wants to produce byte `max + 1` the frame is rejected with
/// [`Error::Serial`] — a zlib bomb cannot make us allocate its claimed
/// size. Truncated streams (input exhausted before the stream end marker)
/// are also rejected instead of yielding a silent prefix.
pub fn inflate_guarded(data: &[u8], max: u64) -> Result<Vec<u8>> {
    let mut d = flate2::Decompress::new(true);
    let limit = max.min(usize::MAX as u64) as usize;
    // Start from the input size, not the (attacker-controlled) claimed
    // output size: a tiny bomb must not trigger a huge up-front reserve.
    let initial = data.len().saturating_mul(3).max(64).min(limit.max(1));
    let mut out: Vec<u8> = Vec::with_capacity(initial);
    loop {
        if out.len() == out.capacity() {
            if out.len() >= limit {
                return Err(Error::Serial(format!(
                    "decompressed payload exceeds the {max}-byte limit"
                )));
            }
            // reserve_exact, clamped to the limit: plain reserve's
            // amortized doubling could hand back capacity past `limit`,
            // and the inflater would happily fill it.
            let grow = out.capacity().max(1024).min(limit - out.len());
            out.reserve_exact(grow);
        }
        let consumed = d.total_in() as usize;
        let produced = out.len();
        let status = d
            .decompress_vec(&data[consumed..], &mut out, flate2::FlushDecompress::Finish)
            .map_err(|e| Error::Serial(format!("inflate: {e}")))?;
        match status {
            flate2::Status::StreamEnd => {
                // Belt and braces: even if the allocator rounded a
                // reserve up past `limit`, never return an over-budget
                // payload.
                if out.len() > limit {
                    return Err(Error::Serial(format!(
                        "decompressed payload exceeds the {max}-byte limit"
                    )));
                }
                return Ok(out);
            }
            flate2::Status::Ok | flate2::Status::BufError => {
                let stalled = d.total_in() as usize == consumed && out.len() == produced;
                if stalled && out.len() < out.capacity() {
                    // Spare output space, yet neither input consumed nor
                    // output produced: the stream ended early.
                    return Err(Error::Serial("truncated zlib stream".into()));
                }
            }
        }
    }
}

pub fn compress(codec: Codec, data: &[u8]) -> Result<Vec<u8>> {
    match codec {
        Codec::None => Ok(data.to_vec()),
        Codec::Zlib => {
            let mut out = Vec::with_capacity(data.len() / 2 + 64);
            deflate_into(&mut out, data)?;
            Ok(out)
        }
        Codec::Auto => Err(Error::Serial(
            "Codec::Auto is a policy, not a wire codec; resolve it before compressing".into(),
        )),
        Codec::Delta | Codec::Sparse => Err(Error::Serial(format!(
            "Codec::{codec:?} is a stateful link codec; use wire::LinkCodec to encode it"
        ))),
    }
}

pub fn decompress(codec: Codec, data: &[u8]) -> Result<Vec<u8>> {
    match codec {
        Codec::None => Ok(data.to_vec()),
        Codec::Zlib => inflate_guarded(data, MAX_DECOMPRESSED),
        Codec::Auto => Err(Error::Serial(
            "Codec::Auto is a policy, not a wire codec; it never appears on received frames".into(),
        )),
        Codec::Delta | Codec::Sparse => Err(Error::Serial(format!(
            "Codec::{codec:?} is a stateful link codec; use wire::LinkDecoder to decode it"
        ))),
    }
}

// ---------------------------------------------------------------------------
// Adaptive codec (Codec::Auto)
// ---------------------------------------------------------------------------

/// What `Codec::Auto` should do with the next frame on a link:
/// measure every applicable arm ([`AutoDecision::Probe`]) or emit the
/// current steady-state arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoDecision {
    /// Sample all applicable arms on this frame and report the sizes
    /// via [`AutoCodec::record_probe`]; the winner is adopted.
    Probe,
    /// Encode with the current arm and report via
    /// [`AutoCodec::record_arm`] / [`AutoCodec::record_none`].
    Use(Codec),
}

/// Per-link adaptive codec state backing `Codec::Auto`.
///
/// Strategy: on a probe frame (the first frame of a link, then one
/// every `probe_interval`) the link samples the encoded size of every
/// applicable arm — zlib always, XOR-delta when the previous frame
/// lines up, sparse COO when the caps describe static tensors — and
/// adopts the smallest; if even the best arm fails `max_ratio`, the
/// link falls back to `Codec::None` and stops paying for encoding.
/// Between probes the adopted arm keeps reporting its achieved ratio:
/// after `strike_limit` consecutive frames at or above `max_ratio`
/// (content drifted incompressible — pre-compressed video, noise,
/// ciphertext) the link drops to pass-through until a probe finds an
/// arm that pays again.
///
/// Every sampled ratio and every mode switch is recorded in the global
/// [`crate::metrics`] registry under `codec.auto.<link>.*` so operators
/// can see what each link decided and why.
pub struct AutoCodec {
    mode: Codec,
    strikes: u32,
    frames_since_probe: u64,
    /// Ratios at or above this count as "not worth compressing".
    pub max_ratio: f64,
    /// Consecutive bad ratios before falling back to `Codec::None`.
    pub strike_limit: u32,
    /// Frames between probes.
    pub probe_interval: u64,
    // Metric handles resolved once at construction — the per-frame cost
    // of recording is an atomic op, not a format!+registry lookup.
    m_ratio: std::sync::Arc<crate::metrics::Histogram>,
    m_zlib_frames: std::sync::Arc<crate::metrics::Counter>,
    m_delta_frames: std::sync::Arc<crate::metrics::Counter>,
    m_sparse_frames: std::sync::Arc<crate::metrics::Counter>,
    m_none_frames: std::sync::Arc<crate::metrics::Counter>,
    m_to_none: std::sync::Arc<crate::metrics::Counter>,
    m_to_zlib: std::sync::Arc<crate::metrics::Counter>,
    m_to_delta: std::sync::Arc<crate::metrics::Counter>,
    m_to_sparse: std::sync::Arc<crate::metrics::Counter>,
}

impl AutoCodec {
    pub fn new(link: &str) -> Self {
        let m = crate::metrics::global();
        Self {
            mode: Codec::Zlib,
            strikes: 0,
            // Primed at the probe interval so the first `next_mode()`
            // call probes — a fresh link measures every arm before
            // settling. (The legacy `next_codec()` path only reads this
            // in pass-through mode, where mode switches reset it, so
            // its behavior is unchanged.)
            frames_since_probe: 64,
            max_ratio: 0.9,
            strike_limit: 3,
            probe_interval: 64,
            m_ratio: m.histogram(&format!("codec.auto.{link}.ratio")),
            m_zlib_frames: m.counter(&format!("codec.auto.{link}.zlib_frames")),
            m_delta_frames: m.counter(&format!("codec.auto.{link}.delta_frames")),
            m_sparse_frames: m.counter(&format!("codec.auto.{link}.sparse_frames")),
            m_none_frames: m.counter(&format!("codec.auto.{link}.none_frames")),
            m_to_none: m.counter(&format!("codec.auto.{link}.to_none")),
            m_to_zlib: m.counter(&format!("codec.auto.{link}.to_zlib")),
            m_to_delta: m.counter(&format!("codec.auto.{link}.to_delta")),
            m_to_sparse: m.counter(&format!("codec.auto.{link}.to_sparse")),
        }
    }

    /// Current steady-state arm (`Codec::None` in pass-through mode).
    pub fn mode(&self) -> Codec {
        self.mode
    }

    fn set_mode(&mut self, mode: Codec) {
        if self.mode == mode {
            return;
        }
        self.mode = mode;
        match mode {
            Codec::None => {
                self.frames_since_probe = 0;
                self.m_to_none.inc();
            }
            Codec::Zlib => self.m_to_zlib.inc(),
            Codec::Delta => self.m_to_delta.inc(),
            Codec::Sparse => self.m_to_sparse.inc(),
            Codec::Auto => unreachable!("Auto is never an arm"),
        }
    }

    /// Multi-arm frame decision for `wire::LinkCodec`.
    pub fn next_mode(&mut self) -> AutoDecision {
        self.frames_since_probe = self.frames_since_probe.saturating_add(1);
        if self.frames_since_probe >= self.probe_interval {
            self.frames_since_probe = 0;
            AutoDecision::Probe
        } else {
            AutoDecision::Use(self.mode)
        }
    }

    /// Report a probe frame: `candidates` holds the sampled encoded
    /// payload size of every applicable arm. Adopts (and returns) the
    /// smallest arm that beats `max_ratio`, else `Codec::None`.
    pub fn record_probe(&mut self, raw: usize, candidates: &[(Codec, usize)]) -> Codec {
        let mut best = (Codec::None, raw);
        for &(codec, size) in candidates {
            if size < best.1 {
                best = (codec, size);
            }
        }
        let ratio = if raw == 0 { 1.0 } else { best.1 as f64 / raw as f64 };
        self.m_ratio.observe(ratio);
        if ratio >= self.max_ratio {
            self.set_mode(Codec::None);
        } else {
            self.strikes = 0;
            self.set_mode(best.0);
        }
        match self.mode {
            Codec::Zlib => self.m_zlib_frames.inc(),
            Codec::Delta => self.m_delta_frames.inc(),
            Codec::Sparse => self.m_sparse_frames.inc(),
            _ => self.m_none_frames.inc(),
        }
        self.mode
    }

    /// Record the outcome of a steady-state frame encoded with `codec`
    /// (raw vs encoded payload bytes) and update the mode via the
    /// strike logic.
    pub fn record_arm(&mut self, codec: Codec, raw: usize, encoded: usize) {
        let ratio = if raw == 0 { 1.0 } else { encoded as f64 / raw as f64 };
        self.m_ratio.observe(ratio);
        match codec {
            Codec::Delta => self.m_delta_frames.inc(),
            Codec::Sparse => self.m_sparse_frames.inc(),
            _ => self.m_zlib_frames.inc(),
        }
        if ratio >= self.max_ratio {
            self.strikes = self.strikes.saturating_add(1);
            if self.mode != Codec::None && self.strikes >= self.strike_limit {
                self.set_mode(Codec::None);
            }
        } else {
            self.strikes = 0;
            if self.mode == Codec::None {
                self.set_mode(codec);
            }
        }
    }

    /// Codec to use for the next frame (legacy zlib-or-none path used
    /// by [`crate::serial::wire::encode_vectored_auto`]: Zlib while the
    /// link compresses well, None otherwise, with a periodic probe).
    pub fn next_codec(&mut self) -> Codec {
        if self.mode != Codec::None {
            return Codec::Zlib;
        }
        self.frames_since_probe += 1;
        if self.frames_since_probe >= self.probe_interval {
            self.frames_since_probe = 0;
            Codec::Zlib
        } else {
            Codec::None
        }
    }

    /// Record the outcome of a deflated frame (raw vs compressed bytes)
    /// and update the mode.
    pub fn record_zlib(&mut self, raw: usize, compressed: usize) {
        self.record_arm(Codec::Zlib, raw, compressed);
    }

    /// Record a frame sent uncompressed in pass-through mode.
    pub fn record_none(&mut self) {
        self.m_none_frames.inc();
    }

    /// Is the link currently paying for encoding? (tests/benches)
    pub fn is_compressing(&self) -> bool {
        self.mode != Codec::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn codec_parse_roundtrip() {
        assert_eq!(Codec::parse("none").unwrap(), Codec::None);
        assert_eq!(Codec::parse("zlib").unwrap(), Codec::Zlib);
        assert_eq!(Codec::parse("gz").unwrap(), Codec::Zlib);
        assert_eq!(Codec::parse("auto").unwrap(), Codec::Auto);
        assert_eq!(Codec::parse("delta").unwrap(), Codec::Delta);
        assert_eq!(Codec::parse("sparse").unwrap(), Codec::Sparse);
        assert!(Codec::parse("lz99").is_err());
        for c in [Codec::None, Codec::Zlib, Codec::Auto, Codec::Delta, Codec::Sparse] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
    }

    #[test]
    fn stateful_codecs_rejected_by_stateless_helpers() {
        for c in [Codec::Delta, Codec::Sparse] {
            assert!(compress(c, &[1, 2, 3]).is_err());
            assert!(decompress(c, &[1, 2, 3]).is_err());
        }
    }

    #[test]
    fn none_is_identity() {
        let data = vec![1u8, 2, 3];
        assert_eq!(compress(Codec::None, &data).unwrap(), data);
        assert_eq!(decompress(Codec::None, &data).unwrap(), data);
    }

    #[test]
    fn auto_is_not_a_wire_codec() {
        assert!(compress(Codec::Auto, &[1, 2, 3]).is_err());
        assert!(decompress(Codec::Auto, &[1, 2, 3]).is_err());
    }

    #[test]
    fn zlib_roundtrip_compressible() {
        let data = vec![7u8; 100_000];
        let c = compress(Codec::Zlib, &data).unwrap();
        assert!(c.len() < data.len() / 10, "compressed {} of {}", c.len(), data.len());
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_random() {
        let mut rng = XorShift64::new(1);
        let mut data = vec![0u8; 10_000];
        rng.fill_bytes(&mut data);
        let c = compress(Codec::Zlib, &data).unwrap();
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_empty() {
        let c = compress(Codec::Zlib, &[]).unwrap();
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn deflate_into_appends_in_place() {
        let mut frame = b"HEADER".to_vec();
        let data = vec![9u8; 50_000];
        let n = deflate_into(&mut frame, &data).unwrap();
        assert_eq!(frame.len(), 6 + n);
        assert_eq!(&frame[..6], b"HEADER");
        assert_eq!(inflate_guarded(&frame[6..], MAX_DECOMPRESSED).unwrap(), data);
    }

    #[test]
    fn deflate_ops_counts_compressions() {
        let before = deflate_ops();
        let _ = compress(Codec::Zlib, &[1, 2, 3]).unwrap();
        assert!(deflate_ops() > before);
    }

    #[test]
    fn corrupt_stream_errors() {
        assert!(decompress(Codec::Zlib, &[1, 2, 3, 4]).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let data = vec![5u8; 20_000];
        let c = compress(Codec::Zlib, &data).unwrap();
        for cut in [1, c.len() / 2, c.len() - 1] {
            let e = inflate_guarded(&c[..cut], MAX_DECOMPRESSED).unwrap_err();
            assert!(matches!(e, Error::Serial(_)), "cut at {cut}: {e}");
        }
        assert!(inflate_guarded(&[], MAX_DECOMPRESSED).is_err());
    }

    #[test]
    fn bomb_rejected_mid_stream() {
        // 4 MiB of zeros deflates to a few KiB; inflating under a 64 KiB
        // limit must fail once the limit is crossed, not after expanding
        // the whole stream.
        let raw = vec![0u8; 4 * 1024 * 1024];
        let c = compress(Codec::Zlib, &raw).unwrap();
        assert!(c.len() < 64 * 1024);
        let e = inflate_guarded(&c, 64 * 1024).unwrap_err();
        assert!(e.to_string().contains("limit"), "{e}");
        // A payload exactly at the limit still inflates.
        let ok = vec![3u8; 64 * 1024];
        let c2 = compress(Codec::Zlib, &ok).unwrap();
        assert_eq!(inflate_guarded(&c2, 64 * 1024).unwrap(), ok);
    }

    #[test]
    fn limit_is_exact_not_capacity_rounded() {
        // Regression: Vec's amortized growth must not smuggle in output
        // past the limit — one byte over is rejected, exactly-at passes,
        // for an odd limit that no power-of-two capacity lands on.
        let limit = 100_003u64;
        let at = vec![9u8; limit as usize];
        let over = vec![9u8; limit as usize + 1];
        let c_at = compress(Codec::Zlib, &at).unwrap();
        let c_over = compress(Codec::Zlib, &over).unwrap();
        assert_eq!(inflate_guarded(&c_at, limit).unwrap(), at);
        assert!(inflate_guarded(&c_over, limit).is_err());
    }

    #[test]
    fn auto_codec_disables_on_incompressible_then_reprobes() {
        let mut auto = AutoCodec::new("test-link");
        assert!(auto.is_compressing());
        // Incompressible frames: ratio ~1.0 -> strikes out after 3.
        for _ in 0..auto.strike_limit {
            assert_eq!(auto.next_codec(), Codec::Zlib);
            auto.record_zlib(1000, 990);
        }
        assert!(!auto.is_compressing());
        // Pass-through until the probe interval elapses.
        let mut zlib_probes = 0;
        for _ in 0..auto.probe_interval {
            if auto.next_codec() == Codec::Zlib {
                zlib_probes += 1;
                // Content turned compressible: switch back on.
                auto.record_zlib(1000, 100);
            } else {
                auto.record_none();
            }
        }
        assert_eq!(zlib_probes, 1, "expected exactly one probe per interval");
        assert!(auto.is_compressing(), "good probe ratio must re-enable zlib");
        assert_eq!(auto.next_codec(), Codec::Zlib);
    }

    #[test]
    fn auto_first_frame_probes_and_adopts_best_arm() {
        let mut auto = AutoCodec::new("test-link-probe");
        // Fresh link: the very first frame is a probe.
        assert_eq!(auto.next_mode(), AutoDecision::Probe);
        // Delta sampled smallest -> adopted.
        let w = auto.record_probe(1000, &[(Codec::Zlib, 400), (Codec::Delta, 50)]);
        assert_eq!(w, Codec::Delta);
        assert_eq!(auto.mode(), Codec::Delta);
        assert!(auto.is_compressing());
        // Steady state uses the adopted arm until the next probe.
        for _ in 0..(auto.probe_interval - 1) {
            assert_eq!(auto.next_mode(), AutoDecision::Use(Codec::Delta));
            auto.record_arm(Codec::Delta, 1000, 50);
        }
        assert_eq!(auto.next_mode(), AutoDecision::Probe);
        // Probe where nothing beats max_ratio -> pass-through.
        assert_eq!(auto.record_probe(1000, &[(Codec::Zlib, 990), (Codec::Delta, 995)]), Codec::None);
        assert!(!auto.is_compressing());
    }

    #[test]
    fn auto_strikes_demote_adopted_arm() {
        let mut auto = AutoCodec::new("test-link-strikes");
        auto.next_mode();
        auto.record_probe(1000, &[(Codec::Sparse, 100)]);
        assert_eq!(auto.mode(), Codec::Sparse);
        // Content drifts dense: consecutive bad ratios strike the arm out.
        for _ in 0..auto.strike_limit {
            assert!(matches!(auto.next_mode(), AutoDecision::Use(Codec::Sparse)));
            auto.record_arm(Codec::Sparse, 1000, 990);
        }
        assert_eq!(auto.mode(), Codec::None);
    }

    #[test]
    fn auto_codec_stays_off_while_probes_fail() {
        let mut auto = AutoCodec::new("test-link-2");
        for _ in 0..auto.strike_limit {
            auto.next_codec();
            auto.record_zlib(100, 100);
        }
        assert!(!auto.is_compressing());
        for _ in 0..(3 * auto.probe_interval) {
            if auto.next_codec() == Codec::Zlib {
                auto.record_zlib(100, 100); // probe still incompressible
            } else {
                auto.record_none();
            }
            assert!(!auto.is_compressing());
        }
    }
}
