//! Frame compression for inter-pipeline transmission (R3; gst-gz analog).
//!
//! zlib via `flate2`. Transport elements apply this per-frame when
//! `compress=zlib` (or `compress=auto`) is configured; the wire flag
//! travels in the EdgeFrame header so receivers self-configure.
//!
//! ## Streaming API (the one-allocation compressed hop)
//!
//! The hot path never materialises an intermediate compressed buffer:
//!
//! - [`deflate_into`] deflates a payload **directly onto the tail of the
//!   frame being assembled**, so `wire::encode_vectored` emits a zlib
//!   `WireFrame` whose header and compressed payload share one backing
//!   allocation.
//! - [`inflate_guarded`] inflates a received frame view into a single
//!   output buffer, enforcing the decompressed-size limit *incrementally*
//!   while the stream is inflating (a zlib bomb is rejected mid-stream,
//!   and never causes more than `max` bytes of output to be reserved),
//!   and rejecting truncated streams instead of silently returning a
//!   prefix.
//!
//! [`AutoCodec`] implements the adaptive `Codec::Auto` mode: it samples
//! the per-link compression ratio and stops paying for deflate when the
//! stream is incompressible (pre-compressed video, encrypted blobs),
//! re-probing periodically in case the content changes. Decisions are
//! recorded in the per-link `metrics` registry.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::{Error, Result};

/// Compression codec for transport frames.
///
/// The `u8` value of `None`/`Zlib` is the on-wire codec flag. `Auto` is a
/// *policy*, not a wire codec: encoders resolve it to `None` or `Zlib`
/// per frame before the header is written, so it never travels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum Codec {
    #[default]
    None = 0,
    Zlib = 1,
    Auto = 2,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Zlib => "zlib",
            Codec::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Codec::None,
            "zlib" | "gz" => Codec::Zlib,
            "auto" => Codec::Auto,
            other => return Err(Error::Serial(format!("unknown codec `{other}`"))),
        })
    }
}

/// Max decompressed size we accept (guards hostile frames): 256 MiB.
pub const MAX_DECOMPRESSED: u64 = 256 * 1024 * 1024;

/// Process-wide count of deflate operations (each call that compresses
/// one payload). The broker fan-out bench asserts this grows once per
/// *published* frame, never per subscriber.
static DEFLATES: AtomicU64 = AtomicU64::new(0);

/// Total deflate operations so far in this process.
pub fn deflate_ops() -> u64 {
    DEFLATES.load(Ordering::Relaxed)
}

/// Streaming compressor: zlib-deflate `data` appended directly onto
/// `out` (the frame being assembled). Returns the number of compressed
/// bytes written. No intermediate compressed buffer is allocated; `out`
/// grows in place as the encoder needs space.
pub fn deflate_into(out: &mut Vec<u8>, data: &[u8]) -> Result<usize> {
    DEFLATES.fetch_add(1, Ordering::Relaxed);
    let start = out.len();
    let mut c = flate2::Compress::new(flate2::Compression::fast(), true);
    loop {
        // Guarantee spare output capacity so every iteration progresses.
        if out.capacity() - out.len() < 1024 {
            out.reserve((data.len() / 2 + 64).max(4096));
        }
        let consumed = c.total_in() as usize;
        let status = c
            .compress_vec(&data[consumed..], out, flate2::FlushCompress::Finish)
            .map_err(|e| Error::Serial(format!("deflate: {e}")))?;
        if status == flate2::Status::StreamEnd {
            return Ok(out.len() - start);
        }
        // Status::Ok / Status::BufError: more output space needed; the
        // reserve at the top of the loop provides it.
    }
}

/// Streaming inflater: decompress a zlib stream (typically a payload view
/// into a received frame) into one fresh buffer.
///
/// The `max` output limit is enforced *while* inflating: output capacity
/// is grown geometrically but never reserved past `max`, and the moment
/// the stream wants to produce byte `max + 1` the frame is rejected with
/// [`Error::Serial`] — a zlib bomb cannot make us allocate its claimed
/// size. Truncated streams (input exhausted before the stream end marker)
/// are also rejected instead of yielding a silent prefix.
pub fn inflate_guarded(data: &[u8], max: u64) -> Result<Vec<u8>> {
    let mut d = flate2::Decompress::new(true);
    let limit = max.min(usize::MAX as u64) as usize;
    // Start from the input size, not the (attacker-controlled) claimed
    // output size: a tiny bomb must not trigger a huge up-front reserve.
    let initial = data.len().saturating_mul(3).max(64).min(limit.max(1));
    let mut out: Vec<u8> = Vec::with_capacity(initial);
    loop {
        if out.len() == out.capacity() {
            if out.len() >= limit {
                return Err(Error::Serial(format!(
                    "decompressed payload exceeds the {max}-byte limit"
                )));
            }
            // reserve_exact, clamped to the limit: plain reserve's
            // amortized doubling could hand back capacity past `limit`,
            // and the inflater would happily fill it.
            let grow = out.capacity().max(1024).min(limit - out.len());
            out.reserve_exact(grow);
        }
        let consumed = d.total_in() as usize;
        let produced = out.len();
        let status = d
            .decompress_vec(&data[consumed..], &mut out, flate2::FlushDecompress::Finish)
            .map_err(|e| Error::Serial(format!("inflate: {e}")))?;
        match status {
            flate2::Status::StreamEnd => {
                // Belt and braces: even if the allocator rounded a
                // reserve up past `limit`, never return an over-budget
                // payload.
                if out.len() > limit {
                    return Err(Error::Serial(format!(
                        "decompressed payload exceeds the {max}-byte limit"
                    )));
                }
                return Ok(out);
            }
            flate2::Status::Ok | flate2::Status::BufError => {
                let stalled = d.total_in() as usize == consumed && out.len() == produced;
                if stalled && out.len() < out.capacity() {
                    // Spare output space, yet neither input consumed nor
                    // output produced: the stream ended early.
                    return Err(Error::Serial("truncated zlib stream".into()));
                }
            }
        }
    }
}

pub fn compress(codec: Codec, data: &[u8]) -> Result<Vec<u8>> {
    match codec {
        Codec::None => Ok(data.to_vec()),
        Codec::Zlib => {
            let mut out = Vec::with_capacity(data.len() / 2 + 64);
            deflate_into(&mut out, data)?;
            Ok(out)
        }
        Codec::Auto => Err(Error::Serial(
            "Codec::Auto is a policy, not a wire codec; resolve it before compressing".into(),
        )),
    }
}

pub fn decompress(codec: Codec, data: &[u8]) -> Result<Vec<u8>> {
    match codec {
        Codec::None => Ok(data.to_vec()),
        Codec::Zlib => inflate_guarded(data, MAX_DECOMPRESSED),
        Codec::Auto => Err(Error::Serial(
            "Codec::Auto is a policy, not a wire codec; it never appears on received frames".into(),
        )),
    }
}

// ---------------------------------------------------------------------------
// Adaptive codec (Codec::Auto)
// ---------------------------------------------------------------------------

/// Per-link adaptive codec state backing `Codec::Auto`.
///
/// Strategy: keep compressing while deflate earns its keep. After
/// `strike_limit` consecutive frames whose compressed/raw ratio is at or
/// above `max_ratio` (incompressible content — pre-compressed video,
/// noise, ciphertext), fall back to `Codec::None` and stop paying for
/// deflate. While in pass-through mode, re-probe one frame every
/// `probe_interval` frames; a good ratio switches compression back on.
///
/// Every sampled ratio and every mode switch is recorded in the global
/// [`crate::metrics`] registry under `codec.auto.<link>.*` so operators
/// can see what each link decided and why.
pub struct AutoCodec {
    compressing: bool,
    strikes: u32,
    frames_since_probe: u64,
    /// Ratios at or above this count as "not worth compressing".
    pub max_ratio: f64,
    /// Consecutive bad ratios before falling back to `Codec::None`.
    pub strike_limit: u32,
    /// Pass-through frames between re-probes.
    pub probe_interval: u64,
    // Metric handles resolved once at construction — the per-frame cost
    // of recording is an atomic op, not a format!+registry lookup.
    m_ratio: std::sync::Arc<crate::metrics::Histogram>,
    m_zlib_frames: std::sync::Arc<crate::metrics::Counter>,
    m_none_frames: std::sync::Arc<crate::metrics::Counter>,
    m_to_none: std::sync::Arc<crate::metrics::Counter>,
    m_to_zlib: std::sync::Arc<crate::metrics::Counter>,
}

impl AutoCodec {
    pub fn new(link: &str) -> Self {
        let m = crate::metrics::global();
        Self {
            compressing: true,
            strikes: 0,
            frames_since_probe: 0,
            max_ratio: 0.9,
            strike_limit: 3,
            probe_interval: 64,
            m_ratio: m.histogram(&format!("codec.auto.{link}.ratio")),
            m_zlib_frames: m.counter(&format!("codec.auto.{link}.zlib_frames")),
            m_none_frames: m.counter(&format!("codec.auto.{link}.none_frames")),
            m_to_none: m.counter(&format!("codec.auto.{link}.to_none")),
            m_to_zlib: m.counter(&format!("codec.auto.{link}.to_zlib")),
        }
    }

    /// Codec to use for the next frame (Zlib while the link compresses
    /// well, None otherwise, with a periodic Zlib probe).
    pub fn next_codec(&mut self) -> Codec {
        if self.compressing {
            return Codec::Zlib;
        }
        self.frames_since_probe += 1;
        if self.frames_since_probe >= self.probe_interval {
            self.frames_since_probe = 0;
            Codec::Zlib
        } else {
            Codec::None
        }
    }

    /// Record the outcome of a deflated frame (raw vs compressed bytes)
    /// and update the mode.
    pub fn record_zlib(&mut self, raw: usize, compressed: usize) {
        let ratio = if raw == 0 { 1.0 } else { compressed as f64 / raw as f64 };
        self.m_ratio.observe(ratio);
        self.m_zlib_frames.inc();
        if ratio >= self.max_ratio {
            self.strikes = self.strikes.saturating_add(1);
            if self.compressing && self.strikes >= self.strike_limit {
                self.compressing = false;
                self.frames_since_probe = 0;
                self.m_to_none.inc();
            }
        } else {
            self.strikes = 0;
            if !self.compressing {
                self.compressing = true;
                self.m_to_zlib.inc();
            }
        }
    }

    /// Record a frame sent uncompressed in pass-through mode.
    pub fn record_none(&mut self) {
        self.m_none_frames.inc();
    }

    /// Is the link currently paying for deflate? (tests/benches)
    pub fn is_compressing(&self) -> bool {
        self.compressing
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn codec_parse_roundtrip() {
        assert_eq!(Codec::parse("none").unwrap(), Codec::None);
        assert_eq!(Codec::parse("zlib").unwrap(), Codec::Zlib);
        assert_eq!(Codec::parse("gz").unwrap(), Codec::Zlib);
        assert_eq!(Codec::parse("auto").unwrap(), Codec::Auto);
        assert!(Codec::parse("lz99").is_err());
    }

    #[test]
    fn none_is_identity() {
        let data = vec![1u8, 2, 3];
        assert_eq!(compress(Codec::None, &data).unwrap(), data);
        assert_eq!(decompress(Codec::None, &data).unwrap(), data);
    }

    #[test]
    fn auto_is_not_a_wire_codec() {
        assert!(compress(Codec::Auto, &[1, 2, 3]).is_err());
        assert!(decompress(Codec::Auto, &[1, 2, 3]).is_err());
    }

    #[test]
    fn zlib_roundtrip_compressible() {
        let data = vec![7u8; 100_000];
        let c = compress(Codec::Zlib, &data).unwrap();
        assert!(c.len() < data.len() / 10, "compressed {} of {}", c.len(), data.len());
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_random() {
        let mut rng = XorShift64::new(1);
        let mut data = vec![0u8; 10_000];
        rng.fill_bytes(&mut data);
        let c = compress(Codec::Zlib, &data).unwrap();
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_empty() {
        let c = compress(Codec::Zlib, &[]).unwrap();
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn deflate_into_appends_in_place() {
        let mut frame = b"HEADER".to_vec();
        let data = vec![9u8; 50_000];
        let n = deflate_into(&mut frame, &data).unwrap();
        assert_eq!(frame.len(), 6 + n);
        assert_eq!(&frame[..6], b"HEADER");
        assert_eq!(inflate_guarded(&frame[6..], MAX_DECOMPRESSED).unwrap(), data);
    }

    #[test]
    fn deflate_ops_counts_compressions() {
        let before = deflate_ops();
        let _ = compress(Codec::Zlib, &[1, 2, 3]).unwrap();
        assert!(deflate_ops() > before);
    }

    #[test]
    fn corrupt_stream_errors() {
        assert!(decompress(Codec::Zlib, &[1, 2, 3, 4]).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let data = vec![5u8; 20_000];
        let c = compress(Codec::Zlib, &data).unwrap();
        for cut in [1, c.len() / 2, c.len() - 1] {
            let e = inflate_guarded(&c[..cut], MAX_DECOMPRESSED).unwrap_err();
            assert!(matches!(e, Error::Serial(_)), "cut at {cut}: {e}");
        }
        assert!(inflate_guarded(&[], MAX_DECOMPRESSED).is_err());
    }

    #[test]
    fn bomb_rejected_mid_stream() {
        // 4 MiB of zeros deflates to a few KiB; inflating under a 64 KiB
        // limit must fail once the limit is crossed, not after expanding
        // the whole stream.
        let raw = vec![0u8; 4 * 1024 * 1024];
        let c = compress(Codec::Zlib, &raw).unwrap();
        assert!(c.len() < 64 * 1024);
        let e = inflate_guarded(&c, 64 * 1024).unwrap_err();
        assert!(e.to_string().contains("limit"), "{e}");
        // A payload exactly at the limit still inflates.
        let ok = vec![3u8; 64 * 1024];
        let c2 = compress(Codec::Zlib, &ok).unwrap();
        assert_eq!(inflate_guarded(&c2, 64 * 1024).unwrap(), ok);
    }

    #[test]
    fn limit_is_exact_not_capacity_rounded() {
        // Regression: Vec's amortized growth must not smuggle in output
        // past the limit — one byte over is rejected, exactly-at passes,
        // for an odd limit that no power-of-two capacity lands on.
        let limit = 100_003u64;
        let at = vec![9u8; limit as usize];
        let over = vec![9u8; limit as usize + 1];
        let c_at = compress(Codec::Zlib, &at).unwrap();
        let c_over = compress(Codec::Zlib, &over).unwrap();
        assert_eq!(inflate_guarded(&c_at, limit).unwrap(), at);
        assert!(inflate_guarded(&c_over, limit).is_err());
    }

    #[test]
    fn auto_codec_disables_on_incompressible_then_reprobes() {
        let mut auto = AutoCodec::new("test-link");
        assert!(auto.is_compressing());
        // Incompressible frames: ratio ~1.0 -> strikes out after 3.
        for _ in 0..auto.strike_limit {
            assert_eq!(auto.next_codec(), Codec::Zlib);
            auto.record_zlib(1000, 990);
        }
        assert!(!auto.is_compressing());
        // Pass-through until the probe interval elapses.
        let mut zlib_probes = 0;
        for _ in 0..auto.probe_interval {
            if auto.next_codec() == Codec::Zlib {
                zlib_probes += 1;
                // Content turned compressible: switch back on.
                auto.record_zlib(1000, 100);
            } else {
                auto.record_none();
            }
        }
        assert_eq!(zlib_probes, 1, "expected exactly one probe per interval");
        assert!(auto.is_compressing(), "good probe ratio must re-enable zlib");
        assert_eq!(auto.next_codec(), Codec::Zlib);
    }

    #[test]
    fn auto_codec_stays_off_while_probes_fail() {
        let mut auto = AutoCodec::new("test-link-2");
        for _ in 0..auto.strike_limit {
            auto.next_codec();
            auto.record_zlib(100, 100);
        }
        assert!(!auto.is_compressing());
        for _ in 0..(3 * auto.probe_interval) {
            if auto.next_codec() == Codec::Zlib {
                auto.record_zlib(100, 100); // probe still incompressible
            } else {
                auto.record_none();
            }
            assert!(!auto.is_compressing());
        }
    }
}
