//! Frame compression for inter-pipeline transmission (R3; gst-gz analog).
//!
//! zlib via `flate2`. Transport elements apply this per-frame when
//! `compress=zlib` is configured; the wire flag travels in the EdgeFrame
//! header so receivers self-configure.

use std::io::{Read, Write};

use crate::util::{Error, Result};

/// Compression codec for transport frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    #[default]
    None,
    Zlib,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Zlib => "zlib",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Codec::None,
            "zlib" | "gz" => Codec::Zlib,
            other => return Err(Error::Serial(format!("unknown codec `{other}`"))),
        })
    }
}

/// Max decompressed size we accept (guards hostile frames): 256 MiB.
const MAX_DECOMPRESSED: u64 = 256 * 1024 * 1024;

pub fn compress(codec: Codec, data: &[u8]) -> Result<Vec<u8>> {
    match codec {
        Codec::None => Ok(data.to_vec()),
        Codec::Zlib => {
            let mut enc = flate2::write::ZlibEncoder::new(
                Vec::with_capacity(data.len() / 2 + 64),
                flate2::Compression::fast(),
            );
            enc.write_all(data).map_err(|e| Error::Serial(e.to_string()))?;
            enc.finish().map_err(|e| Error::Serial(e.to_string()))
        }
    }
}

pub fn decompress(codec: Codec, data: &[u8]) -> Result<Vec<u8>> {
    match codec {
        Codec::None => Ok(data.to_vec()),
        Codec::Zlib => {
            let mut dec = flate2::read::ZlibDecoder::new(data).take(MAX_DECOMPRESSED);
            let mut out = Vec::with_capacity(data.len() * 2);
            dec.read_to_end(&mut out).map_err(|e| Error::Serial(e.to_string()))?;
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn codec_parse_roundtrip() {
        assert_eq!(Codec::parse("none").unwrap(), Codec::None);
        assert_eq!(Codec::parse("zlib").unwrap(), Codec::Zlib);
        assert_eq!(Codec::parse("gz").unwrap(), Codec::Zlib);
        assert!(Codec::parse("lz99").is_err());
    }

    #[test]
    fn none_is_identity() {
        let data = vec![1u8, 2, 3];
        assert_eq!(compress(Codec::None, &data).unwrap(), data);
        assert_eq!(decompress(Codec::None, &data).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_compressible() {
        let data = vec![7u8; 100_000];
        let c = compress(Codec::Zlib, &data).unwrap();
        assert!(c.len() < data.len() / 10, "compressed {} of {}", c.len(), data.len());
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_random() {
        let mut rng = XorShift64::new(1);
        let mut data = vec![0u8; 10_000];
        rng.fill_bytes(&mut data);
        let c = compress(Codec::Zlib, &data).unwrap();
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip_empty() {
        let c = compress(Codec::Zlib, &[]).unwrap();
        assert_eq!(decompress(Codec::Zlib, &c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn corrupt_stream_errors() {
        assert!(decompress(Codec::Zlib, &[1, 2, 3, 4]).is_err());
    }
}
