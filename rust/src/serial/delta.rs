//! XOR-delta wire codec state (`Codec::Delta`).
//!
//! Consecutive frames on an among-device link are rarely independent:
//! video-like tensor streams change a few regions per frame and
//! personalization traffic repeats most of its payload. The delta codec
//! exploits that: each frame is XORed against the link's *previous*
//! frame and the residue — mostly zero bytes for correlated streams —
//! is deflated. The XOR is computed in fixed-size chunks that stream
//! straight into the compressor, which writes onto the tail of the
//! frame being assembled (`deflate_into` style), so a delta frame is
//! still ONE allocation and no payload-sized scratch buffer ever
//! exists.
//!
//! Loss recovery: every `keyframe_interval` frames (and whenever the
//! chain is broken — first frame, payload size change, a non-delta
//! frame interleaved on the link) the encoder emits a *keyframe*: a
//! plain full-frame deflate flagged in the wire header. Every
//! delta-codec frame also carries a wrapping `chain_seq` byte; the
//! decoder ([`crate::serial::wire::LinkDecoder`]) applies a delta only
//! when it is synced and the sequence matches, and otherwise drops
//! deltas until the next keyframe rather than reconstructing a corrupt
//! tensor. (A u8 sequence aliases after exactly 256 lost frames, but a
//! chain never spans more than `keyframe_interval` deltas, so an
//! aliased sequence inside a live chain is impossible; the payload
//! length check narrows the remaining window further.)

use crate::serial::compress;
use crate::util::{Error, Result};

/// Default frames per keyframe period (1 keyframe + N-1 deltas).
pub const DEFAULT_KEYFRAME_INTERVAL: u64 = 16;

/// XOR scratch chunk: big enough to keep the compressor busy, small
/// enough to live on the stack.
const CHUNK: usize = 8 * 1024;

/// Deflate `data XOR prev` appended directly onto `out` (the frame
/// being assembled). Returns the number of compressed bytes written.
/// The residue is produced chunk-by-chunk into a stack buffer and
/// streamed into the compressor — no residue-sized allocation.
pub fn xor_deflate_into(out: &mut Vec<u8>, data: &[u8], prev: &[u8]) -> Result<usize> {
    if data.len() != prev.len() {
        return Err(Error::Serial(format!(
            "delta payload {} bytes != previous frame {} bytes",
            data.len(),
            prev.len()
        )));
    }
    compress::note_deflate();
    let start = out.len();
    let mut c = flate2::Compress::new(flate2::Compression::fast(), true);
    let mut scratch = [0u8; CHUNK];
    let mut fed = 0usize;
    loop {
        let end = (fed + CHUNK).min(data.len());
        let chunk_len = end - fed;
        for i in 0..chunk_len {
            scratch[i] = data[fed + i] ^ prev[fed + i];
        }
        let last = end == data.len();
        let flush =
            if last { flate2::FlushCompress::Finish } else { flate2::FlushCompress::None };
        let mut consumed = 0usize;
        loop {
            // Guarantee spare output capacity so every iteration progresses.
            if out.capacity() - out.len() < 1024 {
                out.reserve((data.len() / 2 + 64).max(4096));
            }
            let before = c.total_in();
            let status = c
                .compress_vec(&scratch[consumed..chunk_len], out, flush)
                .map_err(|e| Error::Serial(format!("delta deflate: {e}")))?;
            consumed += (c.total_in() - before) as usize;
            if last {
                if status == flate2::Status::StreamEnd {
                    return Ok(out.len() - start);
                }
            } else if consumed == chunk_len {
                break;
            }
        }
        fed = end;
    }
}

/// Reconstruct a frame from its inflated XOR residue, in place:
/// `residue[i] ^= prev[i]`. Lengths must match (the decoder treats a
/// mismatch as a broken chain before calling this).
pub fn apply_delta(residue: &mut [u8], prev: &[u8]) -> Result<()> {
    if residue.len() != prev.len() {
        return Err(Error::Serial(format!(
            "delta residue {} bytes != previous frame {} bytes",
            residue.len(),
            prev.len()
        )));
    }
    for (r, &p) in residue.iter_mut().zip(prev) {
        *r ^= p;
    }
    Ok(())
}

/// Encode-side delta-chain state for one link: tracks whether the
/// receiver's previous frame matches ours (`valid`), the wrapping
/// chain sequence, and the keyframe cadence.
#[derive(Debug)]
pub struct DeltaChain {
    valid: bool,
    seq: u8,
    since_key: u64,
    interval: u64,
}

impl DeltaChain {
    pub fn new(interval: u64) -> Self {
        Self { valid: false, seq: 0, since_key: 0, interval: interval.max(1) }
    }

    pub fn set_interval(&mut self, interval: u64) {
        self.interval = interval.max(1);
    }

    pub fn interval(&self) -> u64 {
        self.interval
    }

    /// Must the next delta-codec frame be a keyframe? Yes when the
    /// chain is broken, the payload length changed (XOR needs equal
    /// lengths), or the keyframe period elapsed.
    pub fn needs_keyframe(&self, prev_len: Option<usize>, len: usize) -> bool {
        !self.valid || prev_len != Some(len) || self.since_key + 1 >= self.interval
    }

    /// Record an emitted keyframe; returns the chain-seq to stamp.
    pub fn on_keyframe(&mut self) -> u8 {
        self.valid = true;
        self.since_key = 0;
        self.seq = self.seq.wrapping_add(1);
        self.seq
    }

    /// Record an emitted delta frame; returns the chain-seq to stamp.
    pub fn on_delta(&mut self) -> u8 {
        debug_assert!(self.valid, "delta emitted on an invalid chain");
        self.since_key += 1;
        self.seq = self.seq.wrapping_add(1);
        self.seq
    }

    /// A non-delta frame went out on this link (or the link
    /// reconnected): the receiver's previous frame no longer matches,
    /// so the next delta-codec frame must re-key.
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    pub fn is_valid(&self) -> bool {
        self.valid
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::compress::{inflate_guarded, MAX_DECOMPRESSED};
    use crate::util::rng::XorShift64;

    fn roundtrip(data: &[u8], prev: &[u8]) -> Vec<u8> {
        let mut frame = b"HDR".to_vec();
        let n = xor_deflate_into(&mut frame, data, prev).unwrap();
        assert_eq!(frame.len(), 3 + n);
        assert_eq!(&frame[..3], b"HDR");
        let mut residue = inflate_guarded(&frame[3..], MAX_DECOMPRESSED).unwrap();
        apply_delta(&mut residue, prev).unwrap();
        residue
    }

    #[test]
    fn correlated_frames_deflate_small() {
        // A frame that differs from its predecessor in a handful of
        // bytes must produce a tiny delta (mostly-zero residue).
        let prev = vec![42u8; 100_000];
        let mut data = prev.clone();
        for i in (0..data.len()).step_by(9000) {
            data[i] = data[i].wrapping_add(1);
        }
        let mut out = Vec::new();
        let n = xor_deflate_into(&mut out, &data, &prev).unwrap();
        assert!(n < 2_000, "delta residue should deflate to almost nothing, got {n}");
        assert_eq!(roundtrip(&data, &prev), data);
    }

    #[test]
    fn random_frames_roundtrip_across_chunk_boundaries() {
        let mut rng = XorShift64::new(11);
        // Sizes straddling the XOR chunk size, including 0 and exact
        // multiples.
        for len in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 17] {
            let mut data = vec![0u8; len];
            let mut prev = vec![0u8; len];
            rng.fill_bytes(&mut data);
            rng.fill_bytes(&mut prev);
            assert_eq!(roundtrip(&data, &prev), data, "len {len}");
        }
    }

    #[test]
    fn length_mismatch_rejected() {
        let mut out = Vec::new();
        assert!(xor_deflate_into(&mut out, &[1, 2, 3], &[1, 2]).is_err());
        let mut residue = vec![1u8, 2];
        assert!(apply_delta(&mut residue, &[0u8; 3]).is_err());
    }

    #[test]
    fn chain_keyframe_cadence() {
        let mut chain = DeltaChain::new(4);
        // First frame: no history -> keyframe.
        assert!(chain.needs_keyframe(None, 100));
        let k = chain.on_keyframe();
        // Three deltas fit in the period, the fourth frame re-keys.
        for i in 0..3u8 {
            assert!(!chain.needs_keyframe(Some(100), 100));
            assert_eq!(chain.on_delta(), k.wrapping_add(i + 1));
        }
        assert!(chain.needs_keyframe(Some(100), 100), "period elapsed");
        chain.on_keyframe();
        // A payload size change always re-keys.
        assert!(chain.needs_keyframe(Some(100), 101));
        // A non-delta frame on the link breaks the chain.
        chain.invalidate();
        assert!(chain.needs_keyframe(Some(100), 100));
    }

    #[test]
    fn interval_one_is_all_keyframes() {
        let mut chain = DeltaChain::new(1);
        chain.on_keyframe();
        assert!(chain.needs_keyframe(Some(10), 10));
        // 0 clamps to 1 rather than dividing by zero semantics.
        let chain0 = DeltaChain::new(0);
        assert_eq!(chain0.interval(), 1);
    }

    #[test]
    fn chain_seq_wraps() {
        let mut chain = DeltaChain::new(u64::MAX);
        let first = chain.on_keyframe();
        let mut last = first;
        for _ in 0..300 {
            last = chain.on_delta();
        }
        assert_eq!(last, first.wrapping_add(300));
    }
}
