//! Schemaless typed-tree serialization — the FlexBuffers-role codec
//! behind `other/flexbuf` streams (§4.1, R2).
//!
//! A `Value` is a dynamically-typed tree (null/bool/int/uint/float/str/
//! blob/vector/map).  The wire format is a compact tag+varint encoding of
//! our own; the *semantics* (no schema required at launch, self-describing
//! frames, type checks at decode) match what the paper uses FlexBuffers
//! for.  As the paper warns, schemaless streams trade launch-time type
//! verification for run-time checks — the decoder therefore validates
//! exhaustively and errors loudly.

use std::collections::BTreeMap;

use crate::util::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    UInt(u64),
    Float(f64),
    Str(String),
    Blob(Vec<u8>),
    Vector(Vec<Value>),
    Map(BTreeMap<String, Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::UInt(_) => "uint",
            Value::Float(_) => "float",
            Value::Str(_) => "str",
            Value::Blob(_) => "blob",
            Value::Vector(_) => "vector",
            Value::Map(_) => "map",
        }
    }

    // -- typed accessors (runtime schema checks) --------------------------

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::UInt(v) => Ok(*v),
            Value::Int(v) if *v >= 0 => Ok(*v as u64),
            other => Err(Error::Serial(format!("expected uint, got {}", other.type_name()))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::UInt(v) if *v <= i64::MAX as u64 => Ok(*v as i64),
            other => Err(Error::Serial(format!("expected int, got {}", other.type_name()))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            Value::UInt(v) => Ok(*v as f64),
            other => Err(Error::Serial(format!("expected float, got {}", other.type_name()))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(Error::Serial(format!("expected str, got {}", other.type_name()))),
        }
    }

    pub fn as_blob(&self) -> Result<&[u8]> {
        match self {
            Value::Blob(b) => Ok(b),
            other => Err(Error::Serial(format!("expected blob, got {}", other.type_name()))),
        }
    }

    pub fn as_vector(&self) -> Result<&[Value]> {
        match self {
            Value::Vector(v) => Ok(v),
            other => Err(Error::Serial(format!("expected vector, got {}", other.type_name()))),
        }
    }

    pub fn as_map(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Map(m) => Ok(m),
            other => Err(Error::Serial(format!("expected map, got {}", other.type_name()))),
        }
    }

    /// Map field lookup with a descriptive error.
    pub fn field(&self, key: &str) -> Result<&Value> {
        self.as_map()?
            .get(key)
            .ok_or_else(|| Error::Serial(format!("missing field `{key}`")))
    }
}

const TAG_NULL: u8 = 0;
const TAG_FALSE: u8 = 1;
const TAG_TRUE: u8 = 2;
const TAG_INT: u8 = 3;
const TAG_UINT: u8 = 4;
const TAG_FLOAT: u8 = 5;
const TAG_STR: u8 = 6;
const TAG_BLOB: u8 = 7;
const TAG_VEC: u8 = 8;
const TAG_MAP: u8 = 9;

/// Recursion guard: deeper trees than this are rejected at decode.
const MAX_DEPTH: usize = 64;

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn get_varint(buf: &[u8], off: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*off).ok_or_else(|| Error::Serial("varint truncated".into()))?;
        *off += 1;
        if shift >= 64 {
            return Err(Error::Serial("varint overflow".into()));
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// ZigZag for signed ints.
fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

pub fn encode(v: &Value) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(v, &mut out);
    out
}

fn encode_into(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Null => out.push(TAG_NULL),
        Value::Bool(false) => out.push(TAG_FALSE),
        Value::Bool(true) => out.push(TAG_TRUE),
        Value::Int(i) => {
            out.push(TAG_INT);
            put_varint(out, zigzag(*i));
        }
        Value::UInt(u) => {
            out.push(TAG_UINT);
            put_varint(out, *u);
        }
        Value::Float(f) => {
            out.push(TAG_FLOAT);
            out.extend_from_slice(&f.to_le_bytes());
        }
        Value::Str(s) => {
            out.push(TAG_STR);
            put_varint(out, s.len() as u64);
            out.extend_from_slice(s.as_bytes());
        }
        Value::Blob(b) => {
            out.push(TAG_BLOB);
            put_varint(out, b.len() as u64);
            out.extend_from_slice(b);
        }
        Value::Vector(items) => {
            out.push(TAG_VEC);
            put_varint(out, items.len() as u64);
            for item in items {
                encode_into(item, out);
            }
        }
        Value::Map(m) => {
            out.push(TAG_MAP);
            put_varint(out, m.len() as u64);
            for (k, val) in m {
                put_varint(out, k.len() as u64);
                out.extend_from_slice(k.as_bytes());
                encode_into(val, out);
            }
        }
    }
}

pub fn decode(buf: &[u8]) -> Result<Value> {
    let mut off = 0;
    let v = decode_at(buf, &mut off, 0)?;
    if off != buf.len() {
        return Err(Error::Serial(format!("{} trailing bytes after flexbuf value", buf.len() - off)));
    }
    Ok(v)
}

fn take<'a>(buf: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
    let s = buf
        .get(*off..*off + n)
        .ok_or_else(|| Error::Serial(format!("flexbuf truncated: need {n} at {off}", off = *off)))?;
    *off += n;
    Ok(s)
}

fn decode_at(buf: &[u8], off: &mut usize, depth: usize) -> Result<Value> {
    if depth > MAX_DEPTH {
        return Err(Error::Serial("flexbuf nesting too deep".into()));
    }
    let tag = *buf.get(*off).ok_or_else(|| Error::Serial("flexbuf empty".into()))?;
    *off += 1;
    Ok(match tag {
        TAG_NULL => Value::Null,
        TAG_FALSE => Value::Bool(false),
        TAG_TRUE => Value::Bool(true),
        TAG_INT => Value::Int(unzigzag(get_varint(buf, off)?)),
        TAG_UINT => Value::UInt(get_varint(buf, off)?),
        TAG_FLOAT => {
            let b = take(buf, off, 8)?;
            Value::Float(f64::from_le_bytes(b.try_into().unwrap()))
        }
        TAG_STR => {
            let n = get_varint(buf, off)? as usize;
            let b = take(buf, off, n)?;
            Value::Str(String::from_utf8(b.to_vec()).map_err(|e| Error::Serial(e.to_string()))?)
        }
        TAG_BLOB => {
            let n = get_varint(buf, off)? as usize;
            Value::Blob(take(buf, off, n)?.to_vec())
        }
        TAG_VEC => {
            let n = get_varint(buf, off)? as usize;
            if n > buf.len() {
                return Err(Error::Serial(format!("vector claims {n} items in {} bytes", buf.len())));
            }
            let mut items = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                items.push(decode_at(buf, off, depth + 1)?);
            }
            Value::Vector(items)
        }
        TAG_MAP => {
            let n = get_varint(buf, off)? as usize;
            if n > buf.len() {
                return Err(Error::Serial(format!("map claims {n} entries in {} bytes", buf.len())));
            }
            let mut m = BTreeMap::new();
            for _ in 0..n {
                let klen = get_varint(buf, off)? as usize;
                let kb = take(buf, off, klen)?;
                let k = String::from_utf8(kb.to_vec()).map_err(|e| Error::Serial(e.to_string()))?;
                m.insert(k, decode_at(buf, off, depth + 1)?);
            }
            Value::Map(m)
        }
        other => return Err(Error::Serial(format!("unknown flexbuf tag {other}"))),
    })
}

/// Convenience: build a map value.
pub fn map(entries: Vec<(&str, Value)>) -> Value {
    Value::Map(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: Value) {
        let enc = encode(&v);
        assert_eq!(decode(&enc).unwrap(), v);
    }

    #[test]
    fn scalars_roundtrip() {
        roundtrip(Value::Null);
        roundtrip(Value::Bool(true));
        roundtrip(Value::Bool(false));
        roundtrip(Value::Int(-12345));
        roundtrip(Value::Int(i64::MIN));
        roundtrip(Value::UInt(u64::MAX));
        roundtrip(Value::Float(3.25));
        roundtrip(Value::Str("hello 🌍".into()));
        roundtrip(Value::Blob(vec![0, 255, 7]));
    }

    #[test]
    fn nested_structures_roundtrip() {
        roundtrip(map(vec![
            ("dims", Value::Vector(vec![Value::UInt(4), Value::UInt(20)])),
            ("dtype", Value::Str("float32".into())),
            ("data", Value::Blob(vec![1, 2, 3, 4])),
            (
                "meta",
                map(vec![("pts", Value::UInt(123)), ("live", Value::Bool(true))]),
            ),
        ]));
    }

    #[test]
    fn empty_containers() {
        roundtrip(Value::Vector(vec![]));
        roundtrip(Value::Map(BTreeMap::new()));
    }

    #[test]
    fn zigzag_symmetry() {
        for v in [0i64, 1, -1, 42, -42, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn truncation_detected() {
        let enc = encode(&Value::Str("hello".into()));
        for cut in 1..enc.len() {
            assert!(decode(&enc[..cut]).is_err(), "cut at {cut} should fail");
        }
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut enc = encode(&Value::Int(5));
        enc.push(0);
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(decode(&[200]).is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        // vector claiming u64::MAX items must not OOM
        let mut buf = vec![TAG_VEC];
        put_varint(&mut buf, u64::MAX);
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn deep_nesting_rejected() {
        let mut v = Value::Null;
        for _ in 0..100 {
            v = Value::Vector(vec![v]);
        }
        let enc = encode(&v);
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = map(vec![("n", Value::UInt(7)), ("s", Value::Str("x".into()))]);
        assert_eq!(v.field("n").unwrap().as_u64().unwrap(), 7);
        assert_eq!(v.field("s").unwrap().as_str().unwrap(), "x");
        assert!(v.field("missing").is_err());
        assert!(v.field("s").unwrap().as_u64().is_err());
        assert_eq!(Value::Int(3).as_f64().unwrap(), 3.0);
        assert_eq!(Value::Int(3).as_u64().unwrap(), 3);
        assert!(Value::Int(-3).as_u64().is_err());
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 127, 128, 16383, 16384, u64::MAX] {
            let mut out = Vec::new();
            put_varint(&mut out, v);
            let mut off = 0;
            assert_eq!(get_varint(&out, &mut off).unwrap(), v);
            assert_eq!(off, out.len());
        }
    }
}
