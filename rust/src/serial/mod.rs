//! Serialization for inter-pipeline transmission (§4.1–4.2):
//! [`flexbuf`] schemaless trees, [`compress`] frame compression,
//! [`delta`] the XOR-delta link codec, and [`wire`] the EdgeFrame
//! transport envelope with its per-link codec stack
//! (`LinkCodec`/`LinkDecoder`).

pub mod compress;
pub mod delta;
pub mod flexbuf;
pub mod wire;

pub use compress::Codec;
pub use flexbuf::Value;

use crate::tensor::{DType, TensorInfo, TensorsInfo};
use crate::util::{Error, Result};

/// Encode a static tensors frame as a schemaless flexbuf value
/// (`tensor_decoder mode=flexbuf` / `other/flexbuf` streams).
pub fn tensors_to_flexbuf(info: &TensorsInfo, payload: &[u8]) -> Result<Vec<u8>> {
    if payload.len() != info.frame_size() {
        return Err(Error::Serial(format!(
            "payload {} != frame size {}",
            payload.len(),
            info.frame_size()
        )));
    }
    let mut tensors = Vec::with_capacity(info.len());
    let mut off = 0;
    for t in &info.tensors {
        let sz = t.size();
        tensors.push(flexbuf::map(vec![
            ("dtype", Value::Str(t.dtype.name().into())),
            (
                "dims",
                Value::Vector(t.dims.iter().map(|&d| Value::UInt(d as u64)).collect()),
            ),
            ("data", Value::Blob(payload[off..off + sz].to_vec())),
        ]));
        off += sz;
    }
    Ok(flexbuf::encode(&flexbuf::map(vec![
        ("num_tensors", Value::UInt(info.len() as u64)),
        ("tensors", Value::Vector(tensors)),
    ])))
}

/// Decode a flexbuf frame back into (TensorsInfo, payload) — the
/// `tensor_converter` path for `other/flexbuf` input (§4.1).
pub fn flexbuf_to_tensors(frame: &[u8]) -> Result<(TensorsInfo, Vec<u8>)> {
    let v = flexbuf::decode(frame)?;
    let n = v.field("num_tensors")?.as_u64()? as usize;
    let tensors = v.field("tensors")?.as_vector()?;
    if tensors.len() != n {
        return Err(Error::Serial(format!("num_tensors={n} but {} entries", tensors.len())));
    }
    let mut info = TensorsInfo::default();
    let mut payload = Vec::new();
    for t in tensors {
        let dtype = DType::parse(t.field("dtype")?.as_str()?)?;
        let dims_v = t.field("dims")?.as_vector()?;
        let mut dims = Vec::with_capacity(dims_v.len());
        for d in dims_v {
            dims.push(d.as_u64()? as u32);
        }
        let ti = TensorInfo::new(dtype, &dims)?;
        let data = t.field("data")?.as_blob()?;
        if data.len() != ti.size() {
            return Err(Error::Serial(format!(
                "tensor data {} != declared size {}",
                data.len(),
                ti.size()
            )));
        }
        payload.extend_from_slice(data);
        info.push(ti)?;
    }
    Ok((info, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensors_flexbuf_roundtrip() {
        let mut info = TensorsInfo::default();
        info.push(TensorInfo::new(DType::F32, &[4, 20]).unwrap()).unwrap();
        info.push(TensorInfo::new(DType::U8, &[5]).unwrap()).unwrap();
        let payload: Vec<u8> = (0..info.frame_size() as u32).map(|x| x as u8).collect();
        let enc = tensors_to_flexbuf(&info, &payload).unwrap();
        let (info2, payload2) = flexbuf_to_tensors(&enc).unwrap();
        assert_eq!(info2, info);
        assert_eq!(payload2, payload);
    }

    #[test]
    fn flexbuf_size_mismatch_rejected() {
        let info = TensorsInfo::one(TensorInfo::new(DType::F32, &[4]).unwrap());
        assert!(tensors_to_flexbuf(&info, &[0u8; 3]).is_err());
    }

    #[test]
    fn flexbuf_wrong_shape_rejected() {
        // A structurally valid flexbuf that is not a tensors frame.
        let v = flexbuf::map(vec![("hello", Value::Int(1))]);
        assert!(flexbuf_to_tensors(&flexbuf::encode(&v)).is_err());
    }
}
